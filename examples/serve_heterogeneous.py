"""Serve a heterogeneous expert ensemble with batched requests.

Loads the self-describing checkpoints written by
``examples/train_decentralized.py`` (runs it automatically if the
directory is empty) and serves batched "prompts" through the ServingEngine
with the Fig. 2 inference pipeline, reporting latency per strategy.

  PYTHONPATH=src python examples/serve_heterogeneous.py --ckpt /tmp/hddm

Quantized expert storage (``--param-dtype``, ``core.param_store``): the
stacked expert pytree loads into a typed ``ExpertParamStore`` whose
storage dtype is independent of the checkpoints.  ``int8``/``fp8``
quantize on load with symmetric per-expert-per-leaf scales, drop the
full-precision per-expert param list, and dequantize only the *routed*
slices each step through the fused ``hetero_fuse_dequant`` Pallas
kernel.  Resident expert-param bytes per stored parameter (fp32
checkpoints; exact ratios for an 8-expert dit-b2 ensemble are tracked in
the ``quantized`` section of ``BENCH_sampler.json`` via
``benchmarks/bench_sampler.py --param-dtype int8``):

  ============  =======================  ==========
  param_dtype   bytes/param              vs fp32
  ============  =======================  ==========
  native/fp32   4                        1.0x
  bf16          2                        2.0x
  int8          1 (+4·K/leaf scales)     ~3.99x
  fp8           1 (+4·K/leaf scales)     ~3.99x
  ============  =======================  ==========

int8 round-trip error is ≤ 1/254 ≈ 4e-3 of each expert-leaf's absmax
(sampler outputs stay within FID-proxy tolerance of dense — see
``tests/test_param_store.py``); fp8 (e4m3) carries ≤ 6.25e-2 element
relative error.

On an **elastic** engine (``capacity=K_cap``, see the walkthrough at the
end of this example) the table scales by the capacity, not the live
count: the store is padded to ``K_cap`` slots along the expert axis, so
resident bytes carry a ``(K_cap - K)/K`` overhead of zero-filled padded
slots (int8/fp8 pad with 0 qvals and unit scales).  Padded and evicted
slots are masked by the store's validity bit-vector — never routed,
never gathered — so the overhead is memory-only, not compute.

Step-fused sampling + plan reuse (``--plan-refresh``,
``core.sampling``): every engine here runs the step-fused hot path by
default (``SamplerConfig.step_fused`` — CFG combine + Euler update
folded into the convert-and-fuse kernel, bit-identical to the unfused
chain).  ``--plan-refresh R`` additionally recomputes the router
posterior + ``DispatchPlan`` only every R-th Euler step, carrying the
plan through the scan between refreshes.  The R-vs-parity trade-off
(vs per-step routing; drift measured on the 8-expert top-2 CFG bench
ensemble, ``plan_reuse`` section of ``BENCH_sampler.json``):

  ====  ==========================  =================================
  R     routing work per run        parity vs per-step routing
  ====  ==========================  =================================
  1     every step (S refreshes)    bit-identical (max abs diff = 0)
  2     ceil(S/2) refreshes         small drift: routed experts only
                                    change between refresh steps
  4     ceil(S/4) refreshes         ~1.09x img/s; drift ≈ 0.27 of the
                                    latent scale on the UNTRAINED
                                    bench router (trained routers
                                    whose posteriors vary slowly in t
                                    — the §3.1 premise — drift less)
  8     ceil(S/8) refreshes         ~1.16x img/s; drift ≈ 0.40 of the
                                    latent scale, same caveat
  ====  ==========================  =================================

Cross-request conditioning cache (``--cond-cache``,
``ServingEngine.cond_cache_size``): a content-hash-keyed LRU dedupes
byte-identical text embeddings across ``generate()``/``submit()``
calls — the intra-prompt-diversity workload (one prompt, many seeds)
holds ONE resident device buffer per distinct prompt.  Hit/miss
behavior is observable via ``engine.stats['cond_cache_hits']`` /
``['cond_cache_misses']`` (printed below), not inferred from timings;
0 disables the cache.
"""

import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core import SamplerConfig
from repro.launch.serve import ServingEngine
from repro.models.config import dit_b2, router_b2


def elastic_walkthrough(steps: int) -> None:
    """Fault-tolerant elastic membership, end to end.

    Builds a 6-expert ensemble with 8 capacity slots, admits a request,
    then — *mid-serving* — hot-adds a freshly published 7th expert and
    evicts expert 2.  The in-flight request still completes against the
    membership it was admitted under (bit-identical routing snapshot);
    the next request routes over the new membership; and neither
    membership change retraced the compiled sampler (K is a capacity,
    not a trace constant — membership is data).
    """
    from repro.models import dit as D
    from repro.training import expert_metadata, save_checkpoint

    cfg = dit_b2().reduced(latent_size=8)
    rcfg = router_b2(num_clusters=8).reduced(latent_size=8)
    with tempfile.TemporaryDirectory() as d:
        for cid in range(6):
            save_checkpoint(
                os.path.join(d, f"expert{cid}.npz"),
                D.init(cfg, jax.random.PRNGKey(10 + cid)),
                metadata=expert_metadata(
                    name=f"e{cid}", objective="fm" if cid % 2 else "ddpm",
                    schedule="linear" if cid % 2 else "cosine",
                    cluster_id=cid, arch=cfg.name),
            )
        save_checkpoint(os.path.join(d, "router.npz"),
                        D.init(rcfg, jax.random.PRNGKey(99)))
        engine = ServingEngine.from_checkpoint_dir(
            d, dit_cfg=cfg, router_cfg=rcfg,
            sampler=SamplerConfig(num_steps=steps, cfg_scale=1.0,
                                  strategy="topk", top_k=2),
            capacity=8,
        )
        print(f"elastic: {engine.membership_line()}")
        key = jax.random.PRNGKey(0)
        text = np.asarray(jax.random.normal(
            key, (4, cfg.text_len, cfg.text_dim)))
        h_inflight = engine.submit(key, text, 4)   # 6-expert membership
        # a 7th contributor publishes a checkpoint mid-serving ...
        joiner = os.path.join(d, "joiner.npz")
        save_checkpoint(joiner, D.init(cfg, jax.random.PRNGKey(16)),
                        metadata=expert_metadata(
                            name="e6", objective="fm", schedule="linear",
                            cluster_id=6, arch=cfg.name))
        slot = engine.add_expert(joiner)
        # ... and expert 2's node drops out
        engine.evict_expert(2)
        h_after = engine.submit(jax.random.PRNGKey(1), text, 4)
        dispatches = engine.flush()    # one dispatch per membership epoch
        for h in (h_inflight, h_after):
            assert np.isfinite(np.asarray(h.result())).all()
        print(f"elastic: hot-added slot {slot}, evicted slot 2 between "
              f"submit() and flush() — {dispatches} dispatches, "
              f"traces={engine.stats['traces']} (no retrace)")
        print(f"elastic: {engine.membership_line()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="/tmp/hddm_ckpts")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--dispatch", default="gathered",
                    choices=("gathered", "grouped"),
                    help="expert-dispatch executor for the routed "
                         "strategies (core.dispatch): 'gathered' = "
                         "per-sample param gather + vmap, 'grouped' = "
                         "sort-based grouped segment execution (one "
                         "forward per resident expert)")
    ap.add_argument("--param-dtype", default="native",
                    choices=("native", "fp32", "bf16", "int8", "fp8"),
                    help="stacked expert-param storage "
                         "(core.param_store): int8/fp8 quantize on load "
                         "(~4x fewer resident bytes, see module "
                         "docstring) and dequantize routed slices "
                         "through the fused Pallas kernel")
    ap.add_argument("--plan-refresh", type=int, default=1,
                    help="recompute router posterior + DispatchPlan only "
                         "every R-th Euler step (R=1 per-step routing, "
                         "bit-identical; see the R-vs-parity table in "
                         "the module docstring)")
    ap.add_argument("--cond-cache", type=int, default=64,
                    help="cross-request conditioning LRU capacity "
                         "(content-hash dedupe of text embeddings; "
                         "0 disables)")
    args = ap.parse_args()

    if not os.path.exists(os.path.join(args.ckpt, "expert0.npz")):
        print(f"no checkpoints under {args.ckpt} — training a tiny "
              "ensemble first ...")
        subprocess.run(
            [sys.executable, os.path.join(os.path.dirname(__file__),
                                          "train_decentralized.py"),
             "--out", args.ckpt, "--steps", "40"],
            check=True,
            env={**os.environ,
                 "PYTHONPATH": os.environ.get("PYTHONPATH", "src")},
        )

    dit_cfg = dit_b2().reduced(latent_size=8)
    rcfg = router_b2(num_clusters=4).reduced(latent_size=8)

    for strategy in ("top1", "topk", "full"):
        # routed strategies go through the selected executor backend and
        # param store; the 'full' strategy runs every expert, where only
        # the dense executor applies (and needs the full-precision
        # per-expert params), so it stays on auto/native.
        routed = strategy in ("top1", "topk")
        dispatch = args.dispatch if routed else "auto"
        param_dtype = args.param_dtype if routed else "native"
        engine = ServingEngine.from_checkpoint_dir(
            args.ckpt, dit_cfg=dit_cfg, router_cfg=rcfg,
            sampler=SamplerConfig(num_steps=args.steps, cfg_scale=1.0,
                                  strategy=strategy, top_k=2,
                                  dispatch=dispatch,
                                  param_dtype=param_dtype,
                                  plan_refresh_every=args.plan_refresh),
            cond_cache_size=args.cond_cache,
        )
        objectives = [e.objective for e in engine.experts]
        lat = []
        for r in range(args.requests):
            key = jax.random.PRNGKey(r)
            # host-side ndarray, as a remote text encoder would deliver —
            # the form the conditioning cache hashes (device-resident
            # jax.Arrays pass through unhashed)
            text = np.asarray(jax.random.normal(
                key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
            ))
            t0 = time.time()
            out = jax.block_until_ready(
                engine.generate(key, text, args.batch)
            )
            lat.append(time.time() - t0)
            assert np.isfinite(np.asarray(out)).all()
        # first request includes compile; report steady-state
        steady = np.mean(lat[1:]) if len(lat) > 1 else lat[0]
        print(f"strategy={strategy:5s} dispatch={dispatch:8s} "
              f"params={param_dtype:6s} experts={objectives} "
              f"first={lat[0]:.2f}s steady={steady:.2f}s "
              f"({args.batch/steady:.1f} img/s) "
              f"cond_cache={engine.stats['cond_cache_hits']}h/"
              f"{engine.stats['cond_cache_misses']}m "
              f"plan_refreshes={engine.stats['plan_refreshes']}")

    elastic_walkthrough(args.steps)


if __name__ == "__main__":
    main()
