"""End-to-end decentralized training driver (paper Fig. 6).

Full pipeline: corpus → stub-DINOv2 features → hierarchical k-means →
K isolated heterogeneous experts (2 DDPM + (K-2) FM, the paper's
2DDPM:6FM recipe scaled down) → independent router → self-describing
checkpoints → ensemble sampling report.

Default: tiny CPU-friendly config.  ``--full`` trains DiT-B/2 (121M
params/expert, the paper's small scale) for ``--steps`` steps — sized for a
real accelerator; a few hundred steps of the 121M model also run on CPU in
tens of minutes.

  PYTHONPATH=src python examples/train_decentralized.py --out /tmp/hddm
  PYTHONPATH=src python examples/train_decentralized.py --full --steps 300
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.data import SyntheticSpec, fit_clusters, sample_fid
from repro.data.pipeline import ExpertDataStream, RouterDataStream
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2
from repro.training import (
    AdamWConfig,
    ExpertTrainer,
    RouterTrainer,
    expert_metadata,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--ddpm-experts", type=int, default=2,
                    help="paper's hetero recipe: 2 DDPM : rest FM")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--out", default="/tmp/hddm_ckpts")
    ap.add_argument("--full", action="store_true",
                    help="full DiT-B/2 (121M/expert) instead of reduced")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    K = args.experts
    latent = 32 if args.full else 8
    spec = SyntheticSpec(num_categories=K, latent_size=latent,
                         separation=3.0)
    print(f"[1/4] clustering corpus into {K} partitions ...")
    cm, assign = fit_clusters(spec, corpus_size=1024, num_clusters=K,
                              num_fine=128, seed=args.seed)
    print(f"      cluster sizes: {np.bincount(assign, minlength=K)}")

    cfg = dit_b2() if args.full else dit_b2().reduced(latent_size=latent)
    apply_fn = D.make_expert_apply(cfg)
    n_params = None
    os.makedirs(args.out, exist_ok=True)

    print(f"[2/4] training {K} isolated experts "
          f"({args.ddpm_experts} DDPM : {K - args.ddpm_experts} FM) ...")
    for cid in range(K):
        obj = "ddpm" if cid < args.ddpm_experts else "fm"
        sch = "cosine" if obj == "ddpm" else "linear"
        trainer = ExpertTrainer(
            apply_fn=apply_fn, objective=obj, schedule_name=sch,
            opt=AdamWConfig(learning_rate=1e-4 if args.full else 3e-4,
                            warmup_steps=min(100, args.steps // 10)),
            ema_decay=0.9999 if args.full else 0.8,
        )
        params = D.init(cfg, jax.random.PRNGKey(args.seed + cid))
        if n_params is None:
            n_params = D.param_count(params)
            print(f"      expert size: {n_params/1e6:.1f}M params")
        state = trainer.init_state(params)
        stream = ExpertDataStream(spec, cm, cluster_id=cid,
                                  batch_size=args.batch, seed=cid)
        t0 = time.time()
        for i in range(args.steps):
            state, m = trainer.train_step(
                state, jax.random.fold_in(jax.random.PRNGKey(99), i),
                stream.next_batch(i),
            )
        print(f"      expert {cid} [{obj}] loss {m['loss']:.4f} "
              f"({time.time()-t0:.1f}s)")
        save_checkpoint(
            os.path.join(args.out, f"expert{cid}.npz"), state.ema,
            metadata=expert_metadata(
                name=f"expert{cid}", objective=obj, schedule=sch,
                cluster_id=cid, arch=cfg.name, step=args.steps,
            ),
        )

    print("[3/4] training router (independent, all clusters) ...")
    rcfg = router_b2(num_clusters=K)
    rcfg = rcfg if args.full else rcfg.reduced(latent_size=latent)
    rtrainer = RouterTrainer(
        apply_fn=lambda p, x, t: D.apply(rcfg, p, x, t), num_clusters=K,
    )
    rstate = rtrainer.init_state(D.init(rcfg, jax.random.PRNGKey(777)))
    rstream = RouterDataStream(spec, cm, batch_size=args.batch)
    for i in range(args.steps):
        rstate, rm = rtrainer.train_step(
            rstate, jax.random.fold_in(jax.random.PRNGKey(55), i),
            rstream.next_batch(i),
        )
    print(f"      router acc {rm['acc']:.2f}")
    save_checkpoint(os.path.join(args.out, "router.npz"), rstate.params,
                    metadata={"num_clusters": K})

    print("[4/4] sampling with heterogeneous fusion ...")
    from repro.training import load_checkpoint
    experts, eparams = [], []
    for cid in range(K):
        p, meta = load_checkpoint(os.path.join(args.out,
                                               f"expert{cid}.npz"))
        experts.append(ExpertSpec(meta["name"], meta["objective"],
                                  meta["schedule"], apply_fn,
                                  meta["cluster_id"]))
        eparams.append(p)
    samples = sample_ensemble(
        jax.random.PRNGKey(1), experts, eparams,
        D.make_router_fn(rcfg, rstate.params),
        (64, latent, latent, 4),
        config=SamplerConfig(num_steps=12, cfg_scale=1.0,
                             strategy="topk", top_k=2),
    )
    fid = sample_fid(spec, np.asarray(samples))
    print(f"done: {samples.shape} samples, FID-proxy {fid:.3f}, "
          f"checkpoints in {args.out}")


if __name__ == "__main__":
    main()
