"""Decentralized expert training for the assigned LM architectures.

The DDM half of the paper's technique (isolated cluster experts + router
fusion, Eq. 1) applied to any ``--arch`` from the model zoo: two experts
train in complete isolation on disjoint synthetic corpus clusters, a
token-prototype router routes sequences, and next-token distributions are
fused in probability space.

  PYTHONPATH=src python examples/decentralized_lm_experts.py \
      --arch mamba2-2.7b
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core.lm_ensemble import (
    LMExpertEnsemble,
    TokenPrototypeRouter,
    expert_perplexity,
)
from repro.models import zoo
from repro.training import AdamWConfig, adamw_init
from repro.training.trainer import make_lm_train_step


def cluster_batch(key, batch, seq, vocab, cluster):
    half = vocab // 2
    lo = cluster * half
    toks = jax.random.randint(key, (batch, seq + 1), lo, lo + half)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(vocab_size=64)
    if cfg.arch_type in ("audio", "vlm"):
        print(f"note: {args.arch} needs frontend stubs; using tokens only "
              "via the dense path is unsupported here — pick a decoder "
              "arch for this demo.")
        return
    step = make_lm_train_step(cfg, AdamWConfig(learning_rate=3e-3,
                                               warmup_steps=2))
    experts = []
    print(f"training 2 isolated {args.arch} experts "
          f"(reduced: {cfg.num_layers}L d={cfg.d_model}) ...")
    for cid in range(2):
        params = zoo.init(cfg, jax.random.PRNGKey(cid))
        opt = adamw_init(params)
        for i in range(args.steps):
            key = jax.random.fold_in(jax.random.PRNGKey(10 + cid), i)
            params, opt, loss, _ = step(
                params, opt,
                cluster_batch(key, args.batch, args.seq, 64, cid),
            )
        print(f"  expert {cid} final loss {float(loss):.3f}")
        experts.append(params)

    corpora = [cluster_batch(jax.random.PRNGKey(99 + c), 8, 128, 64,
                             c)["tokens"] for c in range(2)]
    router = TokenPrototypeRouter.fit(corpora, vocab=64)
    ens = LMExpertEnsemble(cfg=cfg, expert_params=experts, router=router,
                           strategy="topk", top_k=1)
    for cid in range(2):
        b = cluster_batch(jax.random.PRNGKey(70 + cid), args.batch,
                          args.seq, 64, cid)
        print(f"cluster {cid}: right-expert ppl "
              f"{expert_perplexity(cfg, experts[cid], b['tokens'], b['labels']):7.2f}  "
              f"wrong-expert ppl "
              f"{expert_perplexity(cfg, experts[1-cid], b['tokens'], b['labels']):7.2f}  "
              f"routed-ensemble ppl {ens.perplexity(b['tokens'], b['labels']):7.2f}")


if __name__ == "__main__":
    main()
