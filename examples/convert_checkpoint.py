"""Checkpoint + objective conversion walkthrough (paper §2.6 / §8).

Demonstrates the three conversion mechanisms:

1. **Eq. 20** — pretrained ImageNet-DDPM DiT → text-conditioned FM expert
   (transfer blocks/embeddings, re-init final layer, fresh text stack).
2. **Eq. 21** — runtime timestep mapping round(999·t) into the pretrained
   discrete embedding table.
3. **Eqs. 22–25 + §8.3** — inference-time ε→velocity conversion with the
   numerical safeguards, verified against the analytic identity on the
   linear path (v = ε − x̂0).

  PYTHONPATH=src python examples/convert_checkpoint.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ConversionConfig,
    convert_checkpoint,
    eps_to_velocity,
    get_schedule,
    to_ddpm_timestep,
)
from repro.models import dit as D
from repro.models.config import dit_b2

key = jax.random.PRNGKey(0)

# --- 1) Eq. 20: architecture-level checkpoint conversion --------------------
print("=== Eq. 20: ImageNet-DDPM checkpoint -> text-conditioned FM expert")
src_cfg = dit_b2(use_text=False).reduced(latent_size=8)   # "ImageNet DiT"
dst_cfg = dit_b2().reduced(latent_size=8)                 # text-conditioned
pretrained = D.init(src_cfg, key)
template = D.init(dst_cfg, jax.random.PRNGKey(1))
params, report = convert_checkpoint(pretrained, template,
                                    rng=jax.random.PRNGKey(2))
for group, action in sorted(report.items()):
    print(f"  {group:18s} -> {action}")
x = jax.random.normal(key, (2, 8, 8, 4))
out = D.apply(dst_cfg, params, x, jnp.array([0.3, 0.8]))
print(f"  converted expert forward OK: {out.shape}, "
      f"finite={bool(jnp.isfinite(out).all())}")

# --- 2) Eq. 21: runtime timestep compatibility -------------------------------
print("\n=== Eq. 21: continuous FM time -> discrete DiT table index")
for t in (0.0, 0.123, 0.5, 1.0):
    print(f"  t={t:5.3f} -> t_DiT={int(to_ddpm_timestep(jnp.array([t]))[0])}")

# --- 3) ε→v conversion with safeguards ---------------------------------------
print("\n=== Eqs. 22–25: schedule-aware ε→velocity conversion")
lin, cos = get_schedule("linear"), get_schedule("cosine")
x0 = jnp.clip(jax.random.normal(key, (4, 8, 8, 4)), -3, 3)
eps = jax.random.normal(jax.random.PRNGKey(3), x0.shape)
t = jnp.array([0.2, 0.5, 0.8, 0.99])
for sch, name in ((lin, "linear"), (cos, "cosine")):
    xt = sch.perturb(x0, eps, t)
    v = eps_to_velocity(xt, eps, sch, t,
                        ConversionConfig(velocity_scaling="none"))
    if name == "linear":
        err = float(jnp.max(jnp.abs(v - (eps - x0))[:3]))
        print(f"  {name}: |v - (eps - x0)| = {err:.2e}  (Eq. 25 identity)")
    else:
        da, ds = sch.derivs(t)
        print(f"  {name}: velocity norms per t: "
              f"{[float(jnp.linalg.norm(v[i])) for i in range(4)]}")
print("  safeguards: alpha_safe=max(alpha,0.01), x0 clamp ±20, "
      "Eq. 31 dampening at t>0.85 (enable with velocity_scaling="
      "'piecewise')")
