"""Quickstart: heterogeneous decentralized diffusion in ~60 lines.

Trains TWO experts in complete isolation — one DDPM (ε-prediction, cosine
schedule), one Flow Matching (velocity, linear path) — on disjoint semantic
clusters, then samples with router-weighted fusion where the DDPM expert's
predictions are unified into velocity space by the schedule-aware
conversion (paper Fig. 2).  Runs in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.data import SyntheticSpec, fit_clusters, sample_fid
from repro.data.pipeline import ExpertDataStream, RouterDataStream
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2
from repro.training import AdamWConfig, ExpertTrainer, RouterTrainer

STEPS, BATCH, K = 40, 32, 2

# 1) cluster the corpus (stub-DINOv2 features + hierarchical k-means, §6.1)
spec = SyntheticSpec(num_categories=K, latent_size=8, separation=3.0)
clusters, _ = fit_clusters(spec, corpus_size=512, num_clusters=K, num_fine=64)

# 2) train experts with HETEROGENEOUS objectives, in complete isolation
cfg = dit_b2().reduced(latent_size=8)
apply_fn = D.make_expert_apply(cfg)
expert_params = []
for cid, (objective, schedule) in enumerate([("ddpm", "cosine"),
                                             ("fm", "linear")]):
    trainer = ExpertTrainer(
        apply_fn=apply_fn, objective=objective, schedule_name=schedule,
        opt=AdamWConfig(learning_rate=3e-4, warmup_steps=5), ema_decay=0.8,
    )
    state = trainer.init_state(D.init(cfg, jax.random.PRNGKey(cid)))
    stream = ExpertDataStream(spec, clusters, cluster_id=cid,
                              batch_size=BATCH, seed=cid)
    for i in range(STEPS):
        state, m = trainer.train_step(
            state, jax.random.fold_in(jax.random.PRNGKey(42), i),
            stream.next_batch(i),
        )
    print(f"expert {cid} ({objective}/{schedule}) final loss "
          f"{m['loss']:.4f}")
    expert_params.append(state.ema)

# 3) train the router (independently, on all clusters, §6.3)
rcfg = router_b2(num_clusters=K).reduced(latent_size=8)
rtrainer = RouterTrainer(apply_fn=lambda p, x, t: D.apply(rcfg, p, x, t),
                         num_clusters=K)
rstate = rtrainer.init_state(D.init(rcfg, jax.random.PRNGKey(9)))
rstream = RouterDataStream(spec, clusters, batch_size=BATCH)
for i in range(STEPS):
    rstate, rm = rtrainer.train_step(
        rstate, jax.random.fold_in(jax.random.PRNGKey(7), i),
        rstream.next_batch(i),
    )
print(f"router acc {rm['acc']:.2f}")

# 4) heterogeneous fusion sampling: ε→v conversion happens inside
experts = [ExpertSpec("ddpm-expert", "ddpm", "cosine", apply_fn, 0),
           ExpertSpec("fm-expert", "fm", "linear", apply_fn, 1)]
samples = sample_ensemble(
    jax.random.PRNGKey(0), experts, expert_params,
    D.make_router_fn(rcfg, rstate.params), (64, 8, 8, 4),
    config=SamplerConfig(num_steps=12, cfg_scale=1.0, strategy="topk",
                         top_k=2),
)
print(f"samples {samples.shape}, "
      f"FID-proxy {sample_fid(spec, np.asarray(samples)):.3f}, "
      f"finite={bool(np.isfinite(np.asarray(samples)).all())}")
