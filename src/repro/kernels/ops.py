"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the compiled Pallas kernels run natively;
elsewhere (this CPU container, unit tests) the same kernel bodies execute
under ``interpret=True``, and callers that need speed on CPU use the
pure-jnp reference paths in the model code.  ``use_pallas()`` is the single
switch, overridable via REPRO_FORCE_PALLAS=0/1.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.conversion import ConversionConfig, velocity_scale
from repro.core.schedules import Schedule
from repro.kernels import ref as _ref
from repro.kernels.adaln_fuse import adaln_fuse as _adaln_fuse
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.hetero_fuse import hetero_fuse as _hetero_fuse
from repro.kernels.hetero_fuse import hetero_fuse_coeffs as _hetero_fuse_coeffs
from repro.kernels.hetero_fuse import hetero_fuse_dequant as _hetero_fuse_dequant
from repro.kernels.hetero_fuse import hetero_fuse_step as _hetero_fuse_step
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan

Array = jax.Array


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas() -> bool:
    env = os.environ.get("REPRO_FORCE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "False")
    return on_tpu()


def _interpret() -> bool:
    return not on_tpu()


# --- flash attention -------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    """(B, H, S, D) attention.  Pallas on TPU, interpret elsewhere."""
    if use_pallas():
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=_interpret(), **kw)
    return _ref.ref_flash_attention(q, k, v, causal=causal, window=window)


def flash_attention_gqa(q, k, v, *, causal=True, window=0, **kw):
    """GQA front-end: q (B, Hq, S, D), k/v (B, Hkv, S, D)."""
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    return flash_attention(q, k, v, causal=causal, window=window, **kw)


# --- SSD scan ---------------------------------------------------------------


def ssd_scan(x, dt, A, B, C, *, chunk=128, **kw):
    """(B, H, S, P) Mamba2 scan.  Pallas on TPU, interpret elsewhere."""
    if use_pallas():
        return _ssd_scan(x, dt, A, B, C, chunk=chunk,
                         interpret=_interpret(), **kw)
    return _ref.ref_ssd_scan(
        jnp.swapaxes(x, 1, 2), jnp.swapaxes(dt, 1, 2), A, B, C
    )[0].swapaxes(1, 2), None


# --- AdaLN fuse --------------------------------------------------------------


def adaln_modulate(x, gamma, beta, *, eps=1e-6, **kw):
    if use_pallas():
        return _adaln_fuse(x, gamma, beta, eps=eps,
                           interpret=_interpret(), **kw)
    return _ref.ref_adaln_fuse(x, gamma, beta, eps=eps)


# --- hetero fuse -------------------------------------------------------------


def fused_velocity(
    preds: Array,             # (K, B, *latent) routed-slot native predictions
    x_t: Array,               # (B, *latent)
    weights: Array,           # (B, K) fusion weights
    coef: Array,              # (5, K, B) unified coefficient stack
    *,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Hot-path convert-and-fuse with precomputed unified coefficients.

    The serving engine precomputes ``conversion.unified_coeff_tables`` once
    per run and gathers the per-step ``(5, K, B)`` slice (per routed slot
    when execution is compute-sparse); this op then does the entire per-step
    fusion — ε→v conversion + Eq. 1 weighting — in one kernel launch
    (Pallas on TPU, oracle elsewhere).
    """
    k, b = preds.shape[0], preds.shape[1]
    latent_shape = preds.shape[2:]
    tsize = 1
    for s in latent_shape:
        tsize *= s
    pf = preds.reshape(k, b, tsize)
    xf = x_t.reshape(b, tsize)
    if use_pallas():
        out = _hetero_fuse_coeffs(
            pf, xf, weights, coef,
            clamp=clamp, alpha_min=alpha_min, interpret=_interpret(),
        )
    else:
        out = _ref.ref_hetero_fuse_coeffs(
            pf, xf, weights, coef, clamp=clamp, alpha_min=alpha_min,
        )
    return out.reshape((b,) + latent_shape)


#: hot-path kernel tile width — multiple of the 128-lane VPU width; rows
#: smaller than one tile pad up to the next 128 multiple instead.
_TILE_BLOCK = 1024


def _tile_pad(t: int) -> tuple[int, int]:
    """Padded row length and block size for a ``t``-wide kernel row.

    Shared padding policy of the row-major hot-path kernels
    (``fused_step``, ``dequant_params``): rows at most one block wide pad
    to the next 128-lane multiple and run as a single block; wider rows
    pad to a whole number of ``_TILE_BLOCK`` tiles.
    """
    if t <= _TILE_BLOCK:
        tp = -(-t // 128) * 128
        return tp, tp
    return -(-t // _TILE_BLOCK) * _TILE_BLOCK, _TILE_BLOCK


def fused_step(
    preds: Array,             # (K, G·B, *latent) per-branch slot predictions
    x_t: Array,               # (B, *latent) current latent
    weights: Array,           # (G·B, K) fusion weights
    coef: Array,              # (5, K, G·B) unified coefficient stack
    dt: Array,                # scalar or (B,) per-row Euler step size (traced)
    *,
    g: int,
    cfg_scale: float = 1.0,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Step-fused hot path: one kernel for convert + fuse + CFG + Euler.

    Takes the exact :func:`fused_velocity` operands — per-slot native
    predictions over the branch-major ``G·B`` guidance batch (branch 0 =
    cond, branch 1 = uncond), fusion weights, and the per-step ``(5, K,
    G·B)`` coefficient slice — plus the Euler ``dt``, and returns the
    *updated latent* ``x − u·dt`` where ``u`` is the CFG-combined fused
    velocity.  The latent is read once and written once per step instead
    of round-tripping through HBM for each of the three unfused ops.
    Non-tile-aligned latents pad up to the kernel tile width (padded
    rows are self-contained zeros and are sliced away).  Pallas
    (``hetero_fuse_step``) on TPU, oracle elsewhere — the oracle
    delegates to ``ref_hetero_fuse_coeffs``, keeping the fused step
    bit-identical to the unfused op chain on the reference path.

    ``dt`` is either the classic batch-shared scalar or a per-row
    ``(B,)`` vector (mixed-timestep rolling batches); a per-row dt whose
    entries equal the scalar is bitwise identical to the scalar form on
    both dispatch paths.
    """
    k = preds.shape[0]
    b = x_t.shape[0]
    latent_shape = x_t.shape[1:]
    tsize = 1
    for s in latent_shape:
        tsize *= s
    pf = preds.reshape(k, g, b, tsize)
    xf = x_t.reshape(b, tsize)
    wf = weights.reshape(g, b, k)
    cf = coef.reshape(5, k, g, b)
    dt = jnp.asarray(dt, jnp.float32).reshape(-1)
    assert dt.shape[0] in (1, b), dt.shape
    if use_pallas():
        t = tsize
        tp, block = _tile_pad(t)
        if tp != t:
            pad = ((0, 0), (0, 0), (0, 0), (0, tp - t))
            pf = jnp.pad(pf, pad)
            xf = jnp.pad(xf, ((0, 0), (0, tp - t)))
        out = _hetero_fuse_step(
            pf, xf, wf, cf, dt,
            cfg_scale=cfg_scale, clamp=clamp, alpha_min=alpha_min,
            block_t=block, interpret=_interpret(),
        )[:, :t]
    else:
        out = _ref.ref_hetero_fuse_step(
            pf, xf, wf, cf, dt,
            cfg_scale=cfg_scale, clamp=clamp, alpha_min=alpha_min,
        )
    return out.reshape((b,) + latent_shape)


def dequant_params(
    q: Array,                 # (R, ...) quantized leaf view (int8 / fp8)
    scale: Array,             # (R,) symmetric per-row scales
    *,
    out_dtype=jnp.float32,
) -> Array:
    """Fused ``scale · q`` dequantization of a gathered/sliced param leaf.

    The hot-path expansion step for ``core.param_store.QuantizedStore``:
    rows are whatever was gathered (per-sample experts, a static expert
    slice, or the full stack for off-path ``materialize``); trailing dims
    flatten into the kernel's tile axis and pad up to the tile width.
    Pallas (``hetero_fuse_dequant``) on TPU, oracle elsewhere.
    """
    q = jnp.asarray(q)
    rows = q.shape[0]
    trailing = q.shape[1:]
    qf = q.reshape(rows, -1) if trailing else q.reshape(rows, 1)
    t = qf.shape[1]
    if use_pallas():
        tp, block = _tile_pad(t)
        if tp != t:
            qf = jnp.pad(qf, ((0, 0), (0, tp - t)))
        out = _hetero_fuse_dequant(
            qf, scale, out_dtype=out_dtype, block_t=block,
            interpret=_interpret(),
        )[:, :t]
    else:
        out = _ref.ref_hetero_fuse_dequant(qf, scale, out_dtype=out_dtype)
    return out.reshape((rows,) + trailing)


def fused_convert_and_fuse(
    preds: Array,             # (K, B, *latent) native predictions
    x_t: Array,               # (B, *latent)
    weights: Array,           # (B, K)
    objectives: list[str],    # per-expert 'ddpm' | 'fm'
    schedules: list[Schedule],
    t: Array,                 # (B,) native time
    conv: ConversionConfig = ConversionConfig(),
) -> Array:
    """High-level entry: computes per-expert schedule coefficients on host
    trace, then runs the fused kernel (or its oracle) over flattened
    latents.  This is the per-step fusion op of Fig. 2."""
    k, b = preds.shape[0], preds.shape[1]
    latent_shape = preds.shape[2:]
    tsize = 1
    for s in latent_shape:
        tsize *= s

    alpha = jnp.stack([s.alpha(t) for s in schedules])        # (K, B)
    sigma = jnp.stack([s.sigma(t) for s in schedules])
    if conv.derivative_mode == "fd":
        d = [s.fd_derivs(t) for s in schedules]
    else:
        d = [s.derivs(t) for s in schedules]
    dalpha = jnp.stack([x[0] for x in d])
    dsigma = jnp.stack([x[1] for x in d])
    is_ddpm = jnp.array([o == "ddpm" for o in objectives])
    vs = velocity_scale(t, conv.velocity_scaling)             # (B,)
    vscale = jnp.where(is_ddpm[:, None], vs[None], 1.0)

    pf = preds.reshape(k, b, tsize)
    xf = x_t.reshape(b, tsize)
    args = (pf, xf, weights, is_ddpm, alpha, sigma, dalpha, dsigma, vscale)
    kwargs = dict(clamp=conv.clamp, alpha_min=conv.alpha_min)
    if use_pallas():
        out = _hetero_fuse(*args, interpret=_interpret(), **kwargs)
    else:
        out = _ref.ref_hetero_fuse(*args, **kwargs)
    return out.reshape((b,) + latent_shape)
