"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the compiled Pallas kernels run natively;
elsewhere (this CPU container, unit tests) the same kernel bodies execute
under ``interpret=True``, and callers that need speed on CPU use the
pure-jnp reference paths in the model code.  ``use_pallas()`` is the single
switch, overridable via REPRO_FORCE_PALLAS=0/1.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.conversion import ConversionConfig, velocity_scale
from repro.core.schedules import Schedule
from repro.kernels import ref as _ref
from repro.kernels.adaln_fuse import adaln_fuse as _adaln_fuse
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.hetero_fuse import hetero_fuse as _hetero_fuse
from repro.kernels.hetero_fuse import hetero_fuse_coeffs as _hetero_fuse_coeffs
from repro.kernels.hetero_fuse import hetero_fuse_dequant as _hetero_fuse_dequant
from repro.kernels.hetero_fuse import hetero_fuse_step as _hetero_fuse_step
from repro.kernels.ragged_gemm import ragged_gemm as _ragged_gemm
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan

Array = jax.Array


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas() -> bool:
    env = os.environ.get("REPRO_FORCE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "False")
    return on_tpu()


def _interpret() -> bool:
    return not on_tpu()


# --- flash attention -------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    """(B, H, S, D) attention.  Pallas on TPU, interpret elsewhere."""
    if use_pallas():
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=_interpret(), **kw)
    return _ref.ref_flash_attention(q, k, v, causal=causal, window=window)


def flash_attention_gqa(q, k, v, *, causal=True, window=0, **kw):
    """GQA front-end: q (B, Hq, S, D), k/v (B, Hkv, S, D)."""
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    return flash_attention(q, k, v, causal=causal, window=window, **kw)


# --- SSD scan ---------------------------------------------------------------


def ssd_scan(x, dt, A, B, C, *, chunk=128, **kw):
    """(B, H, S, P) Mamba2 scan.  Pallas on TPU, interpret elsewhere."""
    if use_pallas():
        return _ssd_scan(x, dt, A, B, C, chunk=chunk,
                         interpret=_interpret(), **kw)
    return _ref.ref_ssd_scan(
        jnp.swapaxes(x, 1, 2), jnp.swapaxes(dt, 1, 2), A, B, C
    )[0].swapaxes(1, 2), None


# --- AdaLN fuse --------------------------------------------------------------


def adaln_modulate(x, gamma, beta, *, eps=1e-6, **kw):
    if use_pallas():
        return _adaln_fuse(x, gamma, beta, eps=eps,
                           interpret=_interpret(), **kw)
    return _ref.ref_adaln_fuse(x, gamma, beta, eps=eps)


# --- hetero fuse -------------------------------------------------------------


def fused_velocity(
    preds: Array,             # (K, B, *latent) routed-slot native predictions
    x_t: Array,               # (B, *latent)
    weights: Array,           # (B, K) fusion weights
    coef: Array,              # (5, K, B) unified coefficient stack
    *,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Hot-path convert-and-fuse with precomputed unified coefficients.

    The serving engine precomputes ``conversion.unified_coeff_tables`` once
    per run and gathers the per-step ``(5, K, B)`` slice (per routed slot
    when execution is compute-sparse); this op then does the entire per-step
    fusion — ε→v conversion + Eq. 1 weighting — in one kernel launch
    (Pallas on TPU, oracle elsewhere).
    """
    k, b = preds.shape[0], preds.shape[1]
    latent_shape = preds.shape[2:]
    tsize = 1
    for s in latent_shape:
        tsize *= s
    pf = preds.reshape(k, b, tsize)
    xf = x_t.reshape(b, tsize)
    if use_pallas():
        out = _hetero_fuse_coeffs(
            pf, xf, weights, coef,
            clamp=clamp, alpha_min=alpha_min, interpret=_interpret(),
        )
    else:
        out = _ref.ref_hetero_fuse_coeffs(
            pf, xf, weights, coef, clamp=clamp, alpha_min=alpha_min,
        )
    return out.reshape((b,) + latent_shape)


#: hot-path kernel tile width — multiple of the 128-lane VPU width; rows
#: smaller than one tile pad up to the next 128 multiple instead.
_TILE_BLOCK = 1024


def _tile_pad(t: int) -> tuple[int, int]:
    """Padded row length and block size for a ``t``-wide kernel row.

    Shared padding policy of the row-major hot-path kernels
    (``fused_step``, ``dequant_params``): rows at most one block wide pad
    to the next 128-lane multiple and run as a single block; wider rows
    pad to a whole number of ``_TILE_BLOCK`` tiles.
    """
    if t <= _TILE_BLOCK:
        tp = -(-t // 128) * 128
        return tp, tp
    return -(-t // _TILE_BLOCK) * _TILE_BLOCK, _TILE_BLOCK


def fused_step(
    preds: Array,             # (K, G·B, *latent) per-branch slot predictions
    x_t: Array,               # (B, *latent) current latent
    weights: Array,           # (G·B, K) fusion weights
    coef: Array,              # (5, K, G·B) unified coefficient stack
    dt: Array,                # scalar or (B,) per-row Euler step size (traced)
    *,
    g: int,
    cfg_scale: float = 1.0,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Step-fused hot path: one kernel for convert + fuse + CFG + Euler.

    Takes the exact :func:`fused_velocity` operands — per-slot native
    predictions over the branch-major ``G·B`` guidance batch (branch 0 =
    cond, branch 1 = uncond), fusion weights, and the per-step ``(5, K,
    G·B)`` coefficient slice — plus the Euler ``dt``, and returns the
    *updated latent* ``x − u·dt`` where ``u`` is the CFG-combined fused
    velocity.  The latent is read once and written once per step instead
    of round-tripping through HBM for each of the three unfused ops.
    Non-tile-aligned latents pad up to the kernel tile width (padded
    rows are self-contained zeros and are sliced away).  Pallas
    (``hetero_fuse_step``) on TPU, oracle elsewhere — the oracle
    delegates to ``ref_hetero_fuse_coeffs``, keeping the fused step
    bit-identical to the unfused op chain on the reference path.

    ``dt`` is either the classic batch-shared scalar or a per-row
    ``(B,)`` vector (mixed-timestep rolling batches); a per-row dt whose
    entries equal the scalar is bitwise identical to the scalar form on
    both dispatch paths.
    """
    k = preds.shape[0]
    b = x_t.shape[0]
    latent_shape = x_t.shape[1:]
    tsize = 1
    for s in latent_shape:
        tsize *= s
    pf = preds.reshape(k, g, b, tsize)
    xf = x_t.reshape(b, tsize)
    wf = weights.reshape(g, b, k)
    cf = coef.reshape(5, k, g, b)
    dt = jnp.asarray(dt, jnp.float32).reshape(-1)
    assert dt.shape[0] in (1, b), dt.shape
    if use_pallas():
        t = tsize
        tp, block = _tile_pad(t)
        if tp != t:
            pad = ((0, 0), (0, 0), (0, 0), (0, tp - t))
            pf = jnp.pad(pf, pad)
            xf = jnp.pad(xf, ((0, 0), (0, tp - t)))
        out = _hetero_fuse_step(
            pf, xf, wf, cf, dt,
            cfg_scale=cfg_scale, clamp=clamp, alpha_min=alpha_min,
            block_t=block, interpret=_interpret(),
        )[:, :t]
    else:
        out = _ref.ref_hetero_fuse_step(
            pf, xf, wf, cf, dt,
            cfg_scale=cfg_scale, clamp=clamp, alpha_min=alpha_min,
        )
    return out.reshape((b,) + latent_shape)


def dequant_params(
    q: Array,                 # (R, ...) quantized leaf view (int8 / fp8)
    scale: Array,             # (R,) symmetric per-row scales
    *,
    out_dtype=jnp.float32,
) -> Array:
    """Fused ``scale · q`` dequantization of a gathered/sliced param leaf.

    The hot-path expansion step for ``core.param_store.QuantizedStore``:
    rows are whatever was gathered (per-sample experts, a static expert
    slice, or the full stack for off-path ``materialize``); trailing dims
    flatten into the kernel's tile axis and pad up to the tile width.
    Pallas (``hetero_fuse_dequant``) on TPU, oracle elsewhere.
    """
    q = jnp.asarray(q)
    rows = q.shape[0]
    trailing = q.shape[1:]
    qf = q.reshape(rows, -1) if trailing else q.reshape(rows, 1)
    t = qf.shape[1]
    if use_pallas():
        tp, block = _tile_pad(t)
        if tp != t:
            qf = jnp.pad(qf, ((0, 0), (0, tp - t)))
        out = _hetero_fuse_dequant(
            qf, scale, out_dtype=out_dtype, block_t=block,
            interpret=_interpret(),
        )[:, :t]
    else:
        out = _ref.ref_hetero_fuse_dequant(qf, scale, out_dtype=out_dtype)
    return out.reshape((rows,) + trailing)


#: max rows per ragged-GEMM tile — whole per-group row blocks halve down
#: to at most this many rows so tiles stay VMEM-friendly.
_RAGGED_BLOCK_M = 256


def ragged_block_m(m: int) -> int | None:
    """Row-tile size for a ragged GEMM whose row groups are ``m`` wide.

    Every tile must be single-expert, so the block must divide the
    per-group row count exactly; groups narrower than the 8-row TPU
    sublane (or with an odd factor that cannot halve under the cap)
    return ``None`` — the wrapper then takes the dense-math fallback.
    """
    if m <= 0 or m % 8:
        return None
    bm = m
    while bm > _RAGGED_BLOCK_M:
        if bm % 2:
            return None
        bm //= 2
    return bm


def ragged_expert_matmul(
    x: Array,                 # (P, ..., D) per-group activations
    w: Array,                 # (K, D, F) stacked expert weights (or quant)
    expert_ids: Array,        # (P,) int32 expert per row group
    *,
    bias: Array | None = None,       # (K, F) stacked bias, optional
    w_scale: Array | None = None,    # (K,) per-expert scales (quant only)
) -> Array:
    """Grouped expert dense: ``y[p] = x[p] @ w[expert_ids[p]] (+ bias)``.

    The executor-facing ragged GEMM seam (``dispatch='ragged'``): ``x``
    carries ``P`` expert-sorted row groups (one per routed sample×slot
    pair, each ``m = prod(middle dims)`` rows wide), and every group
    contracts against its own expert's stacked leaf — all experts in
    one op, empty segments costing nothing.

    On the Pallas path the groups flatten to ``(P·m, D)`` tile-aligned
    rows for :func:`repro.kernels.ragged_gemm.ragged_gemm` (output lanes
    pad via the shared ``_tile_pad`` policy and slice back); quantized
    weights (int8 / fp8, with ``w_scale``) keep their storage dtype all
    the way to the MXU — activations quantize per row symmetrically to
    the same storage format and the kernel fuses the
    ``x_scale·w_scale`` dequant epilogue.  Off-TPU (and for row groups
    too narrow to tile) the same contraction runs as dense jnp math:
    small groups take one all-experts GEMM plus a column select, wide
    groups a per-group gathered einsum; quantized leaves dequantize
    with the exact ``hetero_fuse_dequant`` float32 multiply first, so
    the fallback is bitwise-consistent with the grouped backend's
    store-dequant path.  Output is float32 ``(P, ..., F)``.
    """
    p = x.shape[0]
    d = x.shape[-1]
    mids = x.shape[1:-1]
    m = 1
    for s in mids:
        m *= s
    kx, dw, f = w.shape
    is_int8 = w.dtype == jnp.int8
    is_fp8 = w.dtype == jnp.float8_e4m3fn
    quantized = is_int8 or is_fp8
    if quantized and w_scale is None:
        raise ValueError("quantized ragged_expert_matmul needs w_scale")
    expert_ids = expert_ids.astype(jnp.int32)

    bm = ragged_block_m(m)
    if use_pallas() and bm is not None:
        xf = x.reshape(p * m, d)
        fp, bf = _tile_pad(f)
        wp = jnp.pad(w, ((0, 0), (0, 0), (0, fp - f))) if fp != f else w
        tile_e = jnp.repeat(expert_ids, m // bm)
        if quantized:
            x32 = xf.astype(jnp.float32)
            qmax = 127.0 if is_int8 else 448.0
            xs = jnp.maximum(jnp.max(jnp.abs(x32), axis=1), 1e-12) / qmax
            xq = x32 / xs[:, None]
            if is_int8:
                xq = jnp.clip(jnp.round(xq), -127, 127).astype(jnp.int8)
            else:
                xq = xq.astype(jnp.float8_e4m3fn)
            y = _ragged_gemm(xq, wp, tile_e, xs, w_scale,
                             block_m=bm, block_f=bf, interpret=_interpret())
        else:
            y = _ragged_gemm(xf, wp, tile_e, None, None,
                             block_m=bm, block_f=bf, interpret=_interpret())
        y = y[:, :f].reshape((p,) + mids + (f,))
    else:
        if quantized:
            wd = w.astype(jnp.float32) * w_scale.astype(jnp.float32).reshape(
                (kx,) + (1,) * (w.ndim - 1)
            )
        else:
            wd = w
        mtot = p * m
        if m <= 4:
            # few rows per group: one GEMM against every expert's leaf,
            # then select each group's expert column block.
            y_all = x.reshape(mtot, d) @ jnp.moveaxis(wd, 0, 1).reshape(
                d, kx * f
            )
            y_all = y_all.reshape(x.shape[:-1] + (kx, f))
            e = expert_ids.reshape((p,) + (1,) * (x.ndim - 1))
            y = jnp.take_along_axis(
                y_all,
                jnp.broadcast_to(e[..., None], y_all.shape[:-2] + (1, f)),
                axis=-2,
            )[..., 0, :]
        else:
            y = jnp.einsum("p...d,pdf->p...f", x, wd[expert_ids])
    if bias is not None:
        y = y + bias[expert_ids].reshape(
            (p,) + (1,) * (x.ndim - 2) + (-1,)
        )
    return y


def fused_convert_and_fuse(
    preds: Array,             # (K, B, *latent) native predictions
    x_t: Array,               # (B, *latent)
    weights: Array,           # (B, K)
    objectives: list[str],    # per-expert 'ddpm' | 'fm'
    schedules: list[Schedule],
    t: Array,                 # (B,) native time
    conv: ConversionConfig = ConversionConfig(),
) -> Array:
    """High-level entry: computes per-expert schedule coefficients on host
    trace, then runs the fused kernel (or its oracle) over flattened
    latents.  This is the per-step fusion op of Fig. 2."""
    k, b = preds.shape[0], preds.shape[1]
    latent_shape = preds.shape[2:]
    tsize = 1
    for s in latent_shape:
        tsize *= s

    alpha = jnp.stack([s.alpha(t) for s in schedules])        # (K, B)
    sigma = jnp.stack([s.sigma(t) for s in schedules])
    if conv.derivative_mode == "fd":
        d = [s.fd_derivs(t) for s in schedules]
    else:
        d = [s.derivs(t) for s in schedules]
    dalpha = jnp.stack([x[0] for x in d])
    dsigma = jnp.stack([x[1] for x in d])
    is_ddpm = jnp.array([o == "ddpm" for o in objectives])
    vs = velocity_scale(t, conv.velocity_scaling)             # (B,)
    vscale = jnp.where(is_ddpm[:, None], vs[None], 1.0)

    pf = preds.reshape(k, b, tsize)
    xf = x_t.reshape(b, tsize)
    args = (pf, xf, weights, is_ddpm, alpha, sigma, dalpha, dsigma, vscale)
    kwargs = dict(clamp=conv.clamp, alpha_min=conv.alpha_min)
    if use_pallas():
        out = _hetero_fuse(*args, interpret=_interpret(), **kwargs)
    else:
        out = _ref.ref_hetero_fuse(*args, **kwargs)
    return out.reshape((b,) + latent_shape)
