"""Flash attention Pallas TPU kernel.

TPU adaptation of the attention hot-spot: online-softmax tiling with
MXU-aligned blocks (q-block × kv-block, both multiples of 128 at full
scale), fp32 accumulators in VMEM scratch, causal/sliding-window masking
computed from block indices (whole kv-blocks beyond the causal frontier are
skipped by masking; the grid itself stays rectangular for simplicity).

Grid: (batch*heads, num_q_blocks, num_kv_blocks) with the kv axis
innermost ('arbitrary' semantics — the carry lives in scratch).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, window: int, scale: float,
    block_q: int, block_k: int, num_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (bk, d)
    logits = q @ k.T * scale                             # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (qpos - kpos < window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret",
                     "softmax_scale"),
)
def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    softmax_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """q/k/v: (B, H, S, D) -> (B, H, S, D).  MHA layout (GQA callers expand
    kv heads before the call; the serving engine dedups via the wrapper in
    ops.py)."""
    b, h, s, d = q.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq = s // block_q
    nk = s // block_k

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
