"""Ragged grouped expert GEMM with fused dequant (ROADMAP perf item 1).

One Pallas launch runs the block-diagonal matmul of every resident
expert's contiguous row segment against that expert's stacked weight
leaf — the Megablocks-style grouped-GEMM economy, applied to the
``DispatchPlan``'s expert-sorted row layout:

* the grid iterates over ``(row-tile, out-tile)`` pairs of the *actual*
  row count, so an expert with an empty segment (or a dead validity
  slot, which routing never selects) contributes **zero grid steps** —
  there is no per-expert branch, no power-of-two bucket padding;
* each row tile is single-expert by construction (the ``ops`` wrapper
  derives tiles from the plan's pair-major segments) and its expert id
  is scalar-prefetched, so the tile's weight block DMA reads the stacked
  leaf ``w[e]`` directly — no gather, no materialized per-row weights;
* quantized stores skip materialization entirely: int8 operands contract
  on the MXU with ``preferred_element_type=int32`` (fp8 with float32
  accumulation) and the ``hetero_fuse_dequant`` scale multiply is folded
  into the epilogue — ``acc · x_scale[row] · w_scale[e]`` — so
  quantization buys compute, not just resident bytes.

Tile geometry (``block_m`` rows × ``block_f`` output lanes, full-depth
contraction) is decided by the ``ops.ragged_expert_matmul`` wrapper from
the shared ``_tile_pad`` policy; this module never hard-codes lane
arithmetic.  ``debug=True`` adds a per-grid-step tile counter output so
tests can *measure* that empty segments cost zero tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _dense_body(e_ref, x_ref, w_ref, o_ref, *cnt):
    del e_ref                       # expert id consumed by the index map
    o_ref[...] = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if cnt:
        cnt[0][...] = jnp.ones_like(cnt[0])


def _quant_body(acc_dtype, e_ref, ws_ref, x_ref, xs_ref, w_ref, o_ref,
                *cnt):
    i = pl.program_id(0)
    acc = jax.lax.dot_general(
        x_ref[...], w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    e = e_ref[i]
    o_ref[...] = (
        acc.astype(jnp.float32)
        * xs_ref[...].astype(jnp.float32)
    ) * ws_ref[e].astype(jnp.float32)
    if cnt:
        cnt[0][...] = jnp.ones_like(cnt[0])


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_f", "interpret", "debug"),
)
def ragged_gemm(
    x: Array,                 # (M, D) expert-sorted rows (f32/bf16 or q)
    w: Array,                 # (K, D, F) stacked expert weights
    tile_experts: Array,      # (M // block_m,) int32 expert id per row tile
    x_scale: Array | None = None,   # (M,) per-row act scales (quant only)
    w_scale: Array | None = None,   # (K,) per-expert weight scales
    *,
    block_m: int,
    block_f: int,
    interpret: bool = False,
    debug: bool = False,
):
    """One-launch ragged grouped GEMM: ``y[r] = x[r] @ w[e(r)]``.

    Rows arrive expert-sorted and tile-aligned (every ``block_m`` row
    tile belongs to one expert — ``tile_experts[i]``); the grid is
    ``(M/block_m, F/block_f)`` so work scales with actual rows, never
    with the expert count.  Dense operands contract in float32.  int8
    operands contract as int8×int8→int32 and fp8 as fp8×fp8→f32 (MXU
    native), then the fused dequant epilogue applies
    ``x_scale[row] · w_scale[expert]``.  Output is float32 ``(M, F)``.

    ``debug=True`` returns ``(y, tiles)`` where ``tiles`` is an
    ``(M/block_m, F/block_f)`` int32 map with a 1 per executed grid
    step — the runtime proof that empty segments cost zero tiles.
    """
    m, d = x.shape
    k_cap, dw, f = w.shape
    if dw != d:
        raise ValueError(f"contraction mismatch: x depth {d}, w depth {dw}")
    if m % block_m or f % block_f:
        raise ValueError(
            f"rows/lanes must be tile-aligned: ({m}, {f}) vs "
            f"block ({block_m}, {block_f})"
        )
    gm, gf = m // block_m, f // block_f
    if tile_experts.shape != (gm,):
        raise ValueError(
            f"tile_experts must be ({gm},), got {tile_experts.shape}"
        )
    is_int8 = w.dtype == jnp.int8
    is_fp8 = w.dtype == jnp.float8_e4m3fn
    quantized = is_int8 or is_fp8

    out_shape = [jax.ShapeDtypeStruct((m, f), jnp.float32)]
    out_specs = [
        pl.BlockSpec((block_m, block_f), lambda i, j, *pf: (i, j))
    ]
    if debug:
        out_shape.append(jax.ShapeDtypeStruct((gm, gf), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1), lambda i, j, *pf: (i, j)))

    tile_experts = tile_experts.astype(jnp.int32)
    if quantized:
        if x.dtype != w.dtype:
            raise ValueError(
                f"quantized ragged GEMM needs matching operand storage "
                f"dtypes, got x={x.dtype} w={w.dtype}"
            )
        if x_scale is None or w_scale is None:
            raise ValueError("quantized ragged GEMM needs x_scale + w_scale")
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(gm, gf),
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, j, e, s: (i, 0)),
                pl.BlockSpec((block_m, 1), lambda i, j, e, s: (i, 0)),
                pl.BlockSpec((1, d, block_f),
                             lambda i, j, e, s: (e[i], 0, j)),
            ],
            out_specs=out_specs,
        )
        body = functools.partial(
            _quant_body, jnp.int32 if is_int8 else jnp.float32
        )
        out = pl.pallas_call(
            body, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(tile_experts, w_scale.astype(jnp.float32),
          x, x_scale.astype(jnp.float32).reshape(m, 1), w)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(gm, gf),
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, j, e: (i, 0)),
                pl.BlockSpec((1, d, block_f), lambda i, j, e: (e[i], 0, j)),
            ],
            out_specs=out_specs,
        )
        out = pl.pallas_call(
            _dense_body, grid_spec=grid_spec, out_shape=out_shape,
            interpret=interpret,
        )(tile_experts, x, w)
    return tuple(out) if debug else out[0]
