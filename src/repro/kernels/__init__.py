"""Pallas TPU kernels for the framework's compute hot-spots.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), oracle in
ref.py, jit'd public wrapper + backend dispatch in ops.py.
"""
