"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``<name>`` kernel in this package has a ``ref_<name>`` here with the
exact same signature; tests sweep shapes/dtypes and assert_allclose.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool = True,
    window: int = 0, softmax_scale: float | None = None,
) -> Array:
    """Oracle attention.  q/k/v: (B, H, S, D) (kernel layout)."""
    b, h, s, d = q.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (qpos - kpos < window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_ssd_scan(x: Array, dt: Array, A: Array, B: Array, C: Array):
    """Oracle SSD recurrence — delegates to the sequential reference.

    Matches the kernel's contract exactly: the Pallas ``ssd_scan`` always
    starts from a zero state, so the oracle takes no ``init_state``
    (resumable-state scans go through ``models.mamba2.ssd_sequential``
    directly).
    """
    from repro.models.mamba2 import ssd_sequential

    return ssd_sequential(x, dt, A, B, C)


def ref_adaln_fuse(
    x: Array, gamma: Array, beta: Array, eps: float = 1e-6
) -> Array:
    """Oracle for fused LN-modulate: LN(x)·(1+γ)+β (Eqs. 17/19 inner op).

    x: (B, S, D); gamma/beta: (B, D).
    """
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = y * (1.0 + gamma[:, None].astype(jnp.float32)) + beta[
        :, None
    ].astype(jnp.float32)
    return out.astype(x.dtype)


def ref_hetero_fuse(
    preds: Array,        # (K, B, T) native expert predictions (flattened)
    x_t: Array,          # (B, T)
    weights: Array,      # (B, K) router weights
    is_ddpm: Array,      # (K,) bool — needs ε→v conversion
    alpha: Array,        # (K, B) schedule coeff per expert/sample
    sigma: Array,        # (K, B)
    dalpha: Array,       # (K, B)
    dsigma: Array,       # (K, B)
    vscale: Array,       # (K, B) Eq. 31 dampening (1.0 for FM experts)
    *,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Oracle for the fused convert-and-fuse inference op (paper Fig. 2).

    For DDPM experts: x̂0 = clip((x_t - σ ε)/max(α, α_min)); v = α'x̂0 + σ'ε,
    scaled by vscale.  FM experts pass through.  Output: Σ_k w_k v_k.
    """
    K = preds.shape[0]
    a = jnp.maximum(alpha, alpha_min)[..., None]
    x0h = (x_t[None] - sigma[..., None] * preds) / a
    x0h = jnp.clip(x0h, -clamp, clamp)
    v_conv = (dalpha[..., None] * x0h + dsigma[..., None] * preds) * vscale[
        ..., None
    ]
    v = jnp.where(is_ddpm[:, None, None], v_conv, preds)
    w = jnp.moveaxis(weights, -1, 0)[..., None]            # (K, B, 1)
    return jnp.sum(w * v, axis=0)


def ref_hetero_fuse_dequant(
    q: Array,            # (R, T) quantized values (int8 / float8_e4m3fn)
    scale: Array,        # (R,) symmetric per-row scales
    *,
    out_dtype=jnp.float32,
) -> Array:
    """Oracle for the fused ``scale · q`` dequantization op.

    ``out_dtype`` mirrors the kernel's output-cast knob: the multiply
    always runs in float32, the cast is the last op — same as the Pallas
    path, so mixed-precision parity tests compare like against like.
    """
    out = q.astype(jnp.float32) * scale.astype(jnp.float32)[:, None]
    return out.astype(out_dtype)


def ref_hetero_fuse_step(
    preds: Array,        # (K, G, B, T) per-branch routed-slot predictions
    x_t: Array,          # (B, T)
    weights: Array,      # (G, B, K) fusion weights per guidance branch
    coef: Array,         # (5, K, G, B) unified coefficient stack
    dt: Array,           # (1,) shared or (B,) per-row Euler step size
    *,
    cfg_scale: float = 1.0,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Oracle for the step-fused convert+CFG+Euler hot-path op.

    Folds the whole per-step latent update into one op: per-branch
    convert-and-fuse (exactly :func:`ref_hetero_fuse_coeffs` over the
    branch-major flattened batch), the CFG combine
    ``u = u_u + s (u_c − u_u)`` (branch 0 = cond, branch 1 = uncond; a
    single branch skips the combine), and the Euler update
    ``x ← x − u·dt``.  Delegating the fuse to the coeffs oracle keeps
    this numerically identical to the unfused three-op path.

    ``dt`` may be the classic batch-shared ``(1,)`` scalar or a per-row
    ``(B,)`` vector (mixed-timestep rolling batches, where each request
    sits at its own step of the schedule grid); both forms broadcast
    elementwise over the latent row, so a ``(B,)`` dt whose entries all
    equal the scalar is bitwise identical to the scalar form.
    """
    k, g, b, t = preds.shape
    fused = ref_hetero_fuse_coeffs(
        preds.reshape(k, g * b, t),
        jnp.concatenate([x_t] * g, axis=0),
        weights.reshape(g * b, k),
        coef.reshape(5, k, g * b),
        clamp=clamp, alpha_min=alpha_min,
    )                                                      # (G·B, T)
    if g == 1:
        u = fused
    else:
        u = fused[b:] + cfg_scale * (fused[:b] - fused[b:])
    return x_t - u * jnp.asarray(dt, jnp.float32).reshape(-1, 1)


def ref_ragged_gemm(
    x: Array,                 # (M, D) expert-sorted rows
    w: Array,                 # (K, D, F) stacked expert weights
    tile_experts: Array,      # (M // block_m,) int32 expert id per row tile
    x_scale: Array | None = None,   # (M,) per-row activation scales
    w_scale: Array | None = None,   # (K,) per-expert weight scales
) -> Array:
    """Oracle for the ragged grouped expert GEMM with fused dequant.

    ``tile_experts`` carries one expert id per ``block_m`` row tile; the
    oracle recovers the per-row expert map by even division (the kernel
    wrapper guarantees tile-aligned single-expert row groups).  Dense
    operands contract in float32.  Quantized operands mirror the
    kernel's MXU contract exactly: int8×int8 accumulates in int32 (bit-
    exact integers) and fp8×fp8 in float32, then the dequant epilogue
    applies ``x_scale[row] · w_scale[expert]`` in float32 — the same
    multiply order as the kernel, so the int8 path is bitwise
    comparable.  Output is float32 ``(M, F)``.
    """
    m = x.shape[0]
    gm = tile_experts.shape[0]
    bm = m // gm
    row_e = jnp.repeat(tile_experts.astype(jnp.int32), bm)
    wr = w[row_e]                                          # (M, D, F)
    if w.dtype == jnp.int8:
        acc = jnp.einsum(
            "md,mdf->mf", x.astype(jnp.int32), wr.astype(jnp.int32)
        )
    else:
        acc = jnp.einsum(
            "md,mdf->mf", x.astype(jnp.float32), wr.astype(jnp.float32),
        )
    out = acc.astype(jnp.float32)
    if x_scale is not None and w_scale is not None:
        out = (out * x_scale.astype(jnp.float32)[:, None]) \
            * w_scale.astype(jnp.float32)[row_e][:, None]
    return out


def ref_hetero_fuse_coeffs(
    preds: Array,        # (K, B, T) native predictions of the routed slots
    x_t: Array,          # (B, T)
    weights: Array,      # (B, K) fusion weights
    coef: Array,         # (5, K, B) unified coefficient stack
    *,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
) -> Array:
    """Oracle for the coefficient-folded convert-and-fuse hot-path op.

    FM slots carry the identity coefficients (1, 0, 0, 1, 1), under which
    ``v = 0·x̂0 + 1·pred`` — exact pass-through without a flag select.
    """
    coef = coef.astype(jnp.float32)
    alpha, sigma, dalpha, dsigma, vscale = (
        coef[0], coef[1], coef[2], coef[3], coef[4]
    )                                                      # each (K, B)
    a = jnp.maximum(alpha, alpha_min)[..., None]
    x0h = (x_t[None] - sigma[..., None] * preds) / a
    x0h = jnp.clip(x0h, -clamp, clamp)
    v = (dalpha[..., None] * x0h + dsigma[..., None] * preds) * vscale[
        ..., None
    ]
    w = jnp.moveaxis(weights, -1, 0)[..., None]            # (K, B, 1)
    return jnp.sum(w * v, axis=0)
