"""Fused heterogeneous convert-and-fuse Pallas TPU kernel (paper Fig. 2).

The paper's core inference op: for each sampling step, every expert's
native prediction is unified into velocity space (ε→v conversion, Eqs.
23–24 with §8.3 safeguards) and combined with router weights (Eq. 1).

Done naively this is K reads + K writes of a latent-sized tensor per step;
the fused kernel reads the K stacked predictions once, applies the
per-expert schedule coefficients (scalar per expert×sample, broadcast from
a (K, B) operand), and writes only the fused velocity.

Grid: (B, T/block_t); the expert axis K is kept whole inside the block
(K ≤ 8 in the paper).

Four entry points share the module's dispatch policy:

* :func:`hetero_fuse` — per-expert objective flags + raw schedule coeffs
  (the original dense-ensemble signature);
* :func:`hetero_fuse_coeffs` — the serving hot path: a single ``(5, K, B)``
  coefficient stack with FM experts already folded to the identity
  coefficients ``(1, 0, 0, 1, 1)`` (see ``conversion.unified_coeff_tables``),
  so the kernel needs no flag select and the K axis can hold *routed slots*
  (per-sample gathered experts) instead of the full ensemble;
* :func:`hetero_fuse_step` — the step-fused hot path: the coeffs kernel
  with the CFG combine ``u_u + s (u_c − u_u)`` (over a leading guidance
  branch axis) and the Euler update ``x ← x − u·dt`` folded in, so one
  sampling step costs one latent read + one latent write instead of the
  three round-trips of ``fused_velocity`` → ``cfg_combine`` → ``x − u·dt``;
* :func:`hetero_fuse_dequant` — the quantized-expert companion on the same
  hot path: expands an int8/fp8 gathered/sliced param view to compute
  precision by applying the symmetric per-row ``scale · q`` inline
  (``core.param_store.QuantizedStore``).  One kernel launch per leaf
  replaces the ``astype`` + broadcast-multiply HLO pair, and because it
  runs on the *gathered* slice, the stacked quantized leaves never
  round-trip through HBM at full precision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _fuse_kernel(
    preds_ref, xt_ref, w_ref, flags_ref, coef_ref, o_ref,
    *, clamp: float, alpha_min: float,
):
    preds = preds_ref[:, 0].astype(jnp.float32)       # (K, bt)
    xt = xt_ref[0].astype(jnp.float32)                # (bt,)
    w = w_ref[0].astype(jnp.float32)                  # (K,)
    flags = flags_ref[...].astype(jnp.float32)        # (K,) 1.0 = ddpm
    coef = coef_ref[:, :, 0].astype(jnp.float32)      # (5, K)
    alpha, sigma, dalpha, dsigma, vscale = (
        coef[0], coef[1], coef[2], coef[3], coef[4]
    )

    a_safe = jnp.maximum(alpha, alpha_min)[:, None]
    x0h = (xt[None] - sigma[:, None] * preds) / a_safe
    x0h = jnp.clip(x0h, -clamp, clamp)
    v_conv = (dalpha[:, None] * x0h + dsigma[:, None] * preds) \
        * vscale[:, None]
    v = flags[:, None] * v_conv + (1.0 - flags[:, None]) * preds
    fused = jnp.sum(w[:, None] * v, axis=0)           # (bt,)
    o_ref[0] = fused.astype(o_ref.dtype)


def _fuse_coeffs_kernel(
    preds_ref, xt_ref, w_ref, coef_ref, o_ref,
    *, clamp: float, alpha_min: float,
):
    preds = preds_ref[:, 0].astype(jnp.float32)       # (K, bt)
    xt = xt_ref[0].astype(jnp.float32)                # (bt,)
    w = w_ref[0].astype(jnp.float32)                  # (K,)
    coef = coef_ref[:, :, 0].astype(jnp.float32)      # (5, K)
    alpha, sigma, dalpha, dsigma, vscale = (
        coef[0], coef[1], coef[2], coef[3], coef[4]
    )

    a_safe = jnp.maximum(alpha, alpha_min)[:, None]
    x0h = (xt[None] - sigma[:, None] * preds) / a_safe
    x0h = jnp.clip(x0h, -clamp, clamp)
    v = (dalpha[:, None] * x0h + dsigma[:, None] * preds) * vscale[:, None]
    fused = jnp.sum(w[:, None] * v, axis=0)           # (bt,)
    o_ref[0] = fused.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("clamp", "alpha_min", "block_t", "interpret")
)
def hetero_fuse_coeffs(
    preds: Array,     # (K, B, T) native predictions of the routed slots
    x_t: Array,       # (B, T)
    weights: Array,   # (B, K) fusion weights (rows sum to 1)
    coef: Array,      # (5, K, B) unified (alpha, sigma, dalpha, dsigma, vscale)
    *,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
    block_t: int = 1024,
    interpret: bool = False,
) -> Array:
    k, b, t = preds.shape
    block_t = min(block_t, t)
    assert t % block_t == 0
    kernel = functools.partial(
        _fuse_coeffs_kernel, clamp=clamp, alpha_min=alpha_min
    )
    return pl.pallas_call(
        kernel,
        grid=(b, t // block_t),
        in_specs=[
            pl.BlockSpec((k, 1, block_t), lambda bi, ti: (0, bi, ti)),
            pl.BlockSpec((1, block_t), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((1, k), lambda bi, ti: (bi, 0)),
            pl.BlockSpec((5, k, 1), lambda bi, ti: (0, 0, bi)),
        ],
        out_specs=pl.BlockSpec((1, block_t), lambda bi, ti: (bi, ti)),
        out_shape=jax.ShapeDtypeStruct((b, t), preds.dtype),
        interpret=interpret,
    )(preds, x_t, weights, coef.astype(jnp.float32))


def _fuse_step_kernel(
    preds_ref, xt_ref, w_ref, coef_ref, dt_ref, o_ref,
    *, cfg_scale: float, clamp: float, alpha_min: float,
):
    preds = preds_ref[:, :, 0].astype(jnp.float32)    # (K, G, bt)
    xt = xt_ref[0].astype(jnp.float32)                # (bt,)
    w = w_ref[:, 0].astype(jnp.float32)               # (G, K)
    coef = coef_ref[:, :, :, 0].astype(jnp.float32)   # (5, K, G)
    dt = dt_ref[0].astype(jnp.float32)
    g = preds.shape[1]
    alpha, sigma, dalpha, dsigma, vscale = (
        coef[0], coef[1], coef[2], coef[3], coef[4]
    )                                                 # each (K, G)

    a_safe = jnp.maximum(alpha, alpha_min)[:, :, None]
    x0h = (xt[None, None] - sigma[:, :, None] * preds) / a_safe
    x0h = jnp.clip(x0h, -clamp, clamp)
    v = (dalpha[:, :, None] * x0h + dsigma[:, :, None] * preds) \
        * vscale[:, :, None]
    wk = jnp.swapaxes(w, 0, 1)[:, :, None]            # (K, G, 1)
    fused = jnp.sum(wk * v, axis=0)                   # (G, bt)
    if g == 1:
        u = fused[0]
    else:
        # branch 0 = cond, branch 1 = uncond: u_u + s (u_c − u_u)
        u = fused[1] + cfg_scale * (fused[0] - fused[1])
    o_ref[0] = (xt - u * dt).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg_scale", "clamp", "alpha_min", "block_t",
                     "interpret"),
)
def hetero_fuse_step(
    preds: Array,     # (K, G, B, T) per-branch routed-slot predictions
    x_t: Array,       # (B, T) current latent
    weights: Array,   # (G, B, K) fusion weights per guidance branch
    coef: Array,      # (5, K, G, B) unified coefficient stack
    dt: Array,        # (1,) shared or (B,) per-row Euler step size (traced)
    *,
    cfg_scale: float = 1.0,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
    block_t: int = 1024,
    interpret: bool = False,
) -> Array:
    """Step-fused serving hot path: convert + fuse + CFG + Euler in one
    kernel launch.

    Extends :func:`hetero_fuse_coeffs` by folding the classifier-free
    guidance combine across the ``G`` branch axis (branch 0 = cond,
    branch 1 = uncond; ``G = 1`` skips it) and the Euler update
    ``x ← x − u·dt`` into the same kernel, so per sampling step the
    latent is read once and the updated latent written once — instead of
    the three latent-sized HBM round-trips of the unfused
    ``fused_velocity → cfg_combine → x − u·dt`` op chain.

    ``dt`` is either the classic batch-shared ``(1,)`` step size or a
    per-row ``(B,)`` vector — the mixed-timestep rolling-batch case,
    where each resident request sits at its own step of the schedule
    grid.  Only the BlockSpec index map differs (grid step ``bi`` reads
    row ``bi`` instead of row 0); the kernel body is identical, so the
    per-row form is bitwise equal to the scalar form whenever the rows
    agree.
    """
    k, g, b, t = preds.shape
    block_t = min(block_t, t)
    assert t % block_t == 0
    assert dt.shape[0] in (1, b), dt.shape
    dt_spec = (
        pl.BlockSpec((1,), lambda bi, ti: (bi,))
        if dt.shape[0] == b
        else pl.BlockSpec((1,), lambda bi, ti: (0,))
    )
    kernel = functools.partial(
        _fuse_step_kernel,
        cfg_scale=cfg_scale, clamp=clamp, alpha_min=alpha_min,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, t // block_t),
        in_specs=[
            pl.BlockSpec((k, g, 1, block_t), lambda bi, ti: (0, 0, bi, ti)),
            pl.BlockSpec((1, block_t), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((g, 1, k), lambda bi, ti: (0, bi, 0)),
            pl.BlockSpec((5, k, g, 1), lambda bi, ti: (0, 0, 0, bi)),
            dt_spec,
        ],
        out_specs=pl.BlockSpec((1, block_t), lambda bi, ti: (bi, ti)),
        out_shape=jax.ShapeDtypeStruct((b, t), x_t.dtype),
        interpret=interpret,
    )(preds, x_t, weights, coef.astype(jnp.float32), dt)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)                  # (bt,)
    s = s_ref[0].astype(jnp.float32)                  # per-row scale
    o_ref[0] = (q * s).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "block_t", "interpret")
)
def hetero_fuse_dequant(
    q: Array,         # (R, T) quantized values (int8 / float8_e4m3fn)
    scale: Array,     # (R,) symmetric per-row scales
    *,
    out_dtype=jnp.float32,
    block_t: int = 1024,
    interpret: bool = False,
) -> Array:
    """Fused ``scale · q`` dequantization of a row-major quantized view.

    Rows are whatever the caller gathered: ``B`` per-sample experts, one
    static expert slice, or the full ``K`` stack (off-hot-path
    materialize).  The scale broadcast happens inside the kernel, so the
    quantized bytes are read once and only the compute-precision result
    is written.
    """
    r, t = q.shape
    block_t = min(block_t, t)
    assert t % block_t == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(r, t // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t), lambda ri, ti: (ri, ti)),
            pl.BlockSpec((1,), lambda ri, ti: (ri,)),
        ],
        out_specs=pl.BlockSpec((1, block_t), lambda ri, ti: (ri, ti)),
        out_shape=jax.ShapeDtypeStruct((r, t), out_dtype),
        interpret=interpret,
    )(q, scale)


@functools.partial(
    jax.jit, static_argnames=("clamp", "alpha_min", "block_t", "interpret")
)
def hetero_fuse(
    preds: Array,     # (K, B, T) native expert predictions
    x_t: Array,       # (B, T)
    weights: Array,   # (B, K) router weights
    is_ddpm: Array,   # (K,) bool
    alpha: Array,     # (K, B)
    sigma: Array,     # (K, B)
    dalpha: Array,    # (K, B)
    dsigma: Array,    # (K, B)
    vscale: Array,    # (K, B)
    *,
    clamp: float = 20.0,
    alpha_min: float = 0.01,
    block_t: int = 1024,
    interpret: bool = False,
) -> Array:
    k, b, t = preds.shape
    block_t = min(block_t, t)
    assert t % block_t == 0
    coef = jnp.stack(
        [alpha, sigma, dalpha, dsigma, vscale], axis=0
    ).astype(jnp.float32)                             # (5, K, B)
    kernel = functools.partial(
        _fuse_kernel, clamp=clamp, alpha_min=alpha_min
    )
    return pl.pallas_call(
        kernel,
        grid=(b, t // block_t),
        in_specs=[
            pl.BlockSpec((k, 1, block_t), lambda bi, ti: (0, bi, ti)),
            pl.BlockSpec((1, block_t), lambda bi, ti: (bi, ti)),
            pl.BlockSpec((1, k), lambda bi, ti: (bi, 0)),
            pl.BlockSpec((k,), lambda bi, ti: (0,)),
            pl.BlockSpec((5, k, 1), lambda bi, ti: (0, 0, bi)),
        ],
        out_specs=pl.BlockSpec((1, block_t), lambda bi, ti: (bi, ti)),
        out_shape=jax.ShapeDtypeStruct((b, t), preds.dtype),
        interpret=interpret,
    )(preds, x_t, weights, is_ddpm, coef)
