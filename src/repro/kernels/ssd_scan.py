"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the Mamba2 GPU kernel: instead of a warp-level scan, the
chunk recurrence is phrased as MXU matmuls (intra-chunk (q×q) masked
score matmul + inter-chunk state carry), with the running state held in a
VMEM scratch across the chunk axis of the grid (innermost, 'arbitrary'
semantics).

Grid: (batch, head_blocks, num_chunks).
Blocks: x (1, hb, q, P), dt (1, hb, q), B/C (1, q, N) shared across heads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,
    y_ref, state_out_ref,
    state_scr,
    *, chunk: int, num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)           # (hb, q, P)
    dt = dt_ref[0].astype(jnp.float32)         # (hb, q)
    A = a_ref[...].astype(jnp.float32)         # (hb,)
    B = b_ref[0].astype(jnp.float32)           # (q, N)
    C = c_ref[0].astype(jnp.float32)           # (q, N)
    state = state_scr[...]                     # (hb, P, N)

    a = dt * A[:, None]                        # (hb, q) log-decay
    cum = jnp.cumsum(a, axis=1)
    seg = cum[:, :, None] - cum[:, None, :]    # (hb, q, q)
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril[None], jnp.exp(seg), 0.0)

    CB = C @ B.T                               # (q, q)
    scores = CB[None] * L                      # (hb, q, q)
    xdt = x * dt[..., None]                    # (hb, q, P)
    y_intra = jax.lax.dot_general(
        scores, xdt,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
    )                                          # (hb, q, P)

    # inter-chunk: y[h,i,p] += exp(cum[h,i]) * sum_n C[i,n] state[h,p,n]
    cs = jax.lax.dot_general(
        state, C,
        dimension_numbers=(((2,), (1,)), ((), ())),
    )                                          # (hb, P, q)
    y_inter = jnp.swapaxes(cs, 1, 2) * jnp.exp(cum)[..., None]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(sum a) S + sum_j decay_end_j dt_j x_j ⊗ B_j
    decay_end = jnp.exp(cum[:, -1:] - cum)     # (hb, q)
    w = dt * decay_end                         # (hb, q)
    upd = jax.lax.dot_general(
        jnp.swapaxes(x * w[..., None], 1, 2),  # (hb, P, q)
        B,                                     # (q, N)
        dimension_numbers=(((2,), (0,)), ((), ())),
    )                                          # (hb, P, N)
    state_scr[...] = jnp.exp(cum[:, -1])[:, None, None] * state + upd

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        state_out_ref[0] = state_scr[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "head_block", "interpret")
)
def ssd_scan(
    x: Array,       # (B, H, S, P)
    dt: Array,      # (B, H, S)
    A: Array,       # (H,)
    B: Array,       # (B, S, N)
    C: Array,       # (B, S, N)
    *,
    chunk: int = 128,
    head_block: int = 8,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """Returns (y (B, H, S, P), final_state (B, H, P, N))."""
    b, h, s, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    head_block = min(head_block, h)
    assert s % chunk == 0 and h % head_block == 0
    nc = s // chunk
    nh = h // head_block

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, head_block, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, head_block, chunk),
                         lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((head_block,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, head_block, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, head_block, p, n),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((head_block, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, state
