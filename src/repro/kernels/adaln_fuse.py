"""Fused AdaLN-Single modulation Pallas TPU kernel (paper Eqs. 17/19).

Computes ``LN(x) ⊙ (1 + γ) + β`` in one VMEM pass — the pointwise hot-spot
of the paper's AdaLN-Single architecture, executed 2× per block per step.
LN statistics and modulation are fused so x is read from HBM exactly once.

Grid: (B, S/block_s); the full feature dim lives in VMEM (d ≤ 1152 for
DiT-XL ⇒ block_s×d ≤ 256×1152 fp32 ≈ 1.2 MB, well inside VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _adaln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)            # (block_s, d)
    g = g_ref[0].astype(jnp.float32)            # (d,)
    b = b_ref[0].astype(jnp.float32)            # (d,)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    y = xc * jax.lax.rsqrt(var + eps)
    o_ref[0] = (y * (1.0 + g)[None] + b[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "eps", "interpret"))
def adaln_fuse(
    x: Array,          # (B, S, D)
    gamma: Array,      # (B, D)
    beta: Array,       # (B, D)
    *,
    block_s: int = 256,
    eps: float = 1e-6,
    interpret: bool = False,
) -> Array:
    b, s, d = x.shape
    block_s = min(block_s, s)
    assert s % block_s == 0
    kernel = functools.partial(_adaln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(b, s // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, d), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, d), lambda bi, si: (bi, 0)),
            pl.BlockSpec((1, d), lambda bi, si: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, d), lambda bi, si: (bi, si, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)
