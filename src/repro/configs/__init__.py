"""Architecture config registry (``--arch <id>``).

10 assigned architectures from the public pool + the paper's own DiT
experts.  Every assigned config cites its source in ``CONFIG.source``.
"""

from __future__ import annotations

import importlib

from repro.models.config import DiTConfig, LMConfig, dit_b2, dit_xl2, router_b2
from repro.configs.shapes import SHAPES, InputShape, get_shape

_ARCH_MODULES = {
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "stablelm-1.6b": "repro.configs.stablelm_1p6b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
}

ARCH_IDS: tuple[str, ...] = tuple(_ARCH_MODULES)

# Paper's own diffusion-expert architectures.
DIT_CONFIGS = {
    "dit-xl2": dit_xl2,
    "dit-b2": dit_b2,
    "router-b2": router_b2,
}


def get_config(arch: str) -> LMConfig:
    if arch not in _ARCH_MODULES:
        raise ValueError(
            f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}"
        )
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_dit_config(name: str, **kw) -> DiTConfig:
    return DIT_CONFIGS[name](**kw)


def all_configs() -> dict[str, LMConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "SHAPES", "InputShape", "get_shape",
    "get_config", "get_dit_config", "all_configs",
]
