"""stablelm-1.6b — dense, MHA (kv=heads) [hf:stabilityai/stablelm-2-1_6b]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    decode_window=8192,        # long_500k SWA decode variant only
    remat=True,
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    logits_chunk=512,
    source="hf:stabilityai/stablelm-2-1_6b",
)
