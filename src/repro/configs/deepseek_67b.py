"""deepseek-67b — dense llama-arch, GQA [arXiv:2401.02954]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="deepseek-67b",
    arch_type="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    decode_window=8192,        # long_500k SWA decode variant only
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    remat=True,
    fsdp_params=True,
    logits_chunk=512,
    source="arXiv:2401.02954",
)
