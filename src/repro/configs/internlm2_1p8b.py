"""internlm2-1.8b — dense, GQA [arXiv:2403.17297]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    decode_window=8192,        # long_500k SWA decode variant only
    remat=True,
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    logits_chunk=512,
    source="arXiv:2403.17297",
)
