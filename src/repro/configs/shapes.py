"""Assigned input shapes (public pool) and their lowered entry points.

  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> serve_prefill
  decode_32k   seq_len=32768   global_batch=128   -> serve_step (1 new token,
                                                     KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> serve_step, sub-quadratic
                                                     variants only (DESIGN.md)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError as e:
        raise ValueError(
            f"unknown input shape {name!r}; available: {sorted(SHAPES)}"
        ) from e
