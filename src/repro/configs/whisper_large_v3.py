"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

The mel+conv frontend is a STUB: precomputed frame embeddings
(B, 1500, 1280) feed the encoder (DESIGN.md carve-out).
"""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,             # decoder layers
    num_encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    decode_window=8192,        # long_500k SWA decoder variant only
    remat=True,
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    logits_chunk=512,
    source="arXiv:2212.04356",
)
