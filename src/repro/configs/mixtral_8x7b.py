"""mixtral-8x7b — MoE 8 experts top-2, SWA [arXiv:2401.04088]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    num_experts_per_tok=2,
    moe_capacity_factor=1.25,
    moe_impl="dense_scan",   # GSPMD-clean baseline; dispatch is a §Perf lever
    sliding_window=4096,       # native SWA -> long_500k runs natively
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    remat=True,
    fsdp_params=True,
    logits_chunk=512,
    source="arXiv:2401.04088",
)
