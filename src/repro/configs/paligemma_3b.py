"""paligemma-3b — VLM: SigLIP prefix + gemma decoder [arXiv:2407.07726].

The SigLIP vision tower is a STUB: precomputed patch embeddings
(B, 256, 2048) form the bidirectional prefix (DESIGN.md carve-out).
"""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="paligemma-3b",
    arch_type="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    vision_prefix_len=256,
    decode_window=8192,        # long_500k SWA decode variant only
    remat=True,
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    logits_chunk=256,          # 257k vocab -> chunked CE
    source="arXiv:2407.07726",
)
