"""mamba2-2.7b — attention-free SSM, SSD (state-space duality)
[arXiv:2405.21060]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,               # attention-free
    num_kv_heads=0,
    d_ff=0,                    # no MLP; mixer IS the block
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,            # d_inner 5120 -> 80 SSD heads
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    remat=True,
    logits_chunk=512,
    source="arXiv:2405.21060",
)
