"""zamba2-2.7b — hybrid: Mamba2 trunk + shared attention blocks
[arXiv:2411.15242]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,                # shared attention block's MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    attn_every=6,              # 9 applications of the shared block
    decode_window=8192,        # shared attn uses SWA for long_500k
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    remat=True,
    logits_chunk=512,
    source="arXiv:2411.15242",
)
