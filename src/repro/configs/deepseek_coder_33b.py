"""deepseek-coder-33b — dense llama-arch, GQA [arXiv:2401.14196]."""

import jax.numpy as jnp

from repro.models.config import LMConfig

CONFIG = LMConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100000.0,
    # long_500k runs only under the documented sliding-window decode
    # variant (DESIGN.md §Arch-applicability); window-less otherwise.
    decode_window=8192,
    param_dtype=jnp.bfloat16,
    activation_dtype=jnp.bfloat16,
    remat=True,
    fsdp_params=True,
    logits_chunk=512,
    source="arXiv:2401.14196",
)
