"""Continuous batching scheduler: a rolling mixed-timestep batch.

``ServingEngine.flush()`` is *lockstep*: requests coalesce into one
batch that enters and leaves the sampler together, so the batch runs
below capacity whenever requests arrive staggered — a late request waits
a full ``num_steps`` dispatch.  :class:`ContinuousScheduler` keeps the
batch **rolling** instead (vLLM-style): every tick advances all resident
rows one Euler step via ``core.sampling.sample_ensemble_step``, with
each row at its *own* ``t_idx``; requests join at the next step boundary
as soon as a row frees, finished rows are sliced out and resolved
immediately, and the compiled step program never retraces on churn
(capacity-stable shapes, per-row tables as gathers).

Layering:

* **admission control** — requests queue FIFO (by ``PendingRequest.seq``,
  the engine's global submission counter); a request is admitted when its
  shape bucket has ``batch_size`` free rows.  Queue depth is bounded:
  ``submit`` raises :class:`QueueBackpressure` past ``max_queue_depth``
  (callers shed load instead of growing an unbounded host queue), and a
  request wider than a bucket (``batch_size > max_resident``) is rejected
  outright as unschedulable.
* **shape bucketing** — buckets are keyed by the conditioning signature
  (text present + trailing text shape) and, on an elastic engine, the
  membership epoch the request was admitted under.  Each bucket owns one
  :class:`~repro.serving.batch.RollingBatch` of fixed ``max_resident``
  capacity, so every tick reuses one compiled program per bucket
  whatever joins or leaves.
* **state machine** — ``PendingRequest.state`` walks QUEUED → RESIDENT →
  DONE, or → FAILED after ``engine.max_request_requeues`` automatic
  re-queues (same policy as ``flush``); a failing bucket re-queues its
  residents in **seq order**.
* **snapshot semantics** — a bucket pins its admission-time membership
  tuple, so hot add/evict during flight cannot change in-flight outputs
  (epoch-keyed buckets compose with PR 6's elastic membership: a new
  epoch simply opens a new bucket while the old one drains).
* **observability** — ``metrics`` (``repro.serving.metrics``) records
  queue-wait and end-to-end latency per request in seconds and scheduler
  steps; each tick folds the percentile snapshot into
  ``engine.stats`` (``latency_p50_s`` …) and :meth:`line` renders the
  one-line summary the serve CLI prints.
* **resilience hooks** — three overridable no-op seams
  (:meth:`_admission_blocked`, :meth:`_on_admit`,
  :meth:`_accept_result`) let ``repro.serving.resilience``'s
  :class:`~repro.serving.resilience.ResilientScheduler` layer request
  deadlines, step watchdogs, expert circuit breakers, and a
  crash-recoverable journal on top of this class without forking the
  tick loop.

Bitwise parity: a row admitted at tick ``n`` sees exactly the step
sequence a dedicated ``generate`` call with its key would run (row
independence — see ``sample_ensemble_step``), proven in
``tests/test_continuous.py``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sample_ensemble_step
from repro.launch.sharding import (
    expert_param_shardings,
    rolling_state_shardings,
)
from repro.serving.batch import RollingBatch, draw_noise
from repro.serving.metrics import LatencyRecorder, RequestTiming


class AdmissionError(RuntimeError):
    """A request the admission controller can never schedule."""


class QueueBackpressure(AdmissionError):
    """Queue depth hit ``max_queue_depth`` — shed load and retry later."""


class ContinuousScheduler:
    """Rolling mixed-timestep scheduler over a ``ServingEngine``.

    Construction validates the engine against the rolling hot path's
    restrictions (routed engine, per-sample strategy, step-fused) so
    misconfiguration fails at build time, not at the first tick.

    ``clock`` is injectable for deterministic latency tests.
    """

    def __init__(
        self,
        engine,
        *,
        max_resident: int = 8,
        max_queue_depth: int = 256,
        steps_per_tick: int = 1,
        clock=time.perf_counter,
    ) -> None:
        if max_resident < 1:
            raise ValueError(f"max_resident must be >= 1, got {max_resident}")
        if steps_per_tick < 1:
            raise ValueError(
                f"steps_per_tick must be >= 1, got {steps_per_tick}"
            )
        cfg = engine.sampler
        if cfg.strategy not in ("top1", "topk"):
            raise ValueError(
                f"continuous batching requires per-sample routing "
                f"(strategy 'top1' or 'topk'); got {cfg.strategy!r}"
            )
        if not cfg.step_fused:
            raise ValueError(
                "continuous batching runs on the step-fused hot path "
                "only; construct the engine with step_fused=True"
            )
        if engine.engine not in ("auto", "routed"):
            raise ValueError(
                f"continuous batching requires the routed engine; got "
                f"engine={engine.engine!r}"
            )
        if engine.param_store is None or len(engine.experts) <= 1:
            raise ValueError(
                "continuous batching needs a homogeneous ensemble of "
                ">= 2 experts (stacked param store)"
            )
        self.engine = engine
        self.max_resident = max_resident
        self.max_queue_depth = max_queue_depth
        #: Euler steps each compiled tick advances in ONE launch (an
        #: in-program ``lax.scan`` over the identical fused-step body).
        #: Joins/leaves still happen at step boundaries — a tick
        #: boundary IS a step boundary — but admission granularity
        #: coarsens to every ``steps_per_tick`` steps.  On hosts where
        #: a compiled launch has a large fixed cost (CPU: ~10 ms per
        #: launch vs ~2 ms per in-scan step), this amortizes the launch
        #: the same way the lockstep scan does; rows that finish
        #: mid-tick freeze at the sentinel inside the launch, so the
        #: math is unchanged.
        self.steps_per_tick = steps_per_tick
        self.clock = clock
        self.metrics = LatencyRecorder()
        self.step_count = 0
        K = len(engine.experts)
        self.k_slots = 1 if cfg.strategy == "top1" else min(cfg.top_k, K)
        self._queue: list = []                       # QUEUED, seq order
        self._buckets: dict[tuple, RollingBatch] = {}
        self._timings: dict[int, RequestTiming] = {}

    # -- submission ---------------------------------------------------------

    def submit(self, key, text_emb=None, batch_size: int | None = None):
        """Enqueue a request; returns the engine's ``PendingRequest``.

        Noise derives from the request's own key at admission, so the
        resolved samples are bitwise what ``generate`` with that key
        would produce.  Raises :class:`QueueBackpressure` when the host
        queue is full and :class:`AdmissionError` when ``batch_size``
        exceeds ``max_resident`` (it could never fit a bucket).
        """
        from repro.launch.serve import PendingRequest

        eng = self.engine
        if batch_size is None:
            batch_size = text_emb.shape[0] if text_emb is not None else 1
        if text_emb is not None and text_emb.shape[0] != batch_size:
            raise ValueError(
                f"text_emb batch {text_emb.shape[0]} != batch_size "
                f"{batch_size}"
            )
        if batch_size > self.max_resident:
            raise AdmissionError(
                f"batch_size {batch_size} > max_resident "
                f"{self.max_resident}: the request can never fit a "
                f"rolling bucket — split it or raise max_resident"
            )
        if len(self._queue) >= self.max_queue_depth:
            raise QueueBackpressure(
                f"scheduler queue is full ({self.max_queue_depth} "
                f"requests waiting); retry after step() drains it"
            )
        req = PendingRequest(
            key=key, text_emb=eng._cached_cond(text_emb),
            batch_size=batch_size, _membership=eng._membership(),
        )
        req.seq = eng._next_seq()
        self._timings[req.seq] = RequestTiming(
            submit_t=self.clock(), submit_step=self.step_count
        )
        self._queue.append(req)
        eng.stats["requests"] += 1
        return req

    # -- scheduling tick ----------------------------------------------------

    def step(self) -> int:
        """One scheduler tick: admit → advance every bucket one Euler
        step → resolve finished requests.  Returns the number resolved."""
        self.step_count += 1
        self._admit()
        for sig, bucket in list(self._buckets.items()):
            if bucket.num_resident == 0:
                continue
            try:
                self._advance(bucket)
            except Exception as e:          # noqa: BLE001 — isolate bucket
                self._fail_bucket(sig, bucket, e)
        resolved = self._collect()
        self._gc_buckets()
        self.engine.stats.update(self.metrics.snapshot())
        self.engine.stats["scheduler_steps"] = self.step_count
        return resolved

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Tick until queue and buckets are empty; returns total
        resolved.  ``max_steps`` bounds a livelocked loop loudly."""
        total = 0
        while self._queue or self.num_resident:
            if self.step_count >= max_steps:
                raise RuntimeError(
                    f"scheduler not idle after {max_steps} steps: "
                    f"queued={len(self._queue)} "
                    f"resident={self.num_resident}"
                )
            total += self.step()
        return total

    # -- introspection ------------------------------------------------------

    @property
    def num_resident(self) -> int:
        return sum(b.num_resident for b in self._buckets.values())

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def max_pending_wait_steps(self) -> int:
        """Steps the oldest still-queued request has waited (0 if none);
        the liveness signal ``analysis.sanitize.check_scheduler_liveness``
        bounds."""
        waits = [
            self.step_count - self._timings[r.seq].submit_step
            for r in self._queue
        ]
        return max(waits, default=0)

    def line(self) -> str:
        """One-line scheduler summary (the serve CLI prints it).

        Percentile fields are absent from the snapshot until the first
        request resolves (empty-window percentiles are None, not 0.0 —
        see ``metrics.percentile``), so the line degrades to "-" rather
        than printing garbage or raising on a cold scheduler."""
        s = self.metrics.snapshot()

        def f(key, scale=1.0, fmt=".0f"):
            v = s.get(key)
            return "-" if v is None else format(v * scale, fmt)

        return (
            f"scheduler: step={self.step_count} "
            f"resident={self.num_resident}/{self.max_resident} "
            f"queued={len(self._queue)} "
            f"done={self.metrics.completed} "
            f"({s['throughput_img_s']:.1f} img/s) "
            f"wait p50={f('queue_wait_p50_steps')} "
            f"p95={f('queue_wait_p95_steps')} steps "
            f"e2e p50={f('latency_p50_s', 1e3)} "
            f"p95={f('latency_p95_s', 1e3)} ms"
        )

    # -- internals ----------------------------------------------------------

    def _sig(self, req) -> tuple:
        has_text = req.text_emb is not None
        tail = tuple(req.text_emb.shape[1:]) if has_text else ()
        epoch = req._membership[0] if req._membership is not None else -1
        return (has_text, tail, epoch)

    def _admit(self) -> None:
        """FIFO admission with per-bucket head-of-line blocking: a
        request that doesn't fit blocks later requests of the SAME
        bucket (fairness within a shape class) but not other buckets."""
        eng = self.engine
        blocked: set[tuple] = set()
        rest: list = []
        for req in self._queue:
            sig = self._sig(req)
            if sig in blocked or self._admission_blocked(sig):
                rest.append(req)
                continue
            bucket = self._buckets.get(sig)
            if bucket is None:
                bucket = self._make_bucket(sig, req)
                self._buckets[sig] = bucket
            if bucket.free_count() < req.batch_size:
                blocked.add(sig)
                rest.append(req)
                continue
            noise = draw_noise(
                req.key, (req.batch_size,) + eng.latent_shape
            )
            bucket.admit(req, noise)
            req.state = "RESIDENT"
            tm = self._timings[req.seq]
            tm.admit_t = self.clock()
            tm.admit_step = self.step_count
            # Deterministic refresh-work accounting, mirroring
            # _count_plan_refreshes: each admitted request refreshes its
            # routing slots ceil(S/R) times over its life.
            r = max(1, eng.sampler.plan_refresh_every)
            eng.stats["plan_refreshes"] += -(-eng.sampler.num_steps // r)
            self._on_admit(req, bucket)
        self._queue = rest

    # -- resilience hooks (no-ops here; ResilientScheduler overrides) -------

    def _admission_blocked(self, sig: tuple) -> bool:
        """Extra per-bucket admission gate (e.g. retry backoff windows)."""
        return False

    def _on_admit(self, req, bucket: RollingBatch) -> None:
        """Called once per admitted request (e.g. journal the admit)."""

    def _accept_result(self, bucket: RollingBatch, req, out, rows) -> bool:
        """Vet a finished request's latents before it resolves DONE.

        ``rows`` are the bucket rows the request occupied (already
        released).  Return False to veto: the hook owns the terminal
        state + bookkeeping and ``_collect`` skips the DONE path."""
        return True

    def _make_bucket(self, sig: tuple, req) -> RollingBatch:
        has_text, tail, _epoch = sig
        return RollingBatch(
            capacity=self.max_resident,
            latent_shape=self.engine.latent_shape,
            k_slots=self.k_slots,
            num_steps=self.engine.sampler.num_steps,
            text_tail=tail if has_text else None,
            membership=req._membership,
        )

    def _advance(self, bucket: RollingBatch) -> None:
        eng = self.engine
        has_text = bucket.text is not None
        fn = self._get_rolling_compiled(has_text, bucket.text_tail)
        text = bucket.text if has_text \
            else jnp.zeros((0,), jnp.float32)            # static filler
        if eng.elastic:
            _, store, tables, cmap = bucket.membership
            eng._note_degraded(store, steps=1)
            out = fn(bucket.x, bucket.t_idx, bucket.slot_idx,
                     bucket.slot_w, text, store, tables, cmap)
        else:
            out = fn(bucket.x, bucket.t_idx, bucket.slot_idx,
                     bucket.slot_w, text)
        bucket.x, bucket.t_idx, bucket.slot_idx, bucket.slot_w = out
        bucket.advance_host(self.steps_per_tick)

    def _get_rolling_compiled(self, has_text: bool, text_tail):
        """Jitted rolling step, cached in the engine's compiled-fn cache
        (one trace per bucket shape — ``stats['traces']`` counts it,
        same contract ``assert_no_retrace`` audits)."""
        eng = self.engine
        key = ("rolling", self.max_resident, self.steps_per_tick,
               eng.latent_shape, eng.sampler, eng.engine, has_text,
               text_tail)
        fn = eng._compiled.get(key)
        if fn is not None:
            return fn
        B = self.max_resident
        shape = (B,) + eng.latent_shape
        latent_sharding = None
        plan_sharding = None
        jit_kwargs: dict = {}
        if eng.mesh is not None:
            from repro.launch.sharding import dispatch_plan_sharding

            latent_sharding, row_state = rolling_state_shardings(
                eng.mesh, shape
            )
            plan_sharding = dispatch_plan_sharding(eng.mesh)
            lat_spec = latent_sharding.spec
            batch_sharded = len(lat_spec) > 0 and lat_spec[0] is not None
            text_spec = P("data") if (has_text and batch_sharded) else P()
            in_shardings = [
                latent_sharding,                      # x
                row_state,                            # t_idx
                row_state,                            # slot_idx
                row_state,                            # slot_w
                NamedSharding(eng.mesh, text_spec),   # text
            ]
            if eng.elastic:
                in_shardings += [
                    expert_param_shardings(
                        eng.param_store, eng.mesh,
                        logical_axes=eng.param_store.logical_axes(),
                    ),                                # membership store
                    NamedSharding(eng.mesh, P()),     # coeff tables
                    NamedSharding(eng.mesh, P()),     # cluster map
                ]
            jit_kwargs["in_shardings"] = tuple(in_shardings)

        spt = self.steps_per_tick

        def _tick(one_step, x, t_idx, slot_idx, slot_w):
            """Advance ``steps_per_tick`` fused steps in one launch.

            ``spt == 1`` calls the step body directly (the canonical
            single-step program the parity suite pins down);
            ``spt > 1`` runs the identical body under ``lax.scan``.
            The barrier between iterations is load-bearing for bitwise
            parity: XLA fully unrolls short constant-trip loops and
            would then fuse/reassociate arithmetic ACROSS the step
            boundary (ulp drift vs separate launches); pinning each
            iteration's outputs restores launch-boundary semantics
            while keeping the launch-cost amortization."""
            if spt == 1:
                return one_step((x, t_idx, slot_idx, slot_w))

            def body(carry, _):
                return jax.lax.optimization_barrier(one_step(carry)), None

            carry, _ = jax.lax.scan(
                body, (x, t_idx, slot_idx, slot_w), None, length=spt
            )
            return carry

        if eng.elastic:
            def _step(x, t_idx, slot_idx, slot_w, text, store, tables,
                      cmap):
                eng.stats["traces"] += 1   # runs at trace time only
                cond = {"text_emb": text} if has_text else None
                null = {"text_emb": None} if has_text else None

                def one_step(carry):
                    x, t_idx, slot_idx, slot_w = carry
                    return sample_ensemble_step(
                        eng.experts, eng.expert_params, eng.router_fn,
                        x, t_idx, slot_idx, slot_w,
                        cond=cond, null_cond=null, config=eng.sampler,
                        engine=eng.engine, stacked_params=store,
                        latent_sharding=latent_sharding,
                        plan_sharding=plan_sharding,
                        coeff_tables=tables, cluster_map=cmap,
                    )

                return _tick(one_step, x, t_idx, slot_idx, slot_w)
        else:
            def _step(x, t_idx, slot_idx, slot_w, text):
                eng.stats["traces"] += 1   # runs at trace time only
                cond = {"text_emb": text} if has_text else None
                null = {"text_emb": None} if has_text else None

                def one_step(carry):
                    x, t_idx, slot_idx, slot_w = carry
                    return sample_ensemble_step(
                        eng.experts, eng.expert_params, eng.router_fn,
                        x, t_idx, slot_idx, slot_w,
                        cond=cond, null_cond=null, config=eng.sampler,
                        engine=eng.engine,
                        stacked_params=eng.param_store,
                        latent_sharding=latent_sharding,
                        plan_sharding=plan_sharding,
                    )

                return _tick(one_step, x, t_idx, slot_idx, slot_w)

        # The latent buffer is donated (aliased into the step output);
        # row state is tiny and kept undonated for host re-inspection.
        donate = () if jax.default_backend() == "cpu" else (0,)
        fn = jax.jit(_step, donate_argnums=donate, **jit_kwargs)
        eng._compiled[key] = fn
        return fn

    def _collect(self) -> int:
        """Resolve every request whose rows all reached the grid end."""
        resolved = 0
        for bucket in self._buckets.values():
            if bucket.num_resident == 0:
                continue
            # Pure host computation (t_host mirror): completion never
            # forces a device sync, so ticks pipeline asynchronously and
            # only result() materialization blocks.
            for req in bucket.finished_requests():
                rows = bucket.rows_of(req.seq)
                out = bucket.resolve(req)
                if not self._accept_result(bucket, req, out, rows):
                    continue
                req._result = out
                req.done = True
                req.state = "DONE"
                tm = self._timings.pop(req.seq)
                now = self.clock()
                self.metrics.observe(
                    queue_wait_s=tm.admit_t - tm.submit_t,
                    e2e_s=now - tm.submit_t,
                    queue_wait_steps=tm.admit_step - tm.submit_step,
                    e2e_steps=self.step_count - tm.submit_step,
                    images=req.batch_size,
                    now=now,
                )
                resolved += 1
        return resolved

    def _fail_bucket(self, sig: tuple, bucket: RollingBatch, e) -> None:
        """Isolate a failing bucket: release + re-queue its residents in
        seq (submission) order, FAILED past the re-queue budget; the
        bucket itself is dropped (its buffers may be poisoned)."""
        eng = self.engine
        for req in bucket.resident_requests():
            bucket.release(req)
            req.requeues += 1
            if req.requeues > eng.max_request_requeues:
                req.state = "FAILED"
                req.error = e
                eng.stats["failed_requests"] += 1
                self._timings.pop(req.seq, None)
            else:
                req.state = "QUEUED"
                eng.stats["request_requeues"] += 1
                self._queue.append(req)
        self._queue.sort(key=lambda r: r.seq)
        del self._buckets[sig]

    def _gc_buckets(self) -> None:
        """Drop drained stale-epoch buckets; complete DRAINING slots
        (retire_expert) once nothing in flight references them."""
        eng = self.engine
        if not eng.elastic:
            return
        for sig in [
            s for s, b in self._buckets.items()
            if b.num_resident == 0 and s[2] != eng.membership_epoch
        ]:
            del self._buckets[sig]
        if not self._queue and self.num_resident == 0:
            for i, h in enumerate(eng.expert_health):
                if h == "DRAINING":
                    eng.expert_health[i] = "EVICTED"
