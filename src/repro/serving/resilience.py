"""Serving resilience layer: deadlines, watchdogs, breakers, journal.

The rolling scheduler (``serving.scheduler``) keeps traffic moving when
everything works; this module keeps it moving when things break, in the
decentralized deployment the paper assumes (loosely-coupled experts,
unreliable contributors, crash-prone hosts):

* **request deadlines** — ``submit(..., deadline_s=, max_steps=)``
  bounds a request's lifetime in wall-clock seconds and/or scheduler
  ticks.  Expiry is enforced at tick boundaries (queued and resident
  requests alike): the request lands in the DEADLINE_EXCEEDED terminal
  state and ``result()`` raises :class:`DeadlineExceeded` carrying the
  request id and requeue count.
* **step watchdog** — a wall-clock budget around each bucket's compiled
  launch (host-side timing only; never a device sync inside a trace).
  A tick that blows the budget fails only the offending bucket, whose
  residents re-queue under the engine's ``max_request_requeues`` cap,
  and the bucket's signature enters a bounded exponential-backoff
  window (jitter from the scheduler's threaded ``numpy`` Generator)
  before re-admission.
* **expert circuit breakers** — per-slot rolling fault scores fed by
  NaN/Inf escapes (attributed to the routed slots via the resolved
  rows' ``slot_idx``) and failed/slow bucket dispatches.  A slot whose
  score crosses the threshold trips into the PR 6 health machine's new
  ``PROBATION`` state via exactly the ``quarantine_expert`` masking
  path (validity-bit flip + epoch bump — no retrace), then synthetic
  single-sample canary requests probe it on a backoff schedule and
  auto-restore it on a finite pass.
* **crash-recoverable journal** — an append-only ``journal.jsonl`` of
  submit/admit/tick/resolve/failed/deadline/trip/restore records plus
  periodic per-request row-state snapshots.  Event records derive from
  host state only (the ``t_host`` mirror, request bookkeeping); the
  snapshot cadence is the single place latents are read back.
  :meth:`ResilientScheduler.restore` re-admits in-flight requests at
  their last snapshot, bitwise-identical to an uninterrupted run from
  that step (row independence + capacity-stable shapes; proven in
  ``tests/test_resilience.py`` and ``launch/chaos.py``).

Clock discipline: everything times through the scheduler's injectable
``clock`` so deadline/watchdog behavior is deterministic under a fake
clock in tests.  Backoff jitter and canary keys come from explicitly
seeded generators (``ResiliencePolicy.seed``), never ambient RNG.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fusion_weights, routed_slots
from repro.core.sampling import _time_grid
from repro.serving.batch import RollingBatch, _take_rows, draw_noise
from repro.serving.metrics import RequestTiming
from repro.serving.scheduler import ContinuousScheduler


# --------------------------------------------------------------------------
# Named terminal errors
# --------------------------------------------------------------------------


class RequestError(RuntimeError):
    """Base for per-request terminal errors; carries the request id
    (``seq``) and how many automatic re-queues it burned."""

    def __init__(self, message: str, *, seq: int = -1,
                 requeues: int = 0) -> None:
        super().__init__(message)
        self.seq = seq
        self.requeues = requeues


class RequestFailed(RequestError):
    """Terminal FAILED: the request exhausted its re-queue budget (or
    produced non-finite latents past recovery)."""


class DeadlineExceeded(RequestError):
    """Terminal DEADLINE_EXCEEDED: the request outlived its
    ``deadline_s``/``max_steps`` bound before resolving."""


class RequestTimeout(RequestError):
    """``result(timeout=...)`` gave up waiting — the request is still
    in flight (nobody ticked the scheduler / flushed the engine)."""


class TickBudgetExceeded(RuntimeError):
    """Watchdog: one bucket's compiled launch exceeded the tick budget."""


class JournalRestoreError(RuntimeError):
    """The journal cannot be replayed onto this engine (missing
    snapshot payloads, or membership diverged from the recorded mask)."""


# --------------------------------------------------------------------------
# Policy
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Tuning knobs for :class:`ResilientScheduler` (all host-side)."""

    #: wall-clock budget per bucket launch; None disables the watchdog.
    tick_budget_s: float | None = None
    #: failed-bucket re-admission backoff: base * 2^(attempt-1) ticks,
    #: capped, plus up to ``retry_jitter`` fraction of jitter.
    retry_base_ticks: int = 1
    retry_max_ticks: int = 32
    retry_jitter: float = 0.25
    #: breaker: trip a slot when its rolling fault score crosses the
    #: threshold; scores decay multiplicatively every tick.
    breaker_threshold: float = 2.0
    breaker_decay: float = 0.8
    #: fault weights: one NaN/Inf escape trips immediately (2.0 >=
    #: threshold); dispatch failures need two in quick succession.
    nonfinite_fault: float = 2.0
    dispatch_fault: float = 1.0
    #: canary probe schedule for PROBATION slots (ticks, doubling).
    probe_base_ticks: int = 2
    probe_max_ticks: int = 64
    #: finiteness-check resolved latents on the host at resolution time
    #: (one np read per resolved request — the resilience tax; the base
    #: scheduler stays sync-free).
    check_numerics: bool = True
    #: journal snapshot cadence in ticks (1 = every step boundary).
    snapshot_every: int = 1
    #: seeds the backoff-jitter Generator and the canary key.
    seed: int = 0


# --------------------------------------------------------------------------
# Circuit breaker
# --------------------------------------------------------------------------


class CircuitBreaker:
    """Per-expert-slot rolling fault scores + probation bookkeeping.

    Pure host state: ``record_fault`` bumps scores and returns the
    slots that just crossed the trip threshold; ``decay`` ages every
    score once per tick (time-based decay needs no per-success device
    read of the routing buffers).
    """

    def __init__(self, policy: ResiliencePolicy,
                 rng: np.random.Generator) -> None:
        self.policy = policy
        self.rng = rng
        self.scores: dict[int, float] = {}
        #: slot -> {"next": tick, "backoff": ticks, "probes": n}
        self.probation: dict[int, dict] = {}

    def record_fault(self, slots, weight: float) -> list[int]:
        tripped = []
        for s in slots:
            s = int(s)
            self.scores[s] = self.scores.get(s, 0.0) + weight
            if (self.scores[s] >= self.policy.breaker_threshold
                    and s not in self.probation):
                tripped.append(s)
        return tripped

    def decay(self) -> None:
        for s in list(self.scores):
            self.scores[s] *= self.policy.breaker_decay
            if self.scores[s] < 1e-3:
                del self.scores[s]

    def start_probation(self, slot: int, tick: int) -> None:
        b = self.policy.probe_base_ticks
        self.probation[slot] = {"next": tick + b, "backoff": b,
                                "probes": 0}

    def due_probes(self, tick: int) -> list[int]:
        return sorted(s for s, p in self.probation.items()
                      if tick >= p["next"])

    def probe_failed(self, slot: int, tick: int) -> None:
        p = self.probation[slot]
        p["probes"] += 1
        p["backoff"] = min(p["backoff"] * 2, self.policy.probe_max_ticks)
        p["next"] = tick + p["backoff"] + int(self.rng.integers(0, 2))

    def end_probation(self, slot: int) -> None:
        self.probation.pop(slot, None)
        self.scores.pop(slot, None)


# --------------------------------------------------------------------------
# Crash-recovery journal
# --------------------------------------------------------------------------


class RequestJournal:
    """Append-only on-disk journal (format spec in docs/resilience.md).

    Layout under ``journal_dir``::

        journal.jsonl       one JSON record per line, append-only
        req_<seq>.npz       submit payload (key/text/bounds), atomic
        snap_<tick>.npz     per-request row state + meta, atomic

    Event records are built from host state only (``t_host`` mirror,
    request bookkeeping) so per-tick journaling never syncs the device;
    the submit payload materializes the (tiny) key/conditioning arrays
    once per submit, and the snapshot cadence is the one place resident
    latents are read back.  ``.npz`` payloads write to a temp file and
    ``os.replace`` into place so a crash mid-write never leaves a
    half-readable artifact (the jsonl tail may be torn — the reader
    drops an undecodable last line).
    """

    def __init__(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        self.path = path
        self._f = open(os.path.join(path, "journal.jsonl"), "a",
                       buffering=1)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def event(self, ev: str, **fields) -> None:
        self._f.write(json.dumps({"ev": ev, **fields}) + "\n")

    def _atomic_savez(self, name: str, **arrays) -> None:
        tmp = os.path.join(self.path, f".tmp_{name}")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, os.path.join(self.path, name))

    def record_submit(self, req, tick: int, text_emb) -> None:
        payload = {
            "key": np.asarray(req.key),
            "batch_size": np.int64(req.batch_size),
        }
        if text_emb is not None:
            payload["text"] = np.asarray(text_emb)
        if req.deadline_s is not None:
            payload["deadline_s"] = np.float64(req.deadline_s)
        if req.max_steps is not None:
            payload["max_steps"] = np.int64(req.max_steps)
        self._atomic_savez(f"req_{req.seq:06d}.npz", **payload)
        self.event("submit", seq=req.seq, tick=tick,
                   batch=req.batch_size, deadline_s=req.deadline_s,
                   max_steps=req.max_steps)

    def load_submit(self, seq: int) -> dict | None:
        p = os.path.join(self.path, f"req_{seq:06d}.npz")
        if not os.path.exists(p):
            return None
        with np.load(p, allow_pickle=False) as z:
            out = {
                "key": np.asarray(z["key"]),
                "batch_size": int(z["batch_size"]),
                "text": np.asarray(z["text"]) if "text" in z.files
                else None,
                "deadline_s": float(z["deadline_s"])
                if "deadline_s" in z.files else None,
                "max_steps": int(z["max_steps"])
                if "max_steps" in z.files else None,
            }
        return out

    def write_snapshot(self, tick: int, arrays: dict,
                       meta: dict) -> None:
        arrays = dict(arrays)
        arrays["meta"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8
        )
        self._atomic_savez(f"snap_{tick:06d}.npz", **arrays)
        self.event("snapshot", tick=tick,
                   resident=[r["seq"] for r in meta["resident"]])

    def events(self) -> list[dict]:
        p = os.path.join(self.path, "journal.jsonl")
        if not os.path.exists(p):
            return []
        out = []
        with open(p) as f:
            for line in f:
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    break            # torn tail record from a crash
        return out

    def latest_snapshot(self) -> tuple[dict, dict] | None:
        """(arrays, meta) of the newest readable snapshot, or None."""
        paths = sorted(glob.glob(os.path.join(self.path, "snap_*.npz")))
        for p in reversed(paths):
            try:
                with np.load(p, allow_pickle=False) as z:
                    arrays = {k: np.asarray(z[k]) for k in z.files
                              if k != "meta"}
                    meta = json.loads(bytes(z["meta"]).decode())
                return arrays, meta
            except Exception:        # noqa: BLE001 — torn snapshot
                continue
        return None


# --------------------------------------------------------------------------
# Resilient scheduler
# --------------------------------------------------------------------------


class ResilientScheduler(ContinuousScheduler):
    """Rolling scheduler + deadlines, watchdog, breakers, and journal.

    Builds on the base class's resilience hooks: admission consults the
    per-bucket backoff windows, every admitted/resolved request is
    journaled, and resolved latents pass a host finiteness gate that
    attributes escapes to the routed expert slots.  All policy state is
    host-side; the compiled rolling step is untouched (identical traces
    and bitwise-identical outputs on the fault-free path — tested).
    """

    def __init__(self, engine, *, policy: ResiliencePolicy | None = None,
                 journal_dir: str | None = None, **kwargs) -> None:
        super().__init__(engine, **kwargs)
        self.policy = policy if policy is not None else ResiliencePolicy()
        self.rng = np.random.default_rng(self.policy.seed)
        self.breaker = CircuitBreaker(self.policy, self.rng)
        self.journal = (RequestJournal(journal_dir)
                        if journal_dir is not None else None)
        #: failed-bucket signature -> (retry-at tick, attempt count).
        self._backoff: dict[tuple, tuple[int, int]] = {}
        #: canary base key, folded per probe (threaded, not ambient).
        self._probe_key = jax.random.PRNGKey(self.policy.seed)
        self._probe_count = 0
        for k in ("deadline_exceeded", "watchdog_trips", "breaker_trips",
                  "breaker_probes", "breaker_restores",
                  "journal_snapshots"):
            engine.stats.setdefault(k, 0)
        if self.journal is not None:
            self.journal.event("open", tick=self.step_count)

    # -- submission ---------------------------------------------------------

    def submit(self, key, text_emb=None, batch_size: int | None = None,
               *, deadline_s: float | None = None,
               max_steps: int | None = None):
        """Enqueue a request with optional lifetime bounds.

        ``deadline_s`` is wall-clock (scheduler ``clock``) from submit;
        ``max_steps`` is scheduler ticks from submit.  Either expiring
        before resolution moves the request to DEADLINE_EXCEEDED at the
        next tick boundary.
        """
        req = super().submit(key, text_emb, batch_size)
        req.deadline_s = deadline_s
        req.max_steps = max_steps
        req.submit_t = self._timings[req.seq].submit_t
        if self.journal is not None:
            self.journal.record_submit(req, self.step_count, text_emb)
        return req

    # -- tick ---------------------------------------------------------------

    def step(self) -> int:
        self._expire_deadlines()
        self._run_probes()
        resolved = super().step()
        self.breaker.decay()
        if self.journal is not None:
            self.journal.event(
                "tick", tick=self.step_count,
                epoch=getattr(self.engine, "membership_epoch", 0),
                resolved=resolved, resident=self.num_resident,
                queued=len(self._queue),
            )
            if (self.step_count % max(1, self.policy.snapshot_every) == 0
                    and (self.num_resident or self._queue)):
                self._write_snapshot()
        return resolved

    # -- deadlines ----------------------------------------------------------

    def _expired(self, req, now: float) -> bool:
        tm = self._timings.get(req.seq)
        if tm is None:
            return False
        if req.max_steps is not None \
                and self.step_count - tm.submit_step >= req.max_steps:
            return True
        if req.deadline_s is not None \
                and now - tm.submit_t >= req.deadline_s:
            return True
        return False

    def _expire_deadlines(self) -> None:
        """Tick-boundary deadline sweep over queued + resident requests.

        Pure host bookkeeping (clock + ``t_host``-side row maps); the
        only device op is the sentinel scatter that frees an expired
        resident's rows."""
        now = self.clock()
        expired_q = [r for r in self._queue if self._expired(r, now)]
        if expired_q:
            # identity filter: PendingRequest is a dataclass whose
            # field-wise __eq__ would force array comparisons
            dead = {id(r) for r in expired_q}
            self._queue = [r for r in self._queue if id(r) not in dead]
            for req in expired_q:
                self._deadline(req)
        for bucket in self._buckets.values():
            for req in bucket.resident_requests():
                if self._expired(req, now):
                    bucket.release(req)
                    self._deadline(req)

    def _deadline(self, req) -> None:
        tm = self._timings.pop(req.seq, None)
        waited = self.step_count - tm.submit_step if tm else -1
        req.state = "DEADLINE_EXCEEDED"
        req.error = DeadlineExceeded(
            f"request seq={req.seq} exceeded its deadline after "
            f"{waited} tick(s) ({req.requeues} requeue(s); "
            f"deadline_s={req.deadline_s}, max_steps={req.max_steps})",
            seq=req.seq, requeues=req.requeues,
        )
        self.engine.stats["deadline_exceeded"] += 1
        if self.journal is not None:
            self.journal.event("deadline", seq=req.seq,
                               tick=self.step_count)

    # -- watchdog + bucket retry backoff ------------------------------------

    def _advance(self, bucket: RollingBatch) -> None:
        budget = self.policy.tick_budget_s
        t0 = self.clock()
        super()._advance(bucket)
        if budget is not None and self.clock() - t0 > budget:
            # Wall-clock around the compiled launch on the host side; a
            # slow launch fails ONLY this bucket (base step() isolates
            # the raise into _fail_bucket) and never injects a sync
            # into the traced program.
            self.engine.stats["watchdog_trips"] += 1
            raise TickBudgetExceeded(
                f"bucket launch took {self.clock() - t0:.3f}s > tick "
                f"budget {budget}s"
            )

    def _fail_bucket(self, sig: tuple, bucket: RollingBatch, e) -> None:
        self._attribute_dispatch_fault(bucket)
        residents = bucket.resident_requests()
        super()._fail_bucket(sig, bucket, e)
        until, attempt = self._backoff.get(sig, (0, 0))
        attempt += 1
        delay = min(self.policy.retry_base_ticks * (2 ** (attempt - 1)),
                    self.policy.retry_max_ticks)
        delay += int(round(delay * self.policy.retry_jitter
                           * float(self.rng.random())))
        self._backoff[sig] = (self.step_count + delay, attempt)
        if self.journal is not None:
            self.journal.event("bucket_failed", tick=self.step_count,
                               error=repr(e), backoff_ticks=delay,
                               attempt=attempt)
            for req in residents:
                self.journal.event(
                    "failed" if req.state == "FAILED" else "requeued",
                    seq=req.seq, tick=self.step_count,
                    requeues=req.requeues,
                )

    def _admission_blocked(self, sig: tuple) -> bool:
        until, _ = self._backoff.get(sig, (0, 0))
        return self.step_count < until

    def _attribute_dispatch_fault(self, bucket: RollingBatch) -> None:
        """Charge a bucket failure to the expert slots its in-flight
        rows last routed through.  Rows that never advanced carry no
        routing yet (slot buffers still zero-initialized) and are
        skipped rather than mis-charged to slot 0."""
        rows = [i for i, r in enumerate(bucket.rows)
                if r is not None
                and 0 < int(bucket.t_host[i]) < bucket.num_steps]
        if not rows:
            return
        slots = self._slots_of(bucket, rows)
        tripped = self.breaker.record_fault(
            slots, self.policy.dispatch_fault
        )
        self._trip(tripped)

    def _slots_of(self, bucket: RollingBatch, rows) -> list[int]:
        si = np.asarray(  # lint: allow-host-sync — fault-path attribution
            _take_rows(bucket.slot_idx, jnp.asarray(rows, jnp.int32))
        )
        return sorted({int(s) for s in si.ravel()})

    # -- admit / resolve hooks ----------------------------------------------

    def _on_admit(self, req, bucket: RollingBatch) -> None:
        if self.journal is not None:
            self.journal.event("admit", seq=req.seq,
                               tick=self.step_count,
                               rows=bucket.rows_of(req.seq))

    def _accept_result(self, bucket: RollingBatch, req, out,
                       rows) -> bool:
        if self.policy.check_numerics:
            arr = np.asarray(out)  # lint: allow-host-sync — resolution gate
            if not np.isfinite(arr).all():
                self._reject_nonfinite(bucket, req, rows)
                return False
        self._backoff.pop(self._sig(req), None)
        if self.journal is not None:
            self.journal.event("resolve", seq=req.seq,
                               tick=self.step_count)
        return True

    def _first_step_slots(self, req, bucket: RollingBatch) -> list[int]:
        """Recompute the routing the request's FIRST step used.

        Once non-finite latents feed the router, the carried
        ``slot_idx`` buffers refresh into junk (top-k over NaN logits)
        and no longer name the culprit.  The first step's routing is
        recomputable exactly from host-known inputs — the request's
        key-derived noise through ``fusion_weights`` under the bucket's
        admission-time membership — and a poisoned store corrupts from
        step one, so the first routed slots are the prime suspects."""
        eng = self.engine
        membership = bucket.membership
        store = membership[1] if membership is not None else eng.param_store
        cmap = membership[3] if membership is not None else None
        valid = getattr(store, "valid", None)
        cfg = eng.sampler
        noise = draw_noise(req.key, (req.batch_size,) + eng.latent_shape)
        t0 = jnp.full((req.batch_size,), _time_grid(cfg.num_steps)[0])
        w = fusion_weights(
            eng.experts, eng.router_fn, noise, t0,
            strategy=cfg.strategy, top_k=cfg.top_k,
            threshold=cfg.threshold,
            ddpm_low_noise_only=cfg.ddpm_low_noise_only,
            valid=valid, cluster_map=cmap,
        )
        k = bucket.slot_idx.shape[-1]
        idx, wgt = routed_slots(w, k, valid=valid)
        idx = np.asarray(idx)  # lint: allow-host-sync — fault-path attribution
        wgt = np.asarray(wgt)
        return sorted({int(s) for s, g in zip(idx.ravel(), wgt.ravel())
                       if g > 0})

    def _reject_nonfinite(self, bucket: RollingBatch, req, rows) -> None:
        """A NaN/Inf escape at resolution: attribute it to the routed
        slots, trip the breaker, and re-queue the request under a FRESH
        membership snapshot (its admission-time snapshot still holds
        the faulty store — retrying under it would fail identically)."""
        eng = self.engine
        slots = self._first_step_slots(req, bucket)
        tripped = self.breaker.record_fault(
            slots, self.policy.nonfinite_fault
        )
        self._trip(tripped)
        req.requeues += 1
        if req.requeues > eng.max_request_requeues:
            req.state = "FAILED"
            req.error = RequestFailed(
                f"request seq={req.seq} failed after {req.requeues} "
                f"dispatch attempt(s): non-finite latents escaped the "
                f"compiled step (routed slots {slots})",
                seq=req.seq, requeues=req.requeues,
            )
            eng.stats["failed_requests"] += 1
            self._timings.pop(req.seq, None)
        else:
            req.state = "QUEUED"
            req._membership = eng._membership()
            eng.stats["request_requeues"] += 1
            self._queue.append(req)
            self._queue.sort(key=lambda r: r.seq)
        if self.journal is not None:
            self.journal.event(
                "failed" if req.state == "FAILED" else "requeued",
                seq=req.seq, tick=self.step_count, nonfinite=True,
                slots=slots,
            )

    # -- breaker trip / canary probes ---------------------------------------

    def _trip(self, slots) -> None:
        eng = self.engine
        if not getattr(eng, "elastic", False):
            return
        for s in slots:
            if eng.expert_health[s] != "ACTIVE":
                continue
            if eng.num_live_experts <= 1:
                # Never trip the last live expert: degraded serving
                # beats serving nothing (documented failure-mode table).
                continue
            eng.trip_expert(s)
            self.breaker.start_probation(s, self.step_count)
            if self.journal is not None:
                self.journal.event("trip", slot=s, tick=self.step_count)

    def _run_probes(self) -> None:
        eng = self.engine
        if not getattr(eng, "elastic", False):
            return
        for slot in self.breaker.due_probes(self.step_count):
            eng.stats["breaker_probes"] += 1
            if self._probe(slot):
                eng.restore_expert(slot)
                self.breaker.end_probation(slot)
                eng.stats["breaker_restores"] += 1
                if self.journal is not None:
                    self.journal.event("restore", slot=slot,
                                       tick=self.step_count)
            else:
                self.breaker.probe_failed(slot, self.step_count)

    def _probe(self, slot: int) -> bool:
        """Synthetic canary: one uncond sample routed exclusively
        through ``slot`` (a one-hot validity mask over the SAME
        capacity-stable store — a value change, not a shape change, so
        the probe reuses the engine's compiled batch-1 sampler; the
        first probe ever pays that one compile).  Bypasses
        ``_run_compiled`` so a probe never pollutes the
        ``degraded_steps`` counter."""
        eng = self.engine
        self._probe_count += 1
        key = jax.random.fold_in(self._probe_key, self._probe_count)
        store = eng.param_store
        onehot = jnp.zeros((store.num_experts,), bool).at[slot].set(True)
        try:
            fn = eng._get_compiled(1, False)
            noise = jax.random.normal(
                key, (1,) + eng.latent_shape, jnp.float32
            )
            out = fn(key, noise, jnp.zeros((0,), jnp.float32),
                     store.with_valid(onehot), eng._coeff_tables,
                     eng._cluster_map)
            return bool(np.isfinite(np.asarray(out)).all())
        except Exception:            # noqa: BLE001 — a crashing probe fails
            return False

    # -- journal snapshot / restore -----------------------------------------

    def _write_snapshot(self) -> None:
        eng = self.engine
        arrays: dict = {}
        resident_meta = []
        for sig, bucket in self._buckets.items():
            for req in bucket.resident_requests():
                st = bucket.row_state(req.seq)
                arrays[f"r{req.seq}_x"] = st["x"]
                arrays[f"r{req.seq}_t"] = st["t"]
                arrays[f"r{req.seq}_si"] = st["slot_idx"]
                arrays[f"r{req.seq}_sw"] = st["slot_w"]
                tm = self._timings[req.seq]
                resident_meta.append({
                    "seq": req.seq, "batch": req.batch_size,
                    "submit_step": tm.submit_step,
                    "admit_step": tm.admit_step, "epoch": sig[2],
                    "requeues": req.requeues,
                })
        meta = {
            "tick": self.step_count,
            "resident": resident_meta,
            "queued": [
                {"seq": r.seq,
                 "submit_step": self._timings[r.seq].submit_step,
                 "requeues": r.requeues}
                for r in self._queue
            ],
            "epoch": getattr(eng, "membership_epoch", -1),
            # health-derived live mask — no device read on the event path
            "live_mask": [h == "ACTIVE" for h in eng.expert_health]
            if getattr(eng, "elastic", False) else None,
            "next_seq": eng._seq,
            "steps_per_tick": self.steps_per_tick,
            "max_resident": self.max_resident,
        }
        self.journal.write_snapshot(self.step_count, arrays, meta)
        eng.stats["journal_snapshots"] += 1

    @classmethod
    def restore(cls, engine, journal_dir: str, *,
                policy: ResiliencePolicy | None = None,
                clock=time.perf_counter, **kwargs) -> "ResilientScheduler":
        """Rebuild a scheduler from a journal and re-admit in-flight work.

        ``engine`` must be assembled from the same expert set the
        journal was written under (same store contents); membership is
        verified against the snapshot's recorded live mask and a
        mismatch raises :class:`JournalRestoreError` — restoring onto
        different weights would silently produce different samples.

        Resumption semantics: resident requests re-enter at their last
        snapshot's row state (bitwise-identical continuation — row
        independence makes row *placement* irrelevant); still-queued
        submits re-enter the queue in seq order.  ``max_steps``
        deadlines resume exactly (submit ticks are journaled);
        ``deadline_s`` wall-clock budgets restart at the restore (the
        dead process's wall time is unknowable and charging it would
        expire every restored request on a long outage).
        """
        reader = RequestJournal(journal_dir)
        try:
            events = reader.events()
            if not events:
                raise JournalRestoreError(
                    f"{journal_dir}: no journal records"
                )
            snap = reader.latest_snapshot()
            terminal = {
                e["seq"] for e in events
                if e["ev"] in ("resolve", "failed", "deadline")
            }
            submits = {e["seq"]: e for e in events if e["ev"] == "submit"}
        finally:
            reader.close()

        arrays, meta = snap if snap is not None else ({}, None)
        if meta is not None and meta.get("live_mask") is not None:
            if not getattr(engine, "elastic", False):
                raise JournalRestoreError(
                    "journal was written by an elastic engine; restore "
                    "target is fixed-membership"
                )
            current = [h == "ACTIVE" for h in engine.expert_health]
            if current != meta["live_mask"]:
                raise JournalRestoreError(
                    f"membership diverged from the snapshot: engine "
                    f"live mask {current} != journaled "
                    f"{meta['live_mask']} — rebuild the engine from the "
                    f"same checkpoints (and membership ops) first"
                )
        if meta is not None:
            kwargs.setdefault("max_resident", meta["max_resident"])
            kwargs.setdefault("steps_per_tick", meta["steps_per_tick"])
        sched = cls(engine, policy=policy, journal_dir=journal_dir,
                    clock=clock, **kwargs)
        sched.step_count = meta["tick"] if meta is not None else max(
            (e.get("tick", 0) for e in events), default=0
        )

        from repro.launch.serve import PendingRequest

        def rebuild(seq: int, extra: dict | None):
            payload = reader.load_submit(seq)
            if payload is None:
                raise JournalRestoreError(
                    f"journal names request seq={seq} but its submit "
                    f"payload req_{seq:06d}.npz is missing/unreadable"
                )
            req = PendingRequest(
                key=jnp.asarray(payload["key"]),
                text_emb=engine._cached_cond(payload["text"]),
                batch_size=payload["batch_size"],
                _membership=engine._membership(),
                seq=seq,
            )
            req.deadline_s = payload["deadline_s"]
            req.max_steps = payload["max_steps"]
            now = sched.clock()
            req.submit_t = now
            info = extra or {}
            req.requeues = info.get("requeues", 0)
            sched._timings[seq] = RequestTiming(
                submit_t=now,
                submit_step=info.get(
                    "submit_step", submits[seq].get("tick", 0)
                ),
            )
            return req

        resident_meta = (meta or {}).get("resident", [])
        restored_resident = set()
        for info in sorted(resident_meta, key=lambda r: r["seq"]):
            seq = info["seq"]
            if seq in terminal:
                continue
            req = rebuild(seq, info)
            sig = sched._sig(req)
            bucket = sched._buckets.get(sig)
            if bucket is None:
                bucket = sched._make_bucket(sig, req)
                sched._buckets[sig] = bucket
            bucket.admit_restored(
                req, arrays[f"r{seq}_x"], arrays[f"r{seq}_t"],
                arrays[f"r{seq}_si"], arrays[f"r{seq}_sw"],
            )
            req.state = "RESIDENT"
            tm = sched._timings[seq]
            tm.admit_t = sched.clock()
            tm.admit_step = info.get("admit_step", tm.submit_step)
            restored_resident.add(seq)

        queued_meta = {q["seq"]: q for q in (meta or {}).get("queued", [])}
        pending = sorted(
            s for s in submits
            if s not in terminal and s not in restored_resident
        )
        for seq in pending:
            sched._queue.append(rebuild(seq, queued_meta.get(seq)))
        engine._seq = max(
            [s + 1 for s in submits]
            + [(meta or {}).get("next_seq", 0), engine._seq]
        )
        if sched.journal is not None:
            sched.journal.event(
                "restored", tick=sched.step_count,
                resident=sorted(restored_resident), queued=pending,
            )
        return sched
