"""Rolling mixed-timestep batch state for the continuous scheduler.

A :class:`RollingBatch` owns one *shape bucket*'s device-resident row
state — the ``(B_cap, ...)``-leading buffers that
``core.sampling.sample_ensemble_step`` advances — plus the host-side
bookkeeping that maps requests onto rows.  The capacity ``B_cap`` is
fixed at construction, so every tick of the bucket feeds the compiled
rolling step the **same shapes** whatever requests join or leave: churn
is ``.at[rows].set`` buffer writes (eager ops, cached by shape), never a
retrace of the step program.

Row lifecycle (the device encoding is ``t_idx``):

* ``t_idx == num_steps`` — free/finished sentinel.  The row is frozen by
  the step program (latent passes through, index does not advance), so a
  partially-full batch costs padded FLOPs but stays bit-exact.
* ``t_idx == 0`` — set at admission together with the request's own
  ``N(0, 1)`` noise (drawn from *its* key, exactly as ``generate``
  would), zeroed routing slots, and its conditioning rows.
* ``0 < t_idx < num_steps`` — in flight; advances by 1 per tick.

Requests occupy ``batch_size`` contiguous-in-order (not necessarily
adjacent) rows; resolution slices those rows back out in sample order,
so the result is bitwise what a dedicated ``generate`` call with the
same key would return (proven in ``tests/test_continuous.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# Row-churn device ops, jitted: one compiled dispatch per admission /
# release instead of a chain of eager scatters (eager op dispatch costs
# milliseconds each on the hot scheduler tick; these are the ops a
# profile shows dominating an eager implementation).  jit caches per
# (capacity, batch_size) shape pair — at most ``capacity`` variants.

@jax.jit
def _scatter_admit(x, t_idx, slot_idx, slot_w, idx, noise):
    return (
        x.at[idx].set(noise),
        t_idx.at[idx].set(0),
        slot_idx.at[idx].set(0),
        slot_w.at[idx].set(0.0),
    )


@jax.jit
def _scatter_text(text, idx, emb):
    return text.at[idx].set(emb)


@jax.jit
def _scatter_restore(x, t_idx, slot_idx, slot_w, idx, xv, tv, siv, swv):
    return (
        x.at[idx].set(xv),
        t_idx.at[idx].set(tv),
        slot_idx.at[idx].set(siv),
        slot_w.at[idx].set(swv),
    )


@jax.jit
def _scatter_t(t_idx, idx, value):
    return t_idx.at[idx].set(value)


@jax.jit
def _take_rows(x, idx):
    return x[idx]


@functools.partial(jax.jit, static_argnums=(1,))
def draw_noise(key, shape):
    """Request-key initial noise, bitwise what ``generate`` draws (the
    sampler's own in-jit ``jax.random.normal`` on the same key)."""
    return jax.random.normal(key, shape, jnp.float32)


class RollingBatch:
    """Device row buffers + host row map for one shape bucket.

    ``membership`` is the admission-time elastic snapshot tuple
    ``(epoch, store, tables, cluster_map)`` shared by every request in
    the bucket (the bucket key includes the epoch), or ``None`` on a
    fixed-membership engine.
    """

    def __init__(
        self,
        *,
        capacity: int,
        latent_shape: tuple[int, ...],
        k_slots: int,
        num_steps: int,
        text_tail: tuple[int, ...] | None = None,
        membership: tuple | None = None,
    ) -> None:
        self.capacity = capacity
        self.latent_shape = tuple(latent_shape)
        self.num_steps = num_steps
        self.text_tail = tuple(text_tail) if text_tail is not None else None
        self.membership = membership
        self.x = jnp.zeros((capacity,) + self.latent_shape, jnp.float32)
        self.t_idx = jnp.full((capacity,), num_steps, jnp.int32)
        #: host mirror of ``t_idx``.  Row progress is deterministic —
        #: every active row advances exactly 1 per tick — so completion
        #: detection never has to read the device buffer back: ticks
        #: stay fully asynchronous and the device pipeline never drains
        #: on a scheduler round-trip.  ``advance_host()`` keeps it in
        #: lockstep with the compiled step's ``t_idx + active`` update.
        self.t_host = np.full((capacity,), num_steps, np.int32)
        self.slot_idx = jnp.zeros((capacity, k_slots), jnp.int32)
        self.slot_w = jnp.zeros((capacity, k_slots), jnp.float32)
        self.text = (
            jnp.zeros((capacity,) + self.text_tail, jnp.float32)
            if self.text_tail is not None else None
        )
        #: row -> resident request (or None); requests own their
        #: ``batch_size`` rows from admission to resolution/release.
        self.rows: list = [None] * capacity
        #: request.seq -> ordered row indices (sample order).
        self._rows_of: dict[int, list[int]] = {}
        #: admission order (seq) — resolution and failure handling walk
        #: requests oldest-first so re-queues preserve seq order.
        self._order: list[int] = []
        self._by_seq: dict[int, object] = {}

    # -- occupancy ----------------------------------------------------------

    def free_count(self) -> int:
        return sum(r is None for r in self.rows)

    @property
    def num_resident(self) -> int:
        return len(self._order)

    def resident_requests(self) -> list:
        """Resident requests, oldest (lowest seq) first."""
        return [self._by_seq[s] for s in sorted(self._order)]

    def rows_of(self, seq: int) -> list[int]:
        """The ordered rows a resident request occupies (sample order)."""
        return list(self._rows_of[seq])

    # -- admission / release ------------------------------------------------

    def admit(self, req, noise: jax.Array) -> list[int]:
        """Place ``req`` into the lowest free rows; returns the rows.

        ``noise`` is the request's own ``(batch_size, *latent)`` initial
        noise.  Buffer writes go through one jitted scatter call (cached
        per batch_size), not a chain of eager ops — eager dispatch is
        the scheduler's dominant host cost otherwise.
        """
        free = [i for i, r in enumerate(self.rows) if r is None]
        if len(free) < req.batch_size:
            raise RuntimeError(
                f"bucket has {len(free)} free rows < batch_size "
                f"{req.batch_size} (admission control should gate this)"
            )
        rows = free[: req.batch_size]
        idx = jnp.asarray(rows, jnp.int32)
        self.x, self.t_idx, self.slot_idx, self.slot_w = _scatter_admit(
            self.x, self.t_idx, self.slot_idx, self.slot_w, idx, noise
        )
        self.t_host[rows] = 0
        if self.text is not None:
            self.text = _scatter_text(
                self.text, idx, jnp.asarray(req.text_emb, jnp.float32)
            )
        for i in rows:
            self.rows[i] = req
        self._rows_of[req.seq] = rows
        self._order.append(req.seq)
        self._by_seq[req.seq] = req
        return rows

    def admit_restored(
        self, req, x, t_idx, slot_idx, slot_w,
    ) -> list[int]:
        """Re-admit a request at a journal-snapshot row state.

        The crash-recovery path (``serving.resilience.RequestJournal``):
        instead of fresh key-derived noise at ``t=0``, the request's rows
        are written back exactly as the snapshot captured them — latent,
        step index, and routing slots — so the compiled step resumes the
        *identical* trajectory (``sample_ensemble_step`` refreshes
        routing on each row's own ``t_idx`` phase; everything else is a
        pure function of this row state).  Conditioning rows re-scatter
        from the request handle as on first admission.
        """
        free = [i for i, r in enumerate(self.rows) if r is None]
        if len(free) < req.batch_size:
            raise RuntimeError(
                f"bucket has {len(free)} free rows < batch_size "
                f"{req.batch_size} (restore admission should gate this)"
            )
        rows = free[: req.batch_size]
        idx = jnp.asarray(rows, jnp.int32)
        t_np = np.asarray(t_idx, np.int32)
        self.x, self.t_idx, self.slot_idx, self.slot_w = _scatter_restore(
            self.x, self.t_idx, self.slot_idx, self.slot_w, idx,
            jnp.asarray(x, jnp.float32), jnp.asarray(t_np),
            jnp.asarray(slot_idx, jnp.int32),
            jnp.asarray(slot_w, jnp.float32),
        )
        self.t_host[rows] = t_np
        if self.text is not None:
            self.text = _scatter_text(
                self.text, idx, jnp.asarray(req.text_emb, jnp.float32)
            )
        for i in rows:
            self.rows[i] = req
        self._rows_of[req.seq] = rows
        self._order.append(req.seq)
        self._by_seq[req.seq] = req
        return rows

    def row_state(self, seq: int) -> dict:
        """Host snapshot of one resident request's row state (the
        journal's latent-snapshot payload).  Materializes the request's
        rows of ``x``/``slot_idx``/``slot_w`` (a device→host read — the
        snapshot cadence pays this, never the per-tick event path) and
        reads ``t`` from the host mirror."""
        rows = self._rows_of[seq]
        idx = jnp.asarray(rows, jnp.int32)
        return {
            "x": np.asarray(_take_rows(self.x, idx)),
            "t": self.t_host[rows].copy(),
            "slot_idx": np.asarray(_take_rows(self.slot_idx, idx)),
            "slot_w": np.asarray(_take_rows(self.slot_w, idx)),
        }

    def release(self, req, *, finished: bool = False) -> list[int]:
        """Free ``req``'s rows (failure path or post-resolution).

        Sets the rows' ``t_idx`` back to the sentinel so an in-flight
        row of a failed request stops advancing immediately.  When the
        request ran to completion (``finished=True``), the compiled step
        already parked those rows at the sentinel — the device write is
        skipped and only host bookkeeping runs.
        """
        rows = self._rows_of.pop(req.seq, [])
        if rows:
            if not finished:
                self.t_idx = _scatter_t(
                    self.t_idx,
                    jnp.asarray(rows, jnp.int32),
                    jnp.int32(self.num_steps),
                )
            self.t_host[rows] = self.num_steps
            for i in rows:
                self.rows[i] = None
        if req.seq in self._order:
            self._order.remove(req.seq)
        self._by_seq.pop(req.seq, None)
        return rows

    # -- completion ---------------------------------------------------------

    def advance_host(self, steps: int = 1) -> None:
        """Mirror one compiled tick on the host counters: every active
        row advances ``steps`` (the tick's ``steps_per_tick``), clamped
        at the sentinel exactly as the step program freezes finished
        rows mid-tick.  Called by the scheduler after each successful
        bucket advance, so completion detection stays a pure host
        computation — no device→host read-back stalls the rolling
        pipeline."""
        active = (self.t_host >= 0) & (self.t_host < self.num_steps)
        self.t_host[active] = np.minimum(
            self.t_host[active] + steps, self.num_steps
        )

    def t_idx_host(self) -> np.ndarray:
        """Device read-back of the per-row step indices.  Debug/test
        hook only (it forces a sync with the in-flight step); scheduling
        decisions run off the ``t_host`` mirror instead."""
        return np.asarray(jax.device_get(self.t_idx))

    def finished_requests(self, t_host: np.ndarray | None = None) -> list:
        """Resident requests whose every row reached the grid end, in
        seq order (deterministic resolution order).  Reads the host
        mirror unless an explicit snapshot is passed."""
        if t_host is None:
            t_host = self.t_host
        done = []
        for seq in sorted(self._order):
            rows = self._rows_of[seq]
            if all(int(t_host[i]) >= self.num_steps for i in rows):
                done.append(self._by_seq[seq])
        return done

    def resolve(self, req) -> jax.Array:
        """Slice the finished request's latents out (sample order) and
        free its rows."""
        rows = self._rows_of[req.seq]
        out = _take_rows(self.x, jnp.asarray(rows, jnp.int32))
        self.release(req, finished=True)
        return out
