"""Deterministic continuous-batching self-check (CI smoke).

Builds a toy homogeneous ensemble (analytic expert closures — no model
weights, so the smoke runs in seconds on the CPU container), drives
staggered requests through :class:`repro.serving.ContinuousScheduler`,
and asserts each resolved request is **bitwise identical** to a
dedicated ``generate`` call on a twin engine, with exactly one trace of
the rolling step program.  Exits non-zero on any mismatch.

Run as ``PYTHONPATH=src python -m repro.serving``.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExpertSpec, SamplerConfig
from repro.launch.serve import ServingEngine
from repro.serving import ContinuousScheduler

LATENT = (4, 4, 2)
TEXT_TAIL = (3, 5)
K = 8


def _toy_apply(params, x, t, text_emb=None, drop_mask=None):
    """Analytic expert: batch-leading, row-independent, cond-sensitive."""
    tt = t.reshape((-1,) + (1,) * (x.ndim - 1))
    out = x * params["a"] + params["b"] * tt
    if text_emb is not None:
        c = jnp.tanh(text_emb.mean(axis=tuple(range(1, text_emb.ndim))))
        if drop_mask is not None:
            c = jnp.where(drop_mask, 0.07, c)
        out = out + 0.1 * c.reshape(tt.shape)
    return out


def _toy_router(x, t):
    m = x.mean(axis=tuple(range(1, x.ndim)))
    logits = (jnp.arange(K, dtype=jnp.float32)[None] * 0.3
              + m[:, None] * 3.0 + t[:, None])
    return jax.nn.softmax(logits, axis=-1)


def _make_engine() -> ServingEngine:
    experts = [
        ExpertSpec(
            name=f"toy{i}",
            objective="ddpm" if i % 2 == 0 else "fm",
            schedule="cosine" if i % 2 == 0 else "linear",
            apply_fn=_toy_apply,
            cluster_id=i,
        )
        for i in range(K)
    ]
    params = [
        {"a": jnp.float32(0.8 + 0.03 * i), "b": jnp.float32(0.05 * i - 0.1)}
        for i in range(K)
    ]
    return ServingEngine(
        experts=experts, expert_params=params, router_fn=_toy_router,
        latent_shape=LATENT,
        sampler=SamplerConfig(num_steps=6, cfg_scale=3.0,
                              strategy="topk", top_k=2),
    )


def main() -> int:
    engine = _make_engine()
    sched = ContinuousScheduler(engine, max_resident=4)

    # Staggered arrivals: requests join mid-flight, so the rolling batch
    # genuinely mixes timesteps before the parity check.
    specs = [(0, 1), (1, 2), (2, 1), (4, 1), (5, 2), (7, 1)]  # (tick, bs)
    handles, texts, keys = [], [], []
    tick = 0
    for arrive, bs in specs:
        while tick < arrive:
            sched.step()
            tick += 1
        key = jax.random.PRNGKey(100 + len(handles))
        text = jax.random.normal(
            jax.random.fold_in(key, 1), (bs,) + TEXT_TAIL, jnp.float32
        )
        handles.append(sched.submit(key, text))
        keys.append(key)
        texts.append(text)
    sched.run_until_idle()

    twin = _make_engine()
    ok = True
    for i, (h, key, text) in enumerate(zip(handles, keys, texts)):
        want = np.asarray(twin.generate(key, text, text.shape[0]))
        got = np.asarray(h.result())
        if not np.array_equal(got, want):
            ok = False
            print(f"request {i}: rolling output != generate "
                  f"(max |diff| = {np.abs(got - want).max():.3e})")
    traces = engine.stats["traces"]
    if traces != 1:
        ok = False
        print(f"expected exactly 1 rolling-step trace, got {traces}")
    for k in ("latency_p50_s", "latency_p95_s", "queue_wait_p50_steps"):
        if k not in engine.stats:
            ok = False
            print(f"missing stats key {k!r}")
    print(sched.line())
    if not ok:
        print("continuous-batching smoke FAILED")
        return 1
    print(f"continuous-batching smoke OK: {len(handles)} staggered "
          f"requests bitwise == sequential generate(), traces={traces}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
