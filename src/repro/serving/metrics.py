"""Latency/throughput observability for the continuous-batching scheduler.

The engine's existing ``stats`` dict counts discrete events (traces,
requests, cache hits).  Continuous batching adds *distributions*: how
long a request queued before admission and how long it took end to end,
in both wall-clock seconds and scheduler steps.  This module is the
recorder behind ``ServingEngine.stats``'s ``latency_*``/``queue_wait_*``
percentile fields and ``scheduler_line()``.

Percentiles use the deterministic nearest-rank definition (the smallest
recorded value with at least ``q``% of samples at or below it), so tests
can assert exact values and two runs over the same trace agree bit-for-
bit — no interpolation.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class RequestTiming:
    """Per-request clock/step marks, keyed by ``PendingRequest.seq``.

    ``submit_*`` is stamped when the request enters the scheduler queue,
    ``admit_*`` when it becomes resident in a rolling batch; resolution
    closes the record into the recorder's series.
    """

    submit_t: float
    submit_step: int
    admit_t: float | None = None
    admit_step: int | None = None


def percentile(values: list[float], q: float) -> float | None:
    """Nearest-rank percentile: smallest value covering ``q``% of samples.

    ``rank = ceil(q/100 · n)`` (1-indexed) over the sorted values.
    Deterministic, interpolation-free, and exact for test assertions.
    An empty series has no percentiles: returns None (never a made-up
    0.0 that would read as "zero latency" in ``engine.stats``); a
    single-sample series returns that sample for every ``q``.
    """
    if not values:
        return None
    s = sorted(values)
    rank = max(1, -(-int(q * len(s)) // 100))  # ceil(q*n/100), >= 1
    return s[min(rank, len(s)) - 1]


class LatencyRecorder:
    """Accumulates per-request latency samples and derives summary stats.

    Series (all per *request*, recorded once at resolution):

    * ``queue_wait_s`` / ``queue_wait_steps`` — submit → admission;
    * ``e2e_s`` / ``e2e_steps`` — submit → resolution (the user-visible
      latency, including queue wait).

    ``snapshot()`` folds them into a flat dict of floats suitable for
    merging into ``ServingEngine.stats`` and for the BENCH JSON.
    """

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self.queue_wait_s: list[float] = []
        self.queue_wait_steps: list[float] = []
        self.e2e_s: list[float] = []
        self.e2e_steps: list[float] = []
        self.images = 0
        self.first_t: float | None = None
        self.last_t: float | None = None

    def observe(
        self,
        *,
        queue_wait_s: float,
        e2e_s: float,
        queue_wait_steps: int,
        e2e_steps: int,
        images: int,
        now: float,
    ) -> None:
        """Record one resolved request (``images`` samples) at time ``now``."""
        self.queue_wait_s.append(float(queue_wait_s))
        self.queue_wait_steps.append(float(queue_wait_steps))
        self.e2e_s.append(float(e2e_s))
        self.e2e_steps.append(float(e2e_steps))
        self.images += int(images)
        if self.first_t is None:
            # throughput window opens at the first *resolution* minus its
            # own e2e time (~ the first submit), so a single-request run
            # still reports a finite rate.
            self.first_t = now - float(e2e_s)
        self.last_t = now

    @property
    def completed(self) -> int:
        return len(self.e2e_s)

    def throughput(self) -> float:
        """Resolved images per second over the observation window."""
        if self.first_t is None or self.last_t is None:
            return 0.0
        span = self.last_t - self.first_t
        if span <= 0.0:
            return 0.0
        return self.images / span

    def snapshot(self) -> dict:
        """Flat summary dict (merged into ``ServingEngine.stats``).

        Percentile keys are OMITTED while their series is empty —
        publishing a placeholder would poison ``engine.stats`` with
        fake zero-latency figures that dashboards/benches can't tell
        from real ones (regression-tested in tests/test_resilience.py).
        """
        out = {
            "completed_requests": float(self.completed),
            "completed_images": float(self.images),
            "throughput_img_s": self.throughput(),
        }
        for name, series, unit in (
            ("queue_wait", self.queue_wait_s, "s"),
            ("latency", self.e2e_s, "s"),
            ("queue_wait", self.queue_wait_steps, "steps"),
            ("latency", self.e2e_steps, "steps"),
        ):
            for q in (50, 95, 99):
                p = percentile(series, q)
                if p is not None:
                    out[f"{name}_p{q}_{unit}"] = p
        return out
