"""Continuous batching over the serving engine (rolling mixed-timestep
scheduler, admission control, shape bucketing, latency observability),
plus the serving resilience layer (request deadlines, step watchdogs,
expert circuit breakers, crash-recoverable request journal — see
``docs/resilience.md``).

``python -m repro.serving`` runs a deterministic self-check smoke
(staggered rolling vs sequential ``generate``, asserted bitwise).
"""

from repro.serving.batch import RollingBatch
from repro.serving.metrics import LatencyRecorder, RequestTiming, percentile
from repro.serving.resilience import (
    CircuitBreaker,
    DeadlineExceeded,
    JournalRestoreError,
    RequestError,
    RequestFailed,
    RequestJournal,
    RequestTimeout,
    ResiliencePolicy,
    ResilientScheduler,
    TickBudgetExceeded,
)
from repro.serving.scheduler import (
    AdmissionError,
    ContinuousScheduler,
    QueueBackpressure,
)

__all__ = [
    "AdmissionError",
    "CircuitBreaker",
    "ContinuousScheduler",
    "DeadlineExceeded",
    "JournalRestoreError",
    "LatencyRecorder",
    "QueueBackpressure",
    "RequestError",
    "RequestFailed",
    "RequestJournal",
    "RequestTimeout",
    "RequestTiming",
    "ResiliencePolicy",
    "ResilientScheduler",
    "RollingBatch",
    "TickBudgetExceeded",
    "percentile",
]
