"""Continuous batching over the serving engine (rolling mixed-timestep
scheduler, admission control, shape bucketing, latency observability).

``python -m repro.serving`` runs a deterministic self-check smoke
(staggered rolling vs sequential ``generate``, asserted bitwise).
"""

from repro.serving.batch import RollingBatch
from repro.serving.metrics import LatencyRecorder, RequestTiming, percentile
from repro.serving.scheduler import (
    AdmissionError,
    ContinuousScheduler,
    QueueBackpressure,
)

__all__ = [
    "AdmissionError",
    "ContinuousScheduler",
    "LatencyRecorder",
    "QueueBackpressure",
    "RequestTiming",
    "RollingBatch",
    "percentile",
]
