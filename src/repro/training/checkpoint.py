"""npz checkpointing with pytree flattening + expert metadata.

Decentralized experts checkpoint independently (no coordination); each
checkpoint carries its objective/schedule/cluster metadata so the serving
engine can assemble a heterogeneous ensemble from a directory of expert
checkpoints produced by unrelated contributors (paper §5 limitation iv —
self-describing expert metadata).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix.rstrip(SEP[-1]).rstrip(SEP[0])] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)
    return _intify(tree)


def _intify(node):
    """Convert dicts whose keys are 0..n-1 back into lists."""
    if not isinstance(node, dict):
        return node
    node = {k: _intify(v) for k, v in node.items()}
    keys = list(node)
    if keys and all(k.isdigit() for k in keys):
        idx = sorted(int(k) for k in keys)
        if idx == list(range(len(idx))):
            return [node[str(i)] for i in idx]
    return node


def save_checkpoint(
    path: str, params: Any, *, metadata: dict | None = None
) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    meta = json.dumps(metadata or {})
    np.savez(path, __metadata__=np.asarray(meta), **flat)


def load_checkpoint(path: str) -> tuple[Any, dict]:
    """Load a ``save_checkpoint`` artifact, failing with *named* errors.

    A missing file raises ``FileNotFoundError`` naming the resolved path;
    a missing ``__metadata__`` entry, a truncated/corrupt archive, or a
    non-zip file raises ``ValueError`` naming the file and the reason —
    never an opaque ``KeyError``/``BadZipFile``/``OSError`` from deep
    inside ``np.load`` (decentralized contributors hand us arbitrary
    bytes over unreliable transports; the error must say which file is
    wrong and why, so the serving engine's quarantine path can record it
    instead of crashing).
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"checkpoint not found: {path} (expected an .npz written by "
            f"repro.training.save_checkpoint)"
        )
    try:
        with np.load(path, allow_pickle=False) as z:
            names = sorted(z.files)
            has_meta = "__metadata__" in z.files
            raw_meta = str(z["__metadata__"]) if has_meta else ""
            flat = {k: z[k] for k in z.files if k != "__metadata__"}
    except Exception as e:
        # zipfile.BadZipFile (non-zip bytes), OSError/EOFError (archive
        # truncated mid-member), struct.error, np.load's own bare
        # ValueError on unpicklable garbage, ...
        raise ValueError(
            f"{path}: corrupt or truncated checkpoint archive — "
            f"{type(e).__name__}: {e}"
        ) from e
    if not has_meta:
        raise ValueError(
            f"{path}: missing '__metadata__' entry — not a "
            f"save_checkpoint artifact (archive keys: {names[:5]}"
            f"{'...' if len(names) > 5 else ''})"
        )
    try:
        meta = json.loads(raw_meta)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"{path}: mangled '__metadata__' JSON — {e}"
        ) from e
    return _unflatten(flat), meta


def expert_metadata(
    *, name: str, objective: str, schedule: str, cluster_id: int,
    arch: str, step: int = 0, extra: dict | None = None,
) -> dict:
    md = {
        "name": name, "objective": objective, "schedule": schedule,
        "cluster_id": cluster_id, "arch": arch, "step": step,
        "format_version": 1,
    }
    if extra:
        md.update(extra)
    return md
