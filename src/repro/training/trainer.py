"""Trainers: isolated diffusion experts, router, and LM smoke-training.

The expert trainer is deliberately self-contained — one expert, one data
partition, one optimizer; nothing references any other expert.  The
decentralization of the paper is enforced by construction: training K
experts is literally K independent invocations of ``ExpertTrainer`` (in the
paper, on K different contributors' GPUs).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.objectives import diffusion_loss, sample_timesteps
from repro.core.schedules import Schedule, get_schedule
from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    ema_init,
    ema_update,
)

Array = jax.Array


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: AdamWState
    ema: Any
    step: int = 0


@dataclasses.dataclass
class ExpertTrainer:
    """One decentralized diffusion expert (paper §6.2).

    apply_fn(params, x_t, t, text_emb=...) -> prediction.
    """

    apply_fn: Callable[..., Array]
    objective: str                      # 'ddpm' | 'fm'
    schedule_name: str                  # 'cosine' | 'linear'
    opt: AdamWConfig = AdamWConfig()
    cfg_drop_prob: float = 0.1          # classifier-free guidance dropout
    ema_decay: float = 0.9999

    def __post_init__(self):
        self.schedule: Schedule = get_schedule(self.schedule_name)
        self._step = jax.jit(self._train_step)

    def init_state(self, params) -> TrainState:
        return TrainState(
            params=params, opt_state=adamw_init(params),
            ema=ema_init(params), step=0,
        )

    def loss(self, params, key, latents: Array, text_emb: Array | None):
        k_t, k_eps, k_drop = jax.random.split(key, 3)
        b = latents.shape[0]
        t = sample_timesteps(k_t, b, objective=self.objective)
        eps = jax.random.normal(k_eps, latents.shape)
        cond: dict = {}
        if text_emb is not None:
            # paper §2.5: conditioning dropped with p=0.1; dropped samples
            # use the learned null embedding (handled by the model given
            # the per-sample drop mask).
            drop = jax.random.bernoulli(k_drop, self.cfg_drop_prob, (b,))
            cond = {"text_emb": text_emb, "drop_mask": drop}
        return diffusion_loss(
            self.apply_fn, params, latents, eps, t,
            objective=self.objective, schedule=self.schedule, cond=cond,
        )

    def _train_step(self, state_tuple, key, latents, text_emb):
        params, opt_state, ema = state_tuple
        loss, grads = jax.value_and_grad(
            lambda p: self.loss(p, key, latents, text_emb)
        )(params)
        params, opt_state, metrics = adamw_update(
            self.opt, grads, opt_state, params
        )
        ema = ema_update(ema, params, self.ema_decay)
        return params, opt_state, ema, loss, metrics

    def train_step(self, state: TrainState, key, batch: dict) -> tuple[
        TrainState, dict
    ]:
        params, opt_state, ema, loss, metrics = self._step(
            (state.params, state.opt_state, state.ema),
            key, batch["latents"], batch.get("text_emb"),
        )
        return TrainState(params, opt_state, ema, state.step + 1), {
            "loss": float(loss), **{k: float(v) for k, v in metrics.items()},
        }


@dataclasses.dataclass
class RouterTrainer:
    """Router classifier over noisy latents (paper §6.3).

    Trains with CE against ground-truth cluster ids; timesteps sampled
    uniformly in both objective domains so the router covers DDPM's
    discrete grid and FM's continuous range.
    """

    apply_fn: Callable[..., Array]       # (params, x_t, t) -> (B, K) logits
    num_clusters: int
    opt: AdamWConfig = AdamWConfig(
        learning_rate=5e-5, weight_decay=1e-2, warmup_steps=0,
        cosine_decay=True, min_lr_ratio=0.01,
    )

    def __post_init__(self):
        self._step = jax.jit(self._train_step)
        self._lin = get_schedule("linear")
        self._cos = get_schedule("cosine")

    def init_state(self, params) -> TrainState:
        return TrainState(
            params=params, opt_state=adamw_init(params),
            ema=ema_init(params), step=0,
        )

    def loss(self, params, key, latents: Array, labels: Array):
        k_t, k_eps, k_mix = jax.random.split(key, 3)
        b = latents.shape[0]
        t = jax.random.uniform(k_t, (b,))
        eps = jax.random.normal(k_eps, latents.shape)
        # §6.3 timestep sampling: half the batch perturbed with the DDPM
        # cosine schedule, half with the FM linear path.
        use_cos = jax.random.bernoulli(k_mix, 0.5, (b,))
        x_cos = self._cos.perturb(latents, eps, t)
        x_lin = self._lin.perturb(latents, eps, t)
        x_t = jnp.where(use_cos[:, None, None, None], x_cos, x_lin)
        logits = self.apply_fn(params, x_t, t)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.mean(
            jnp.take_along_axis(logp, labels[:, None], axis=-1)
        )
        acc = jnp.mean(jnp.argmax(logits, -1) == labels)
        return ce, acc

    def _train_step(self, state_tuple, key, latents, labels):
        params, opt_state, ema = state_tuple
        (loss, acc), grads = jax.value_and_grad(
            lambda p: self.loss(p, key, latents, labels), has_aux=True
        )(params)
        params, opt_state, metrics = adamw_update(
            self.opt, grads, opt_state, params
        )
        ema = ema_update(ema, params)
        return params, opt_state, ema, loss, acc, metrics

    def train_step(self, state: TrainState, key, batch: dict):
        params, opt_state, ema, loss, acc, metrics = self._step(
            (state.params, state.opt_state, state.ema),
            key, batch["latents"], batch["cluster"],
        )
        return TrainState(params, opt_state, ema, state.step + 1), {
            "loss": float(loss), "acc": float(acc),
            **{k: float(v) for k, v in metrics.items()},
        }


def make_lm_train_step(cfg, opt: AdamWConfig):
    """Jitted LM train step for the assigned architectures (zoo dispatch)."""
    from repro.models import zoo

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: zoo.loss_fn(cfg, p, batch), has_aux=True
        )(params)
        params, opt_state, om = adamw_update(opt, grads, opt_state, params)
        return params, opt_state, loss, {**metrics, **om}

    return step
