"""Optimizer substrate (optax-lite, built in-repo per scope rules).

AdamW exactly as §6.2: β1=0.9, β2=0.999, ε=1e-8, weight decay 0 for
experts / 1e-2 for the router, linear warmup, optional cosine decay,
global-norm gradient clipping (max 1.0), and EMA(0.9999) of parameters
updated after every step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 5000
    total_steps: int = 500_000
    cosine_decay: bool = False
    min_lr_ratio: float = 0.01
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: Array
    mu: PyTree
    nu: PyTree


def lr_schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup then constant (paper) or cosine decay (router §6.3)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    if not cfg.cosine_decay:
        return cfg.learning_rate * warm
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    floor = cfg.min_lr_ratio
    return cfg.learning_rate * warm * (floor + (1.0 - floor) * cos)


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree
) -> tuple[PyTree, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }


# --- EMA (§6.2) --------------------------------------------------------------


def ema_init(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def ema_update(ema: PyTree, params: PyTree, decay: float = 0.9999) -> PyTree:
    return jax.tree.map(
        lambda e, p: decay * e + (1.0 - decay) * p.astype(jnp.float32),
        ema, params,
    )
