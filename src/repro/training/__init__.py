from repro.training.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update, ema_init, ema_update,
    clip_by_global_norm, global_norm, lr_schedule,
)
from repro.training.trainer import (
    ExpertTrainer, RouterTrainer, TrainState, make_lm_train_step,
)
from repro.training.checkpoint import (
    save_checkpoint, load_checkpoint, expert_metadata,
)
