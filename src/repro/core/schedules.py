"""Noise schedules for diffusion / flow-matching experts.

The paper (§2.3, §8.1) uses two schedule families:

* **linear** (rectified-flow interpolation): ``alpha_t = 1 - t``,
  ``sigma_t = t`` with continuous ``t in [0, 1]`` — used by Flow Matching
  experts (Eq. 4).
* **cosine**: ``alpha_t = cos(pi t / 2)``, ``sigma_t = sin(pi t / 2)`` —
  used by DDPM experts (Eq. 26).  This is variance preserving
  (``alpha^2 + sigma^2 = 1``).

Every schedule exposes ``alpha/sigma`` and their *analytic* time
derivatives, plus the paper's §8.3.3 central finite-difference fallback
(``h = 1e-4``) used when a schedule has no closed-form derivative.

Conventions (paper §2.3): ``t = 0`` is data, ``t = 1`` is noise, for both
families.  Discrete DDPM timesteps are mapped through Eq. 21:
``t_DiT = round(999 t)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

#: §8.3.3 — derivative epsilon for finite differences.
FD_EPS = 1e-4

#: Eq. 21 — size of the pretrained DiT timestep-embedding table.
NUM_DDPM_TIMESTEPS = 1000


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A forward-process schedule ``x_t = alpha_t x0 + sigma_t eps``."""

    name: str
    alpha: Callable[[Array], Array]
    sigma: Callable[[Array], Array]
    dalpha: Callable[[Array], Array]
    dsigma: Callable[[Array], Array]
    #: True when ``alpha^2 + sigma^2 == 1`` for all t.
    variance_preserving: bool = False

    def coeffs(self, t: Array) -> tuple[Array, Array]:
        return self.alpha(t), self.sigma(t)

    def derivs(self, t: Array) -> tuple[Array, Array]:
        return self.dalpha(t), self.dsigma(t)

    def fd_derivs(self, t: Array, h: float = FD_EPS) -> tuple[Array, Array]:
        """§8.3.3 central finite differences of the schedule coefficients."""
        da = (self.alpha(t + h) - self.alpha(t - h)) / (2.0 * h)
        ds = (self.sigma(t + h) - self.sigma(t - h)) / (2.0 * h)
        return da, ds

    def snr(self, t: Array) -> Array:
        """Signal-to-noise ratio ``alpha^2 / sigma^2``."""
        a, s = self.coeffs(t)
        return (a * a) / jnp.maximum(s * s, 1e-12)

    def perturb(self, x0: Array, eps: Array, t: Array) -> Array:
        """Forward process ``x_t = alpha_t x0 + sigma_t eps`` (Eq. 22).

        ``t`` broadcasts against leading axes of ``x0``.
        """
        a, s = self.coeffs(t)
        a = _left_broadcast(a, x0.ndim)
        s = _left_broadcast(s, x0.ndim)
        return a * x0 + s * eps


def coeff_table(
    schedule: "Schedule", ts: Array, *, derivative_mode: str = "analytic"
) -> Array:
    """Precomputed ``(4, S)`` table of ``(alpha, sigma, dalpha, dsigma)``.

    The sampling hot path evaluates schedule coefficients at the same step
    grid every request; tabulating them once per run keeps the per-step
    work to a single gather (see ``conversion.unified_coeff_tables``).
    """
    ts = jnp.asarray(ts, jnp.float32)
    a, s = schedule.coeffs(ts)
    if derivative_mode == "fd":
        da, ds = schedule.fd_derivs(ts)
    else:
        da, ds = schedule.derivs(ts)
    return jnp.stack([
        jnp.broadcast_to(a, ts.shape), jnp.broadcast_to(s, ts.shape),
        jnp.broadcast_to(da, ts.shape), jnp.broadcast_to(ds, ts.shape),
    ]).astype(jnp.float32)


def _left_broadcast(c: Array, ndim: int) -> Array:
    """Reshape a per-sample coefficient ``(B,)`` to ``(B, 1, ..., 1)``."""
    c = jnp.asarray(c)
    return c.reshape(c.shape + (1,) * (ndim - c.ndim))


def linear_schedule() -> Schedule:
    """Rectified-flow linear interpolation: ``x_t = (1-t) x0 + t eps``."""
    return Schedule(
        name="linear",
        alpha=lambda t: 1.0 - t,
        sigma=lambda t: jnp.asarray(t, jnp.result_type(t, 0.0)),
        dalpha=lambda t: jnp.full_like(jnp.asarray(t, jnp.float32), -1.0),
        dsigma=lambda t: jnp.full_like(jnp.asarray(t, jnp.float32), 1.0),
        variance_preserving=False,
    )


def cosine_schedule() -> Schedule:
    """Cosine VP schedule (Eq. 26/27)."""
    half_pi = jnp.pi / 2.0
    return Schedule(
        name="cosine",
        alpha=lambda t: jnp.cos(half_pi * t),
        sigma=lambda t: jnp.sin(half_pi * t),
        dalpha=lambda t: -half_pi * jnp.sin(half_pi * t),
        dsigma=lambda t: half_pi * jnp.cos(half_pi * t),
        variance_preserving=True,
    )


_REGISTRY: dict[str, Callable[[], Schedule]] = {
    "linear": linear_schedule,
    "cosine": cosine_schedule,
}


def get_schedule(name: str) -> Schedule:
    try:
        return _REGISTRY[name]()
    except KeyError as e:  # pragma: no cover - config error
        raise ValueError(
            f"unknown schedule {name!r}; available: {sorted(_REGISTRY)}"
        ) from e


def register_schedule(name: str, factory: Callable[[], Schedule]) -> None:
    """Extension hook (paper §5 limitation iii — more objective families)."""
    _REGISTRY[name] = factory


def to_ddpm_timestep(t: Array, num_timesteps: int = NUM_DDPM_TIMESTEPS) -> Array:
    """Eq. 21 — map continuous ``t in [0,1]`` to the discrete DiT table index.

    ``t_DiT = round(999 t)`` clipped to ``[0, 999]``.  Integer inputs are
    assumed to already be table indices (DDPM experts) and pass through.
    """
    t = jnp.asarray(t)
    if jnp.issubdtype(t.dtype, jnp.integer):
        return jnp.clip(t, 0, num_timesteps - 1)
    idx = jnp.round((num_timesteps - 1) * t)
    return jnp.clip(idx, 0, num_timesteps - 1).astype(jnp.int32)


def from_ddpm_timestep(idx: Array, num_timesteps: int = NUM_DDPM_TIMESTEPS) -> Array:
    """Inverse of :func:`to_ddpm_timestep` (continuous grid point)."""
    return jnp.asarray(idx, jnp.float32) / float(num_timesteps - 1)


def snr_matched_time(
    source: Schedule, target: Schedule, t: Array, *, iters: int = 40
) -> Array:
    """Find ``t'`` such that ``target.snr(t') == source.snr(t)``.

    Beyond-paper utility: the paper queries heterogeneous experts at the
    *same* native time (identity time map).  Matching the noise level
    (log-SNR) between the sampling path's schedule and the expert's training
    schedule is a more principled alignment; we expose it as an optional
    ``time_map='snr_match'`` in the ensemble sampler.  Solved by bisection
    (both families have monotone SNR in t).
    """
    want = jnp.log(source.snr(t) + 1e-20)

    lo = jnp.zeros_like(jnp.asarray(t, jnp.float32))
    hi = jnp.ones_like(lo)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        got = jnp.log(target.snr(mid) + 1e-20)
        # SNR decreases with t: got > want -> need larger t.
        go_right = got > want
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)
