"""Compute-sparse fused ODE sampling with heterogeneous experts (Fig. 2, §3).

The unified sampler integrates the data-to-noise velocity *backwards*
(t = 1 → 0) with Euler steps: ``x_{t-Δt} = x_t − v · Δt`` (Eq. 8 remark).
All experts — DDPM or FM — contribute through the common velocity space.

Serving hot path (the paper's central efficiency claim, §3.1): Top-K /
threshold routing means inference only pays for the *selected* experts.
Three mechanisms realize that here:

* **batched CFG** — the conditional and unconditional branches are stacked
  along the batch axis (null conditioning expressed via the model's
  ``drop_mask``), so guidance costs one expert forward instead of two;
* **routed-expert-only execution** — homogeneous-architecture expert
  params stack into a typed ``core.param_store.ExpertParamStore``
  (dense, or int8/fp8-quantized via ``SamplerConfig.param_dtype`` with
  dequant fused into the hot path) and each step builds a
  ``core.dispatch.DispatchPlan`` from the router posterior, then
  executes only the routed experts through a pluggable
  ``ExpertExecutor`` backend (``SamplerConfig.dispatch``): per-sample
  gather+vmap (``gathered``), sort-based grouped segment execution
  (``grouped``), or the heterogeneous dense fallback (``dense``);
* **fused convert-and-fuse** — the per-step (alpha, sigma, dalpha, dsigma,
  vscale) conversion coefficients are tabulated once per run key
  (``coeff_tables_cached``, a process-wide cache over
  ``conversion.unified_coeff_tables``) and the ε→v conversion + Eq. 1
  weighting run as a single ``kernels.ops.fused_velocity`` kernel call
  (Pallas on TPU, oracle elsewhere);
* **step fusion** — with ``SamplerConfig.step_fused`` (the default) the
  CFG combine ``u_u + s·(u_c − u_u)`` and the Euler update ``x ← x − u·dt``
  fold INTO that kernel (``kernels.ops.fused_step``): executors hand back
  per-branch routed predictions and one kernel launch reads the latent
  once and writes the updated latent once per step;
* **plan reuse** — ``SamplerConfig.plan_refresh_every`` recomputes the
  router posterior + ``DispatchPlan`` only every R-th step, carrying the
  plan through the scan (posteriors change slowly in t); R=1 is
  bit-identical to per-step routing.

The dense all-experts path is kept as an automatic fallback for expert
sets the sparse engine cannot stack (heterogeneous ``apply_fn``s) and the
original per-expert reference path remains available (``engine=
"reference"``) for parity testing and the ``snr_match`` time map.

Also provided: classifier-free guidance (train-time drop prob 0.1, learned
null embeddings — §2.5), the native DDPM ancestral sampler (Table 3 "Native
DDPM" row), and the deterministic two-expert threshold sampler (§3.3).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.conversion import ConversionConfig, unified_coeff_tables
from repro.core.dispatch import (
    full_dispatch_plan,
    make_dispatch_plan,
    make_executor,
    plan_from_slots,
    resolve_dispatch,
    routed_slots,
    slot_coef,
    slot_coef_rows,
)
from repro.kernels import ops
from repro.core.fusion import (
    ExpertSpec,
    fuse_predictions,
    fusion_weights,
    unified_expert_velocities,
)
from repro.core.param_store import as_store, make_store
from repro.core.schedules import get_schedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Inference settings.  Paper defaults: aligned = (7.5, 50); conversion
    study = (6.0, 75)."""

    num_steps: int = 50
    cfg_scale: float = 7.5
    strategy: str = "topk"          # 'top1' | 'topk' | 'full' | 'threshold'
    top_k: int = 2
    threshold: float = 0.5          # for strategy='threshold'
    #: default_factory (not a class-level instance) so every config owns
    #: its conversion settings; with frozen=True on both dataclasses the
    #: pair stays hashable by construction — serving jit-cache keys depend
    #: on that.
    conversion: ConversionConfig = dataclasses.field(
        default_factory=ConversionConfig
    )
    #: identity (paper) or snr_match (beyond-paper time alignment)
    time_map: str = "identity"
    #: §7.3 finding: ε→v conversion is only stable at low noise.  If > 0,
    #: DDPM experts' routing weights are zeroed for t above this value
    #: (renormalized over the remaining experts).
    ddpm_low_noise_only: float = 0.0
    #: stack cond/uncond along the batch axis so CFG costs one forward.
    #: Requires apply_fns that accept ``drop_mask`` when the null branch
    #: uses a model-internal null embedding; automatically falls back to
    #: the two-pass formulation when the cond dicts cannot be batched.
    batched_cfg: bool = True
    #: expert-dispatch backend for routed execution (``core.dispatch``):
    #: 'auto' (grouped when params stack — 1.22x faster per
    #: BENCH_sampler.json and bounded by resident experts; gathered for
    #: batch-uniform threshold plans; dense otherwise) | 'gathered'
    #: (per-sample param gather + vmap) | 'grouped' (sort-based grouped
    #: segment execution, one forward per resident expert) | 'dense'
    #: (every expert via its own apply_fn).
    dispatch: str = "auto"
    #: storage dtype of the stacked expert params
    #: (``core.param_store.PARAM_DTYPES``): 'native' keeps checkpoint
    #: precision (bit-identical DenseStore — the default), 'fp32'/'bf16'
    #: cast dense storage, 'int8'/'fp8' quantize with per-expert
    #: symmetric scales and dequantize routed slices through the fused
    #: ``hetero_fuse_dequant`` Pallas kernel (~4x / ~4x fewer resident
    #: expert-param bytes vs fp32).
    param_dtype: str = "native"
    #: fold the CFG combine and the Euler update into the convert-and-
    #: fuse kernel (``kernels.ops.fused_step``), so one sampling step
    #: costs one fused kernel launch — the latent is read once and the
    #: updated latent written once per step instead of round-tripping
    #: through HBM for ``fused_velocity`` → ``cfg_combine`` → ``x − u·dt``.
    #: The fused engines only; the reference engine ignores it.  False
    #: keeps the unfused three-op chain (parity baseline, benchmarks).
    step_fused: bool = True
    #: recompute the router posterior + ``DispatchPlan`` only every R-th
    #: Euler step, carrying the plan through the scan in between — the
    #: ROADMAP "KV/latent caching" observation that router posteriors
    #: change slowly in t.  R=1 (default) refreshes every step and is
    #: bit-identical to per-step routing; R>1 trades bounded sampler
    #: drift (tracked in ``BENCH_sampler.json`` ``plan_reuse``) for
    #: skipping the router forward and the ``B·k`` argsort on the other
    #: R−1 of every R steps.  Fused engines only; the reference engine
    #: rejects R>1.
    plan_refresh_every: int = 1


def cfg_combine(cond_pred: Array, uncond_pred: Array, scale: float) -> Array:
    """Classifier-free guidance: ``u + s (c - u)``."""
    return uncond_pred + scale * (cond_pred - uncond_pred)


# ---------------------------------------------------------------------------
# Engine selection
# ---------------------------------------------------------------------------


def params_are_stackable(params: Sequence) -> bool:
    """True when every expert's param pytree has identical structure and
    leaf shapes/dtypes — the precondition for stacked-params dispatch."""
    if len(params) <= 1:
        return True
    try:
        t0 = jax.tree.structure(params[0])
        l0 = jax.tree.leaves(params[0])
        for p in params[1:]:
            if jax.tree.structure(p) != t0:
                return False
            lp = jax.tree.leaves(p)
            for a, b in zip(l0, lp):
                a, b = jnp.asarray(a), jnp.asarray(b)
                if a.shape != b.shape or a.dtype != b.dtype:
                    return False
    except Exception:
        return False
    return True


def _resolve_engine(
    engine: str,
    experts: Sequence[ExpertSpec],
    params: Sequence | None,
    config: SamplerConfig,
) -> str:
    if engine not in ("auto", "routed", "dense", "reference"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "reference":
        if config.dispatch != "auto":
            raise ValueError(
                "the reference engine predates the dispatch API; use "
                "dispatch='auto' (executor backends apply to the fused "
                "engines only)"
            )
        if config.plan_refresh_every != 1:
            raise ValueError(
                "plan_refresh_every > 1 requires the fused engines (the "
                "reference path recomputes routing every step by design)"
            )
        return engine
    if config.time_map != "identity":
        # snr_match queries experts at rebased times/inputs — only the
        # per-expert reference path implements it.
        if engine != "auto":
            raise ValueError(
                f"engine={engine!r} requires time_map='identity'"
            )
        if config.dispatch != "auto":
            # fail loudly rather than silently running the reference path
            # while the caller believes an executor backend is in effect.
            raise ValueError(
                f"dispatch={config.dispatch!r} requires time_map="
                f"'identity'; snr_match resolves to the reference engine, "
                f"which predates the dispatch API"
            )
        if config.plan_refresh_every != 1:
            raise ValueError(
                "plan_refresh_every > 1 requires time_map='identity'; "
                "snr_match resolves to the reference engine, which "
                "recomputes routing every step by design"
            )
        return "reference"
    K = len(experts)
    # params=None means the caller holds stacked params only as an
    # ExpertParamStore (e.g. a quantized serving engine that dropped the
    # full-precision per-expert list); a store is stackable by
    # construction.
    homogeneous = K == 1 or (
        all(e.apply_fn is experts[0].apply_fn for e in experts)
        and (params is None or params_are_stackable(params))
    )
    routed_ok = K > 1 and (
        (config.strategy in ("top1", "topk") and homogeneous)
        or config.strategy == "threshold"
    )
    if engine == "auto":
        return "routed" if routed_ok else "dense"
    if engine == "routed" and not routed_ok:
        raise ValueError(
            "routed engine needs strategy in (top1, topk, threshold) and, "
            "for per-sample routing, a shared apply_fn with stackable params"
        )
    return engine


# ---------------------------------------------------------------------------
# Batched classifier-free guidance
# ---------------------------------------------------------------------------


def _cfg_batchable(cond: dict, null_cond: dict) -> bool:
    """Can the cond/uncond branches be expressed as one doubled batch?"""
    if "drop_mask" in cond or "drop_mask" in null_cond:
        return False
    for k, v in null_cond.items():
        if v is not None and cond.get(k) is None:
            return False
    return True


def _cfg_grouped_cond(cond: dict, null_cond: dict | None, batch: int) -> dict:
    """Per-sample CFG-branch conditioning: leaves gain a ``(B, G, ...)``
    group axis (G=2 cond/uncond, G=1 without guidance batching).

    This is the conditioning form every ``ExpertExecutor`` backend
    receives: the gathered backend runs both guidance branches inside one
    vmapped instance (params gathered once, not per branch); the grouped
    and dense backends flatten the group axis branch-major, recovering
    the classic ``[cond; uncond]`` concatenated batch.
    """
    if null_cond is None:
        return {
            k: v[:, None] for k, v in cond.items() if v is not None
        }
    out: dict = {}
    need_drop = False
    for key in sorted(set(cond) | set(null_cond)):
        c, n = cond.get(key), null_cond.get(key)
        if c is None and n is None:
            continue
        if n is None:
            out[key] = jnp.stack([c, c], axis=1)
            need_drop = True
        else:
            out[key] = jnp.stack(
                [jnp.asarray(c), jnp.asarray(n)], axis=1
            )
    if need_drop:
        out["drop_mask"] = jnp.broadcast_to(
            jnp.array([False, True])[None], (batch, 2)
        )
    return out


# ---------------------------------------------------------------------------
# Fused compute-sparse engine
# ---------------------------------------------------------------------------


def _stack_params(params: Sequence):
    if len(params) == 1:
        return jax.tree.map(lambda x: jnp.asarray(x)[None], params[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params)


@functools.lru_cache(maxsize=128)
def _time_grid(num_steps: int) -> Array:
    """Euler time grid ``linspace(1, 0, S+1)`` as a host-side constant.

    Computed eagerly (compile-time) and cached so every jit program —
    the lockstep scan and the stepwise continuous-batching entry —
    embeds the *same bytes*.  ``jnp.linspace`` traced inside a program
    can constant-fold to values 1 ulp away from its eager result
    depending on the surrounding graph, which would silently break the
    bitwise scan-vs-stepwise parity the rolling batch is built on.
    """
    with jax.ensure_compile_time_eval():
        return jnp.linspace(1.0, 0.0, num_steps + 1)


@functools.lru_cache(maxsize=128)
def coeff_tables_cached(
    objectives: tuple[str, ...],
    schedule_names: tuple[str, ...],
    num_steps: int,
    conv: ConversionConfig,
) -> Array:
    """Per-run ``unified_coeff_tables`` result, cached by its run key.

    The ``(S, 5, K)`` table depends only on static run parameters —
    expert objectives/schedules, the step count and the conversion
    config — yet was rebuilt (K schedule sweeps + stacking) on every
    sampler trace.  A long-lived ``ServingEngine`` retraces per (batch,
    shape, conditioning) cache entry, so identical tables were being
    recomputed per entry; this cache builds each distinct table once per
    process.  All key parts are hashable by construction
    (``ConversionConfig`` is frozen).
    """
    # The first call usually happens INSIDE a sampler trace;
    # ensure_compile_time_eval forces concrete (non-tracer) arrays so the
    # cached table is safe to reuse across traces.
    with jax.ensure_compile_time_eval():
        ts = jnp.linspace(1.0, 0.0, num_steps + 1)[:-1]
        return unified_coeff_tables(
            list(objectives),
            [get_schedule(name) for name in schedule_names],
            ts, conv,
        )


def _sample_fused(
    key: jax.Array,
    experts: Sequence[ExpertSpec],
    params: Sequence,
    router_fn,
    shape: tuple[int, ...],
    cond: dict,
    null_cond: dict | None,
    config: SamplerConfig,
    mode: str,
    init_noise: Array | None,
    stacked_params=None,
    latent_sharding=None,
    plan_sharding=None,
    coeff_tables=None,
    cluster_map=None,
) -> Array:
    K = len(experts)
    B = shape[0]
    conv = config.conversion
    homogeneous = all(e.apply_fn is experts[0].apply_fn for e in experts)

    use_cfg = null_cond is not None and config.cfg_scale != 1.0
    batched = (
        use_cfg and config.batched_cfg
        and _cfg_batchable(cond, null_cond or {})
    )

    if mode == "routed":
        k_slots = 1 if config.strategy in ("top1", "threshold") \
            else min(config.top_k, K)
        uniform = config.strategy == "threshold"
    else:
        k_slots, uniform = K, False

    # Routed dispatch substrate, resolved to a typed ExpertParamStore
    # (core.param_store): callers that keep long-lived stacked params
    # (ServingEngine) pass a store — or the legacy raw stacked pytree —
    # in; otherwise the per-expert list stacks once per trace, into the
    # storage dtype requested by ``config.param_dtype`` (quantized stores
    # dequantize routed slices through the fused hetero_fuse_dequant
    # kernel).  _resolve_engine already guaranteed stackability for
    # per-sample routing; the batch-uniform threshold path re-checks
    # because it also serves heterogeneous expert sets (via the dense
    # executor's switch).
    stacked = as_store(stacked_params, dtype=config.param_dtype)
    # Elastic membership (capacity stores): the liveness mask is traced
    # data riding the store, so an eviction/hot-add reaches this engine as
    # new argument *values* under the same trace — no recompile.
    valid = getattr(stacked, "valid", None)
    if stacked is None and params is None:
        raise ValueError(
            "params=None requires stacked_params (an ExpertParamStore or "
            "raw stacked pytree)"
        )
    if stacked is None and mode == "routed" and homogeneous and (
        not uniform or params_are_stackable(params)
    ):
        stacked = make_store(_stack_params(params),
                             dtype=config.param_dtype)

    # Pluggable expert-dispatch backend (core.dispatch): the executor owns
    # HOW routed forwards run; the plan built per step owns WHICH experts
    # run; CFG orchestration below is shared across all backends.
    # Ragged eligibility: every expert must publish the SAME pair-major
    # ragged forward (ExpertSpec.ragged_apply_fn) — the one-kernel backend
    # gathers weights per (sample, slot) pair, so a single shared forward
    # is a structural requirement, mirroring the homogeneous-apply_fn rule.
    ragged_fn = getattr(experts[0], "ragged_apply_fn", None)
    ragged_ok = (
        mode == "routed" and not uniform and ragged_fn is not None
        and all(getattr(e, "ragged_apply_fn", None) is ragged_fn
                for e in experts)
    )
    backend = resolve_dispatch(
        config.dispatch, mode, stacked is not None, uniform, ragged_ok,
    )
    executor = make_executor(
        backend,
        apply_fns=[e.apply_fn for e in experts],
        params=params,
        stacked_params=stacked,
        conv=conv,
        ragged_apply_fn=ragged_fn if ragged_ok else None,
    )

    x = init_noise if init_noise is not None \
        else jax.random.normal(key, shape, dtype=jnp.float32)
    if latent_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, latent_sharding)
    ts = _time_grid(config.num_steps)
    # Schedule-coefficient tables: computed ONCE per run key (cached
    # process-wide, so serving retraces reuse them), gathered per step.
    # Elastic engines instead pass ``coeff_tables`` as a traced argument:
    # a hot-added expert may change a capacity slot's objective/schedule,
    # which must reach the sampler as new table *values*, not a new trace.
    if coeff_tables is not None:
        tables = coeff_tables                             # (S, 5, K)
    else:
        tables = coeff_tables_cached(
            tuple(e.objective for e in experts),
            tuple(e.schedule for e in experts),
            config.num_steps, conv,
        )                                                 # (S, 5, K)

    refresh_every = int(config.plan_refresh_every)
    if refresh_every < 1:
        raise ValueError(
            f"plan_refresh_every must be >= 1, got {refresh_every}"
        )

    def make_plan(w):
        if backend == "dense" and not uniform:
            plan = full_dispatch_plan(w)
        else:
            plan = make_dispatch_plan(w, k_slots, uniform=uniform,
                                      valid=valid)
        if plan_sharding is not None:
            # Sharded serving: routing metadata replicates across the mesh
            # (every shard needs the full plan to slice its resident
            # experts' groups); see launch.sharding.dispatch_plan_sharding.
            plan = jax.tree.map(
                lambda a: jax.lax.with_sharding_constraint(a, plan_sharding),
                plan,
            )
        return plan

    def routed_plan(x, tb):
        w = fusion_weights(
            experts, router_fn, x, tb,
            strategy=config.strategy, top_k=config.top_k,
            threshold=config.threshold,
            ddpm_low_noise_only=config.ddpm_low_noise_only,
            valid=valid, cluster_map=cluster_map,
        )                                                 # (B, K)
        return make_plan(w)

    def velocity_update(plan, x, tb, dt, tab):
        # Unfused three-op chain: fused velocity, CFG combine, Euler —
        # each a latent-sized HBM round-trip (parity/bench baseline).
        if batched:
            cond_g = _cfg_grouped_cond(cond, null_cond or {}, B)
            fused = executor.velocity(plan, x, tb, cond_g, 2, tab)
            u = cfg_combine(fused[:B], fused[B:], config.cfg_scale)
        elif use_cfg:
            u_c = executor.velocity(
                plan, x, tb, _cfg_grouped_cond(cond, None, B), 1, tab)
            u_u = executor.velocity(
                plan, x, tb,
                _cfg_grouped_cond(dict(null_cond or {}), None, B), 1, tab)
            u = cfg_combine(u_c, u_u, config.cfg_scale)
        else:
            u = executor.velocity(
                plan, x, tb, _cfg_grouped_cond(cond, None, B), 1, tab)
        return x - u * dt

    def fused_step_update(plan, x, tb, dt, tab):
        # Step-fused hot path: the executor hands back per-branch routed
        # predictions and ONE kernel (kernels.ops.fused_step) does the
        # convert-and-fuse, CFG combine and Euler update — the latent is
        # read once and written once; no velocity materializes in HBM.
        if batched:
            cond_g = _cfg_grouped_cond(cond, null_cond or {}, B)
            preds, w_all, idx_all = executor.predictions(
                plan, x, tb, cond_g, 2, tab)
            g, scale = 2, config.cfg_scale
        elif use_cfg:
            p_c, w1, i1 = executor.predictions(
                plan, x, tb, _cfg_grouped_cond(cond, None, B), 1, tab)
            p_u, _, _ = executor.predictions(
                plan, x, tb,
                _cfg_grouped_cond(dict(null_cond or {}), None, B), 1, tab)
            # branch-major [cond; uncond], the layout batched CFG emits
            preds = jnp.concatenate([p_c, p_u], axis=1)
            w_all = jnp.concatenate([w1, w1], axis=0)
            idx_all = jnp.concatenate([i1, i1], axis=0)
            g, scale = 2, config.cfg_scale
        else:
            preds, w_all, idx_all = executor.predictions(
                plan, x, tb, _cfg_grouped_cond(cond, None, B), 1, tab)
            g, scale = 1, 1.0
        return ops.fused_step(
            preds, x, w_all, slot_coef(tab, idx_all), dt,
            g=g, cfg_scale=scale,
            clamp=conv.clamp, alpha_min=conv.alpha_min,
        )

    update = fused_step_update if config.step_fused else velocity_update

    def advance(plan, x, i):
        t_hi, t_lo = ts[i], ts[i + 1]
        tb = jnp.full((B,), t_hi)
        x = update(plan, x, tb, t_hi - t_lo, tables[i])
        if latent_sharding is not None:
            # Pin the evolving latent's batch dim to the mesh "data" axis
            # every step — without the constraint GSPMD may re-replicate
            # the batch through the routed param resolution and serialize
            # the data-parallel shards.  On the step-fused path this is
            # the constraint on the fused kernel's output.
            x = jax.lax.with_sharding_constraint(x, latent_sharding)
        return x

    if refresh_every == 1:

        def step(x, i):
            plan = routed_plan(x, jnp.full((B,), ts[i]))
            return advance(plan, x, i), None

        x, _ = jax.lax.scan(step, x, jnp.arange(config.num_steps))
    else:
        # Plan reuse: routing (router forward + top-k + the grouped
        # argsort, all inside routed_plan) runs only on refresh steps;
        # in between, the registered-pytree DispatchPlan rides the scan
        # carry.  lax.cond executes a single branch at run time, so
        # non-refresh steps pay zero routing compute.
        def step(carry, i):
            x, plan = carry
            plan = jax.lax.cond(
                i % refresh_every == 0,
                lambda: routed_plan(x, jnp.full((B,), ts[i])),
                lambda: plan,
            )
            return (advance(plan, x, i), plan), None

        # Structural placeholder only — step 0 always refreshes.
        init_plan = make_plan(jnp.zeros((B, K), jnp.float32))
        (x, _), _ = jax.lax.scan(
            step, (x, init_plan), jnp.arange(config.num_steps)
        )
    return x


# ---------------------------------------------------------------------------
# Reference (per-expert, all-experts, two-pass CFG) path
# ---------------------------------------------------------------------------


def _expert_velocities_with_cfg(
    experts: Sequence[ExpertSpec],
    params: Sequence,
    x_t: Array,
    t: Array,
    cond: dict,
    null_cond: dict | None,
    cfg: SamplerConfig,
) -> Array:
    v_c = unified_expert_velocities(
        experts, params, x_t, t, cond, conv_cfg=cfg.conversion,
        time_map=cfg.time_map,
    )
    if null_cond is None or cfg.cfg_scale == 1.0:
        return v_c
    v_u = unified_expert_velocities(
        experts, params, x_t, t, null_cond, conv_cfg=cfg.conversion,
        time_map=cfg.time_map,
    )
    return cfg_combine(v_c, v_u, cfg.cfg_scale)


def _sample_reference(
    key: jax.Array,
    experts: Sequence[ExpertSpec],
    params: Sequence,
    router_fn,
    shape: tuple[int, ...],
    cond: dict,
    null_cond: dict | None,
    config: SamplerConfig,
    init_noise: Array | None,
) -> Array:
    x = init_noise if init_noise is not None \
        else jax.random.normal(key, shape, dtype=jnp.float32)
    ts = jnp.linspace(1.0, 0.0, config.num_steps + 1)

    def step(x, i):
        t_hi, t_lo = ts[i], ts[i + 1]
        dt = t_hi - t_lo
        tb = jnp.full((shape[0],), t_hi)
        v = _expert_velocities_with_cfg(
            experts, params, x, tb, cond, null_cond, config
        )
        w = fusion_weights(
            experts, router_fn, x, tb,
            strategy=config.strategy, top_k=config.top_k,
            threshold=config.threshold,
            ddpm_low_noise_only=config.ddpm_low_noise_only,
        )
        u = fuse_predictions(v, w)
        return x - u * dt, None

    x, _ = jax.lax.scan(step, x, jnp.arange(config.num_steps))
    return x


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def sample_ensemble(
    key: jax.Array,
    experts: Sequence[ExpertSpec],
    params: Sequence | None,
    router_fn: Callable[[Array, Array], Array] | None,
    shape: tuple[int, ...],
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    config: SamplerConfig | None = None,
    engine: str = "auto",
    init_noise: Array | None = None,
    stacked_params=None,
    latent_sharding=None,
    plan_sharding=None,
    coeff_tables=None,
    cluster_map=None,
) -> Array:
    """Euler-ODE sampling with router-weighted heterogeneous fusion.

    Args:
      router_fn: ``(x_t, t) -> (B, K) posterior``; may be None only for
        single-expert sampling or the threshold strategy.
      shape: sample shape ``(B, ...)`` in latent space.
      engine: ``'auto'`` picks the compute-sparse routed engine when the
        strategy and expert set allow it, falling back to the dense
        fused engine otherwise; ``'routed'`` / ``'dense'`` force a path;
        ``'reference'`` is the original per-expert two-pass formulation
        (required for ``time_map='snr_match'``, kept for parity tests).
      init_noise: optional pre-drawn ``N(0,1)`` latents of ``shape`` (lets
        serving donate the buffer); drawn from ``key`` when omitted.
      stacked_params: optional pre-stacked expert params — an
        ``ExpertParamStore`` (``core.param_store``; quantized stores keep
        int8/fp8 leaves resident and dequantize routed slices through the
        fused kernel) or the legacy raw stacked pytree (leaves
        ``(K, ...)``, see ``models.dit.stack_expert_params``) — so
        long-lived engines don't re-stack per compiled cache entry.  May
        arrive device_put on an ("expert", "data") mesh — the routed
        gather then resolves via an all-gather of the selected experts'
        shards (expert-parallel serving, ``launch.serve``).  When given,
        ``params`` may be None (routed execution only).
      latent_sharding: optional ``NamedSharding`` for the evolving latent
        state; the fused engine re-constrains x to it every Euler step so
        the batch stays on the mesh "data" axis under sharded serving.
      plan_sharding: optional ``NamedSharding`` for the per-step
        ``DispatchPlan`` arrays (typically replicated — see
        ``launch.sharding.dispatch_plan_sharding``) so routing metadata
        never forces collectives inside the executor's expert branches.
      coeff_tables: optional pre-built ``(S, 5, K)`` unified-coefficient
        tables *as traced data* — elastic serving passes them so a
        hot-added expert's objective/schedule reaches the sampler as new
        values instead of a retrace; omitted, they come from the static
        per-``ExpertSpec`` ``coeff_tables_cached`` path (fused engines
        only — the reference engine derives coefficients per expert).
      cluster_map: optional ``(K,)`` int cluster-id-per-slot array, the
        traced counterpart of ``ExpertSpec.cluster_id`` for elastic
        engines (see ``fusion.fusion_weights``); fused engines only.

    ``stacked_params`` carrying an ``ExpertParamStore`` with a ``valid``
    liveness mask (``param_store.pad_to_capacity``) makes the fused
    engines membership-aware: routing renormalizes over live slots only
    and dispatch never gathers or runs a dead slot's params.

    Returns samples at t=0 (clean latents).
    """
    cond = cond or {}
    config = config if config is not None else SamplerConfig()
    mode = _resolve_engine(engine, experts, params, config)
    if params is None and mode == "reference":
        raise ValueError(
            "the reference engine runs each expert from its own params "
            "list; params=None (store-only serving) supports the fused "
            "engines only"
        )
    if mode == "reference":
        if coeff_tables is not None or cluster_map is not None:
            raise ValueError(
                "coeff_tables/cluster_map (elastic membership) require "
                "the fused engines; the reference engine derives "
                "coefficients from the static ExpertSpec list"
            )
        return _sample_reference(
            key, experts, params, router_fn, shape, cond, null_cond,
            config, init_noise,
        )
    return _sample_fused(
        key, experts, params, router_fn, shape, cond, null_cond, config,
        mode, init_noise, stacked_params, latent_sharding, plan_sharding,
        coeff_tables, cluster_map,
    )


def sample_ensemble_step(
    experts: Sequence[ExpertSpec],
    params: Sequence | None,
    router_fn: Callable[[Array, Array], Array] | None,
    x: Array,
    t_idx: Array,
    slot_idx: Array,
    slot_w: Array,
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    config: SamplerConfig | None = None,
    engine: str = "auto",
    stacked_params=None,
    latent_sharding=None,
    plan_sharding=None,
    coeff_tables=None,
    cluster_map=None,
) -> tuple[Array, Array, Array, Array]:
    """One Euler step of a *mixed-timestep* batch (continuous batching).

    The stepwise counterpart of :func:`sample_ensemble`'s fused scan: the
    unit of work is one step of each resident row, where every row sits
    at its **own** position ``t_idx[r]`` on the shared ``num_steps``-step
    Euler grid.  The per-run ``(S, 5, K)`` coefficient tables are already
    per-step lookups, so a mixed batch is a *gather* (``tables[t_idx]``,
    per-row ``ts``/``dt``) feeding the same ``kernels.ops.fused_step``
    launch — not a retrace and not a second kernel.  `repro.serving`
    drives this in a rolling batch where requests join and leave at step
    boundaries.

    Row state (all ``(B, ...)``-leading, carried by the caller across
    steps):

    * ``x`` — current latents;
    * ``t_idx`` — int32 step index per row: ``0 <= t_idx < num_steps``
      is an active row, ``num_steps`` (or any out-of-range value) marks
      a finished/empty row, which is frozen: its latent passes through
      unchanged and its ``t_idx`` does not advance;
    * ``slot_idx``/``slot_w`` — ``(B, k)`` carried routing slots
      (``core.dispatch.routed_slots``), refreshed per row on the row's
      own ``plan_refresh_every`` phase (``t_idx % R == 0``), so each
      request carries its own R-phase exactly as the lockstep scan does.

    Bitwise parity with the sequential scan rests on batch-row
    independence: the router and expert forwards compute row ``r``'s
    outputs from row ``r``'s inputs only (the same property `flush()`
    coalescing already relies on), and the fused-step kernel is
    elementwise per row with per-row ``dt``/coefficients.  A row
    advancing from ``t_idx = i`` therefore sees exactly the values the
    lockstep scan's step ``i`` would feed it, whatever its neighbors are
    doing — proven bitwise in ``tests/test_continuous.py``.

    Restrictions (fail loudly): routed engine, ``strategy`` in
    ``('top1', 'topk')``, ``step_fused=True`` — threshold/uniform plans
    collapse routing to a batch-global scalar gather, which has no
    per-row meaning in a mixed batch.

    Returns the advanced ``(x, t_idx, slot_idx, slot_w)``.
    """
    cond = cond or {}
    config = config if config is not None else SamplerConfig()
    if config.strategy not in ("top1", "topk"):
        raise ValueError(
            f"continuous batching requires per-sample routing (strategy "
            f"in ('top1', 'topk')); strategy={config.strategy!r} plans "
            f"are batch-uniform or dense and have no per-row meaning in "
            f"a mixed-timestep batch"
        )
    if not config.step_fused:
        raise ValueError(
            "continuous batching runs on the step-fused hot path only "
            "(step_fused=True): per-row dt is a fused-kernel operand"
        )
    mode = _resolve_engine(engine, experts, params, config)
    if mode != "routed":
        raise ValueError(
            f"continuous batching requires the routed engine; this "
            f"configuration resolved to {mode!r} (need a shared apply_fn "
            f"with stackable params and >1 expert)"
        )

    K = len(experts)
    B = x.shape[0]
    conv = config.conversion
    k_slots = 1 if config.strategy == "top1" else min(config.top_k, K)
    if slot_idx.shape != (B, k_slots) or slot_w.shape != (B, k_slots):
        raise ValueError(
            f"slot state must be ({B}, {k_slots}); got "
            f"slot_idx {slot_idx.shape}, slot_w {slot_w.shape}"
        )
    slot_idx = slot_idx.astype(jnp.int32)
    slot_w = slot_w.astype(jnp.float32)
    t_idx = t_idx.astype(jnp.int32)

    use_cfg = null_cond is not None and config.cfg_scale != 1.0
    batched = (
        use_cfg and config.batched_cfg
        and _cfg_batchable(cond, null_cond or {})
    )

    # Dispatch substrate — identical to _sample_fused's resolution.
    stacked = as_store(stacked_params, dtype=config.param_dtype)
    if stacked is None and params is None:
        raise ValueError(
            "params=None requires stacked_params (an ExpertParamStore or "
            "raw stacked pytree)"
        )
    if stacked is None:
        stacked = make_store(_stack_params(params),
                             dtype=config.param_dtype)
    # Bitwise-parity guard: expert params that are trace literals (toy
    # closures, tests) must NOT constant-fold into the expert forward.
    # The lockstep scan's loop body already treats them as opaque loop
    # inputs, so folding here (a loop-free program) would reassociate
    # constant adds — e.g. fma(x, a, b) + c vs fma(x, a, b + c) — and
    # break rolling == lockstep at the ulp level.  Real checkpoints
    # arrive as jit arguments and are unaffected.
    stacked = jax.tree.map(jax.lax.optimization_barrier, stacked)
    valid = getattr(stacked, "valid", None)
    ragged_fn = getattr(experts[0], "ragged_apply_fn", None)
    ragged_ok = ragged_fn is not None and all(
        getattr(e, "ragged_apply_fn", None) is ragged_fn for e in experts
    )
    backend = resolve_dispatch(config.dispatch, mode, True, False, ragged_ok)
    executor = make_executor(
        backend,
        apply_fns=[e.apply_fn for e in experts],
        params=params,
        stacked_params=stacked,
        conv=conv,
        ragged_apply_fn=ragged_fn if ragged_ok else None,
    )

    S = config.num_steps
    ts = _time_grid(S)
    if coeff_tables is not None:
        tables = coeff_tables                             # (S, 5, K)
    else:
        tables = coeff_tables_cached(
            tuple(e.objective for e in experts),
            tuple(e.schedule for e in experts),
            S, conv,
        )
    num_slots = tables.shape[-1]                          # capacity K

    refresh_every = int(config.plan_refresh_every)
    if refresh_every < 1:
        raise ValueError(
            f"plan_refresh_every must be >= 1, got {refresh_every}"
        )

    # Per-row grid state: finished/empty rows clip to a valid index (the
    # gathered values are discarded by the `active` mask below).
    i = jnp.clip(t_idx, 0, S - 1)                         # (B,)
    active = (t_idx >= 0) & (t_idx < S)                   # (B,)
    tb = ts[i]                                            # (B,)
    dt = ts[i] - ts[i + 1]                                # (B,)
    row_tab = tables[i]                                   # (B, 5, K)

    # Per-request R-phase: a row refreshes its routing slots on ITS OWN
    # refresh steps.  lax.cond skips the router forward entirely on
    # ticks where no resident row is at a refresh phase.
    refresh = active & (t_idx % refresh_every == 0)       # (B,)

    def fresh_slots():
        w = fusion_weights(
            experts, router_fn, x, tb,
            strategy=config.strategy, top_k=config.top_k,
            threshold=config.threshold,
            ddpm_low_noise_only=config.ddpm_low_noise_only,
            valid=valid, cluster_map=cluster_map,
        )                                                 # (B, K)
        return routed_slots(w, k_slots, valid=valid)

    new_idx, new_w = jax.lax.cond(
        jnp.any(refresh), fresh_slots, lambda: (slot_idx, slot_w)
    )
    slot_idx = jnp.where(refresh[:, None], new_idx, slot_idx)
    slot_w = jnp.where(refresh[:, None], new_w, slot_w)

    plan = plan_from_slots(slot_idx, slot_w, num_slots)
    if plan_sharding is not None:
        plan = jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(a, plan_sharding),
            plan,
        )

    # CFG orchestration mirrors _sample_fused.fused_step_update; the
    # `tab` executors receive is unused by `predictions` (only the
    # unfused `velocity` reads it), so a representative (5, K) slice
    # keeps the signature satisfied.
    tab0 = tables[0]
    if batched:
        cond_g = _cfg_grouped_cond(cond, null_cond or {}, B)
        preds, w_all, idx_all = executor.predictions(
            plan, x, tb, cond_g, 2, tab0)
        g, scale = 2, config.cfg_scale
    elif use_cfg:
        p_c, w1, i1 = executor.predictions(
            plan, x, tb, _cfg_grouped_cond(cond, None, B), 1, tab0)
        p_u, _, _ = executor.predictions(
            plan, x, tb,
            _cfg_grouped_cond(dict(null_cond or {}), None, B), 1, tab0)
        preds = jnp.concatenate([p_c, p_u], axis=1)
        w_all = jnp.concatenate([w1, w1], axis=0)
        idx_all = jnp.concatenate([i1, i1], axis=0)
        g, scale = 2, config.cfg_scale
    else:
        preds, w_all, idx_all = executor.predictions(
            plan, x, tb, _cfg_grouped_cond(cond, None, B), 1, tab0)
        g, scale = 1, 1.0
    # Per-row coefficient slices, tiled branch-major like the weights.
    tab_all = row_tab if g == 1 \
        else jnp.concatenate([row_tab, row_tab], axis=0)  # (g·B, 5, K)
    x_step = ops.fused_step(
        preds, x, w_all, slot_coef_rows(tab_all, idx_all), dt,
        g=g, cfg_scale=scale,
        clamp=conv.clamp, alpha_min=conv.alpha_min,
    )
    mask = active.reshape((B,) + (1,) * (x.ndim - 1))
    x = jnp.where(mask, x_step, x)
    if latent_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, latent_sharding)
    t_idx = t_idx + active.astype(jnp.int32)
    return x, t_idx, slot_idx, slot_w


def sample_single_expert(
    key: jax.Array,
    expert: ExpertSpec,
    params,
    shape: tuple[int, ...],
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    config: SamplerConfig | None = None,
) -> Array:
    """Single-expert ODE sampling (Table 3 'FM' and 'DDPM→FM' rows)."""
    config = config if config is not None else SamplerConfig()
    return sample_ensemble(
        key, [expert], [params], None, shape,
        cond=cond, null_cond=null_cond,
        config=dataclasses.replace(config, strategy="full"),
    )


def sample_ddpm_ancestral(
    key: jax.Array,
    apply_fn: Callable[..., Array],
    params,
    shape: tuple[int, ...],
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    num_steps: int = 75,
    cfg_scale: float = 6.0,
    schedule_name: str = "cosine",
) -> Array:
    """Native DDPM ancestral sampler (Table 3 baseline row).

    DDIM-style deterministic-σ=... we use the stochastic ancestral update
    with the VP cosine schedule, operating on the discrete grid.
    """
    cond = cond or {}
    sched = get_schedule(schedule_name)
    ts = jnp.linspace(1.0, 0.0, num_steps + 1)
    x = jax.random.normal(key, shape, dtype=jnp.float32)

    def pred_eps(x, tb):
        e_c = apply_fn(params, x, tb, **cond)
        if null_cond is None or cfg_scale == 1.0:
            return e_c
        e_u = apply_fn(params, x, tb, **null_cond)
        return cfg_combine(e_c, e_u, cfg_scale)

    def step(carry, i):
        x, key = carry
        key, nk = jax.random.split(key)
        t_hi, t_lo = ts[i], ts[i + 1]
        tb = jnp.full((shape[0],), t_hi)
        eps = pred_eps(x, tb)
        a_hi, s_hi = sched.coeffs(t_hi)
        a_lo, s_lo = sched.coeffs(t_lo)
        x0 = (x - s_hi * eps) / jnp.maximum(a_hi, 0.01)
        x0 = jnp.clip(x0, -20.0, 20.0)
        # DDIM (eta=0) update on the continuous grid.
        x_next = a_lo * x0 + s_lo * eps
        return (x_next, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), jnp.arange(num_steps))
    return x
