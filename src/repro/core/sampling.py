"""ODE sampling with heterogeneous expert fusion (paper Fig. 2, §3, §7).

The unified sampler integrates the data-to-noise velocity *backwards*
(t = 1 → 0) with Euler steps: ``x_{t-Δt} = x_t − v · Δt`` (Eq. 8 remark).
All experts — DDPM or FM — contribute through the common velocity space.

Also provided: classifier-free guidance (train-time drop prob 0.1, learned
null embeddings — §2.5), the native DDPM ancestral sampler (Table 3 "Native
DDPM" row), and the deterministic two-expert threshold sampler (§3.3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.conversion import ConversionConfig
from repro.core.fusion import (
    ExpertSpec,
    fuse_predictions,
    routing_weights,
    threshold_router_weights,
    unified_expert_velocities,
)
from repro.core.schedules import get_schedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Inference settings.  Paper defaults: aligned = (7.5, 50); conversion
    study = (6.0, 75)."""

    num_steps: int = 50
    cfg_scale: float = 7.5
    strategy: str = "topk"          # 'top1' | 'topk' | 'full' | 'threshold'
    top_k: int = 2
    threshold: float = 0.5          # for strategy='threshold'
    conversion: ConversionConfig = ConversionConfig()
    #: identity (paper) or snr_match (beyond-paper time alignment)
    time_map: str = "identity"
    #: §7.3 finding: ε→v conversion is only stable at low noise.  If > 0,
    #: DDPM experts' routing weights are zeroed for t above this value
    #: (renormalized over the remaining experts).
    ddpm_low_noise_only: float = 0.0


def cfg_combine(cond_pred: Array, uncond_pred: Array, scale: float) -> Array:
    """Classifier-free guidance: ``u + s (c - u)``."""
    return uncond_pred + scale * (cond_pred - uncond_pred)


def _expert_velocities_with_cfg(
    experts: Sequence[ExpertSpec],
    params: Sequence,
    x_t: Array,
    t: Array,
    cond: dict,
    null_cond: dict | None,
    cfg: SamplerConfig,
) -> Array:
    v_c = unified_expert_velocities(
        experts, params, x_t, t, cond, conv_cfg=cfg.conversion,
        time_map=cfg.time_map,
    )
    if null_cond is None or cfg.cfg_scale == 1.0:
        return v_c
    v_u = unified_expert_velocities(
        experts, params, x_t, t, null_cond, conv_cfg=cfg.conversion,
        time_map=cfg.time_map,
    )
    return cfg_combine(v_c, v_u, cfg.cfg_scale)


def sample_ensemble(
    key: jax.Array,
    experts: Sequence[ExpertSpec],
    params: Sequence,
    router_fn: Callable[[Array, Array], Array] | None,
    shape: tuple[int, ...],
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    config: SamplerConfig = SamplerConfig(),
) -> Array:
    """Euler-ODE sampling with router-weighted heterogeneous fusion.

    Args:
      router_fn: ``(x_t, t) -> (B, K) posterior``; may be None only for
        single-expert sampling or the threshold strategy.
      shape: sample shape ``(B, ...)`` in latent space.

    Returns samples at t=0 (clean latents).
    """
    cond = cond or {}
    K = len(experts)
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    ts = jnp.linspace(1.0, 0.0, config.num_steps + 1)

    def step(x, i):
        t_hi, t_lo = ts[i], ts[i + 1]
        dt = t_hi - t_lo
        tb = jnp.full((shape[0],), t_hi)
        v = _expert_velocities_with_cfg(
            experts, params, x, tb, cond, null_cond, config
        )
        if config.strategy == "threshold":
            w = threshold_router_weights(tb, K, threshold=config.threshold)
        else:
            if router_fn is None:
                if K != 1:
                    raise ValueError("router_fn required for multi-expert fusion")
                w = jnp.ones((shape[0], 1))
            else:
                probs = router_fn(x, tb)          # (B, num_clusters)
                # Map cluster posterior -> per-expert probs via each
                # expert's owned cluster (Eq. 1: p(k | x_t)).
                cluster_ids = jnp.array(
                    [max(e.cluster_id, 0) for e in experts]
                )
                if probs.shape[-1] != K or any(
                    e.cluster_id not in (-1, i)
                    for i, e in enumerate(experts)
                ):
                    probs = probs[:, cluster_ids]
                    probs = probs / jnp.maximum(
                        probs.sum(-1, keepdims=True), 1e-12
                    )
                w = routing_weights(probs, config.strategy, config.top_k)
        if config.ddpm_low_noise_only > 0.0:
            # §7.3: restrict converted-DDPM experts to low-noise steps.
            is_ddpm = jnp.array([e.objective == "ddpm" for e in experts])
            high_noise = tb > config.ddpm_low_noise_only        # (B,)
            gate = jnp.where(
                high_noise[:, None] & is_ddpm[None, :], 0.0, 1.0
            )
            w = w * gate
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)
        u = fuse_predictions(v, w)
        return x - u * dt, None

    x, _ = jax.lax.scan(step, x, jnp.arange(config.num_steps))
    return x


def sample_single_expert(
    key: jax.Array,
    expert: ExpertSpec,
    params,
    shape: tuple[int, ...],
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    config: SamplerConfig = SamplerConfig(),
) -> Array:
    """Single-expert ODE sampling (Table 3 'FM' and 'DDPM→FM' rows)."""
    return sample_ensemble(
        key, [expert], [params], None, shape,
        cond=cond, null_cond=null_cond,
        config=dataclasses.replace(config, strategy="full"),
    )


def sample_ddpm_ancestral(
    key: jax.Array,
    apply_fn: Callable[..., Array],
    params,
    shape: tuple[int, ...],
    *,
    cond: dict | None = None,
    null_cond: dict | None = None,
    num_steps: int = 75,
    cfg_scale: float = 6.0,
    schedule_name: str = "cosine",
) -> Array:
    """Native DDPM ancestral sampler (Table 3 baseline row).

    DDIM-style deterministic-σ=... we use the stochastic ancestral update
    with the VP cosine schedule, operating on the discrete grid.
    """
    cond = cond or {}
    sched = get_schedule(schedule_name)
    ts = jnp.linspace(1.0, 0.0, num_steps + 1)
    x = jax.random.normal(key, shape, dtype=jnp.float32)

    def pred_eps(x, tb):
        e_c = apply_fn(params, x, tb, **cond)
        if null_cond is None or cfg_scale == 1.0:
            return e_c
        e_u = apply_fn(params, x, tb, **null_cond)
        return cfg_combine(e_c, e_u, cfg_scale)

    def step(carry, i):
        x, key = carry
        key, nk = jax.random.split(key)
        t_hi, t_lo = ts[i], ts[i + 1]
        tb = jnp.full((shape[0],), t_hi)
        eps = pred_eps(x, tb)
        a_hi, s_hi = sched.coeffs(t_hi)
        a_lo, s_lo = sched.coeffs(t_lo)
        x0 = (x - s_hi * eps) / jnp.maximum(a_hi, 0.01)
        x0 = jnp.clip(x0, -20.0, 20.0)
        # DDIM (eta=0) update on the continuous grid.
        x_next = a_lo * x0 + s_lo * eps
        return (x_next, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), jnp.arange(num_steps))
    return x
