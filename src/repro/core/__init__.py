"""Core library — the paper's primary contribution.

Heterogeneous Decentralized Diffusion: isolated experts with mixed DDPM /
Flow-Matching objectives, unified at inference via schedule-aware ε→v
conversion and fused with a learned router.
"""

from repro.core.schedules import (
    Schedule,
    coeff_table,
    cosine_schedule,
    get_schedule,
    linear_schedule,
    snr_matched_time,
    to_ddpm_timestep,
    from_ddpm_timestep,
)
from repro.core.objectives import (
    DDPM,
    FLOW_MATCHING,
    Objective,
    diffusion_loss,
    get_objective,
    sample_timesteps,
    target_for,
    w_eps,
    w_v,
    weight_ratio,
)
from repro.core.conversion import (
    ConversionConfig,
    convert_checkpoint,
    eps_to_velocity,
    predict_x0_from_eps,
    unified_coeff_tables,
    unify_prediction,
    velocity_scale,
    velocity_to_x0,
)
from repro.core.param_store import (
    EXPERT_AXIS,
    PARAM_DTYPES,
    DenseStore,
    ExpertParamStore,
    QuantizedStore,
    as_store,
    make_store,
    pad_to_capacity,
)
from repro.core.dispatch import (
    DISPATCH_BACKENDS,
    DenseExecutor,
    DispatchPlan,
    ExpertExecutor,
    GatheredExecutor,
    GroupedExecutor,
    RaggedExecutor,
    full_dispatch_plan,
    make_dispatch_plan,
    make_executor,
    plan_from_slots,
    resolve_dispatch,
    routed_slots,
    slot_coef,
    slot_coef_rows,
    tile_plan,
    topk_slots,
)
from repro.core.fusion import (
    ExpertSpec,
    fuse_predictions,
    fusion_weights,
    prediction_conflict,
    routing_weights,
    select_topk,
    threshold_router_weights,
    unified_expert_velocities,
)
from repro.core.sampling import (
    SamplerConfig,
    cfg_combine,
    coeff_tables_cached,
    params_are_stackable,
    sample_ddpm_ancestral,
    sample_ensemble,
    sample_ensemble_step,
    sample_single_expert,
)
from repro.core.clustering import (
    ClusterModel,
    cluster_balance,
    cosine_assign,
    hierarchical_kmeans,
    kmeans,
    partition_indices,
)

__all__ = [k for k in dir() if not k.startswith("_")]
