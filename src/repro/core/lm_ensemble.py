"""Decentralized expert ensembling for the assigned LM architectures.

DESIGN.md §4: the paper's ε/v objective heterogeneity has no analogue for
autoregressive training, but its *decentralized-expert* half (the DDM part
— cluster-partitioned isolated training + router-weighted fusion, Eq. 1)
is backbone-agnostic.  This module applies it to the model zoo:

* K LM experts of any ``--arch`` train in complete isolation on disjoint
  corpus clusters (zero gradient/parameter/activation synchronization —
  same invariant as the diffusion experts);
* a lightweight prototype router assigns sequences to clusters from
  bag-of-tokens statistics (the text-domain stand-in for DINOv2 k-means);
* at inference, expert next-token *log-probabilities* are fused with
  router weights — the Eq. 1 mixture, exact for a mixture-of-corpora
  generative model:  p(x_{t+1} | x) = Σ_k p(k | x) p_k(x_{t+1} | x).

Supports the same Top-1 / Top-K / Full strategies as the diffusion
sampler.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import routing_weights
from repro.models import zoo
from repro.models.config import LMConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Prototype router over token statistics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TokenPrototypeRouter:
    """Nearest-prototype routing on normalized token histograms.

    Fitted from per-cluster corpora; `posterior` returns softmax(-dist/τ),
    a calibrated stand-in for the paper's learned DiT router.
    """

    # Host-side fitted state, never a jit cache key (posterior() lifts it
    # to device per call).  # lint: allow-mutable-config
    prototypes: np.ndarray          # (K, V) normalized token frequencies
    temperature: float = 0.05

    @staticmethod
    def _histogram(tokens: Array, vocab: int) -> Array:
        onehot_counts = jnp.zeros((tokens.shape[0], vocab))
        b = jnp.arange(tokens.shape[0])[:, None]
        onehot_counts = onehot_counts.at[
            jnp.broadcast_to(b, tokens.shape), tokens
        ].add(1.0)
        h = onehot_counts / jnp.maximum(
            onehot_counts.sum(-1, keepdims=True), 1.0
        )
        return h / jnp.maximum(
            jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-8
        )

    @classmethod
    def fit(cls, corpora: Sequence[Array], vocab: int,
            temperature: float = 0.05) -> "TokenPrototypeRouter":
        protos = []
        for tokens in corpora:
            h = cls._histogram(tokens.reshape(1, -1), vocab)[0]
            protos.append(np.asarray(h))
        return cls(prototypes=np.stack(protos), temperature=temperature)

    def posterior(self, tokens: Array) -> Array:
        """(B, S) int tokens -> (B, K) routing posterior."""
        vocab = self.prototypes.shape[1]
        h = self._histogram(tokens, vocab)                   # (B, V)
        sims = h @ jnp.asarray(self.prototypes).T            # (B, K)
        return jax.nn.softmax(sims / self.temperature, axis=-1)


def _host_scalar(x: Array) -> float:
    """The module's one explicit device→host boundary.

    Perplexity numbers are returned to callers as Python floats (they go
    to logs and assertions, not back to device), so the blocking
    transfer is intentional and lives here, visibly, instead of as
    ``float(jnp...)`` scattered through the scoring paths.
    """
    return float(jnp.asarray(x).item())  # lint: allow-host-sync


# ---------------------------------------------------------------------------
# Ensemble
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMExpertEnsemble:
    """K isolated LM experts + router, fused in log-probability space."""

    cfg: LMConfig
    expert_params: list
    router: TokenPrototypeRouter
    strategy: str = "topk"
    top_k: int = 2

    def fused_logprobs(self, tokens: Array) -> Array:
        """(B, S) -> (B, S, V) mixture log-probabilities (Eq. 1 in
        probability space: log Σ_k w_k softmax(logits_k))."""
        probs = self.router.posterior(tokens)                # (B, K)
        w = routing_weights(probs, self.strategy, self.top_k)
        logps = []
        for p in self.expert_params:
            logits, _ = zoo.forward_train(self.cfg, p, {"tokens": tokens})
            logps.append(jax.nn.log_softmax(
                logits.astype(jnp.float32), axis=-1))
        stacked = jnp.stack(logps)                           # (K, B, S, V)
        logw = jnp.log(jnp.maximum(w, 1e-12))                # (B, K)
        logw = jnp.moveaxis(logw, -1, 0)[:, :, None, None]
        return jax.nn.logsumexp(stacked + logw, axis=0)

    def perplexity(self, tokens: Array, labels: Array) -> float:
        lp = self.fused_logprobs(tokens)
        picked = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        return _host_scalar(jnp.exp(-jnp.mean(picked)))

    def decode_greedy(self, prompt: Array, steps: int) -> Array:
        """Greedy continuation with router weights fixed from the prompt."""
        probs = self.router.posterior(prompt)
        w = routing_weights(probs, self.strategy, self.top_k)
        logw = jnp.log(jnp.maximum(w, 1e-12))
        b = prompt.shape[0]
        caches = [zoo.make_cache(self.cfg, b, prompt.shape[1] + steps)
                  for _ in self.expert_params]
        # prefill each expert by replaying the prompt token-by-token
        out = prompt
        tok = prompt[:, :1]
        for i in range(prompt.shape[1] + steps - 1):
            pos = jnp.full((b,), i, jnp.int32)
            logps = []
            for e, p in enumerate(self.expert_params):
                lg, caches[e] = zoo.decode_step(self.cfg, p, caches[e],
                                                tok, pos)
                logps.append(jax.nn.log_softmax(
                    lg.astype(jnp.float32), -1))
            fused = jax.nn.logsumexp(
                jnp.stack(logps) + jnp.moveaxis(logw, -1, 0)[:, :, None],
                axis=0,
            )
            if i + 1 < prompt.shape[1]:
                tok = prompt[:, i + 1:i + 2]       # teacher-forced prefix
            else:
                tok = jnp.argmax(fused, -1).astype(jnp.int32)[:, None]
                out = jnp.concatenate([out, tok], axis=1)
        return out


def expert_perplexity(cfg: LMConfig, params, tokens: Array,
                      labels: Array) -> float:
    """Single-expert perplexity (baseline for the ensemble comparison)."""
    logits, _ = zoo.forward_train(cfg, params, {"tokens": tokens})
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    picked = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return _host_scalar(jnp.exp(-jnp.mean(picked)))
