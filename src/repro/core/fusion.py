"""Heterogeneous expert fusion (paper Fig. 2, Eq. 1, §3.1 strategies).

Given K experts with mixed objectives, fusion at a sampling step is:

1. query each (selected) expert at ``(x_t, t, c)`` in its native
   parameterization and timestep domain (Eq. 21),
2. unify every prediction into velocity space (``conversion.unify_prediction``),
3. combine with router weights ``p(k | x_t, t)`` (Eq. 1):
   ``u_t(x_t) = sum_k p_t(k|x_t) v^(k)(x_t)``.

Selection strategies (§3.1): ``top1`` routes to the argmax expert, ``topk``
renormalizes over the K highest-probability experts, ``full`` uses all.
The §3.3 two-expert *threshold* router deterministically switches experts at
a native-time threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.conversion import ConversionConfig, unify_prediction
# Slot selection moved into the dispatch-plan API (core.dispatch); the
# re-export keeps the historical ``fusion.topk_slots`` import path alive.
from repro.core.dispatch import topk_slots  # noqa: F401  (re-export)
from repro.core.schedules import Schedule, get_schedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ExpertSpec:
    """Static description of one decentralized expert."""

    name: str
    objective: str                      # 'ddpm' | 'fm'
    schedule: str                       # 'cosine' | 'linear'
    apply_fn: Callable[..., Array]      # (params, x_t, t, **cond) -> pred
    cluster_id: int = -1
    #: optional pair-major ragged forward (``models.dit.
    #: make_ragged_expert_apply`` signature) — publishing one makes the
    #: expert set eligible for the ``dispatch='ragged'`` one-kernel
    #: grouped-GEMM backend; ``None`` keeps the executor choice as before.
    ragged_apply_fn: Callable[..., Array] | None = None

    def get_schedule(self) -> Schedule:
        return get_schedule(self.schedule)


def select_topk(probs: Array, k: int) -> tuple[Array, Array]:
    """Top-K routing weights.

    Args:
      probs: ``(B, K)`` router posterior.
      k: number of experts to keep.

    Returns:
      ``(weights, mask)`` both ``(B, K)``; weights renormalized over the
      selected set (zero elsewhere).

    Ties at the k-th probability are broken deterministically toward the
    lowest expert index (``jax.lax.top_k`` order), so exactly ``k`` experts
    are selected — a ``probs >= thresh`` mask would silently select more
    than ``k`` on ties and change the fusion weights.

    The renormalizer is the sum of the *width-k* ``top_k`` values, not the
    masked width-K row: both sum the same k numbers, but the width-k form
    associates them identically whatever K is — so routing over a
    capacity-padded posterior (invalid slots masked to probability zero)
    is **bitwise** identical to routing over the compacted valid subset,
    which the elastic-membership parity proofs
    (``tests/test_faults.py``) rely on.
    """
    B, K = probs.shape
    k = min(k, K)
    vals, idx = jax.lax.top_k(probs, k)                  # (B, k), ties -> low idx
    mask = jnp.zeros((B, K), bool)
    mask = mask.at[jnp.arange(B)[:, None], idx].set(True)
    w = probs * mask
    w = w / jnp.maximum(vals.sum(axis=-1, keepdims=True), 1e-12)
    return w, mask


def routing_weights(probs: Array, strategy: str, k: int = 2) -> Array:
    """Map the router posterior to fusion weights per §3.1."""
    if strategy == "top1":
        w, _ = select_topk(probs, 1)
    elif strategy == "topk":
        w, _ = select_topk(probs, k)
    elif strategy == "full":
        w = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-12)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return w


def fuse_predictions(
    preds: Array,
    weights: Array,
) -> Array:
    """Eq. 1 — router-weighted combination of unified velocities.

    Args:
      preds: ``(K, B, ...)`` stacked per-expert velocity predictions.
      weights: ``(B, K)`` fusion weights (rows sum to 1 over selected set).
    """
    K, B = preds.shape[0], preds.shape[1]
    w = jnp.moveaxis(weights, -1, 0)                 # (K, B)
    w = w.reshape((K, B) + (1,) * (preds.ndim - 2))
    return jnp.sum(w * preds, axis=0)


def unified_expert_velocities(
    experts: Sequence[ExpertSpec],
    params: Sequence,
    x_t: Array,
    t: Array,
    cond: dict | None = None,
    *,
    conv_cfg: ConversionConfig = ConversionConfig(),
    time_map: str = "identity",
    path_schedule: Schedule | None = None,
) -> Array:
    """Query every expert and unify into velocity space -> ``(K, B, ...)``.

    ``time_map='identity'`` is the paper's scheme (all experts queried at
    the sampling path's native time, Fig. 2).  ``'snr_match'`` rebases
    experts whose training schedule differs from the sampling path via the
    SNR-matched conversion (beyond-paper, §5.ii).

    This is the dense *reference* arm: every expert runs every call.  The
    serving hot path (``sampling._sample_fused``) instead executes only
    the routed experts and fuses through ``kernels.ops.fused_velocity``;
    this path remains the parity oracle and the ``snr_match`` implementation.
    """
    cond = cond or {}
    path = path_schedule or get_schedule("linear")
    outs = []
    for spec, p in zip(experts, params):
        sched = spec.get_schedule()
        if time_map == "snr_match" and sched.name != path.name:
            from repro.core.conversion import snr_rebased_velocity

            v = snr_rebased_velocity(
                spec.apply_fn, p, x_t, t,
                objective=spec.objective,
                expert_schedule=sched, path_schedule=path,
                cond=cond, cfg=conv_cfg,
            )
        else:
            pred = spec.apply_fn(p, x_t, t, **cond)
            v = unify_prediction(
                pred, x_t, t,
                objective=spec.objective,
                schedule=sched,
                cfg=conv_cfg,
            )
        outs.append(v)
    return jnp.stack(outs, axis=0)


def fusion_weights(
    experts: Sequence[ExpertSpec],
    router_fn: Callable[[Array, Array], Array] | None,
    x_t: Array,
    t: Array,
    *,
    strategy: str,
    top_k: int = 2,
    threshold: float = 0.5,
    ddpm_low_noise_only: float = 0.0,
    valid: Array | None = None,
    cluster_map: Array | None = None,
) -> Array:
    """Per-step fusion weights ``(B, K)`` — the single source of truth.

    Shared by the dense all-experts path and the compute-sparse routed
    engine so that routed-only execution is *structurally* weight-identical
    to the dense reference.  Covers the §3.1 strategies, the Eq. 1 cluster
    -> expert posterior mapping, and the §7.3 low-noise DDPM gate.

    Elastic membership: ``valid`` is an optional ``(K,)`` bool liveness
    mask — invalid slots are zeroed *before* strategy selection, so every
    strategy renormalizes over live experts only and an evicted slot
    carries exactly zero weight.  ``cluster_map`` is an optional ``(K,)``
    int array replacing the static per-``ExpertSpec`` cluster gather with
    traced data, so a hot-added expert's cluster assignment takes effect
    without recompiling.  Strategy renormalization happens exactly once
    (inside ``routing_weights`` / here for ``threshold``): every §3.1
    strategy is scale-invariant in the posterior, so no interim renorm is
    applied after the cluster gather or the mask — the single-renorm form
    is what makes masked capacity-K routing bitwise-equal to routing over
    the compacted valid subset.
    """
    K = len(experts)
    B = x_t.shape[0]
    if strategy == "threshold":
        w = threshold_router_weights(t, K, threshold=threshold)
        if valid is not None:
            w = w * jnp.asarray(valid)[None, :]
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)
    elif router_fn is None:
        if K != 1:
            raise ValueError("router_fn required for multi-expert fusion")
        w = jnp.ones((B, 1))
    else:
        probs = router_fn(x_t, t)                        # (B, num_clusters)
        # Map cluster posterior -> per-expert probs via each expert's owned
        # cluster (Eq. 1: p(k | x_t)).
        if cluster_map is not None:
            probs = probs[:, jnp.asarray(cluster_map)]
        else:
            cluster_ids = jnp.array([max(e.cluster_id, 0) for e in experts])
            if probs.shape[-1] != K or any(
                e.cluster_id not in (-1, i) for i, e in enumerate(experts)
            ):
                probs = probs[:, cluster_ids]
        if valid is not None:
            probs = probs * jnp.asarray(valid)[None, :]
        w = routing_weights(probs, strategy, top_k)
    if ddpm_low_noise_only > 0.0:
        # §7.3: restrict converted-DDPM experts to low-noise steps.
        is_ddpm = jnp.array([e.objective == "ddpm" for e in experts])
        high_noise = t > ddpm_low_noise_only             # (B,)
        gate = jnp.where(high_noise[:, None] & is_ddpm[None, :], 0.0, 1.0)
        w = w * gate
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)
    return w


def threshold_router_weights(
    t: Array, num_experts: int, *, threshold: float = 0.5,
    low_noise_expert: int = 0, high_noise_expert: int = 1,
) -> Array:
    """§3.3.1 deterministic two-expert threshold router.

    For native time ``t' <= threshold`` (low noise) use ``low_noise_expert``
    (the converted-DDPM expert in the paper's study); for ``t' > threshold``
    use ``high_noise_expert`` (FM).  Returns one-hot weights ``(B, K)``.
    """
    t = jnp.asarray(t)
    b = t.shape[0] if t.ndim else 1
    pick = jnp.where(t <= threshold, low_noise_expert, high_noise_expert)
    pick = jnp.broadcast_to(pick, (b,))
    return jax.nn.one_hot(pick, num_experts)


def prediction_conflict(preds: Array, weights: Array) -> Array:
    """Diagnostic from §7.5 — weighted variance of expert velocities.

    High conflict explains the Full-ensemble FID regression (Table 1): when
    experts disagree, averaging blurs.  Returns a scalar per batch element.
    """
    mean = fuse_predictions(preds, weights)
    diff = preds - mean[None]
    w = jnp.moveaxis(weights, -1, 0).reshape(
        (preds.shape[0], preds.shape[1]) + (1,) * (preds.ndim - 2)
    )
    var = jnp.sum(w * diff * diff, axis=0)
    return jnp.mean(var.reshape(var.shape[0], -1), axis=-1)
