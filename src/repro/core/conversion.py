"""Schedule-aware ε→velocity conversion (paper §2.3, §8) and checkpoint
conversion (paper §2.6).

The inference-time conversion is the paper's central mechanism: it lets DDPM
(ε-prediction) experts participate in a Flow-Matching-style ODE sampler
without any retraining.

Pipeline (Eqs. 22–25):

1. ``x̂0 = (x_t - sigma_t * eps_theta) / alpha_safe``           (Eq. 23 + Eq. 29)
2. clamp ``x̂0`` to a data-space-dependent range                 (Eq. 28)
3. ``v = dalpha/dt * x̂0 + dsigma/dt * eps_theta``               (Eq. 24)
4. adaptive velocity scaling at elevated noise levels            (Eq. 31)

For the linear path (``alpha=1-t, sigma=t``) step 3 reduces to
``v = eps - x̂0`` (Eq. 25), matching the FM target ``eps - x0``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.schedules import Schedule, _left_broadcast, coeff_table

Array = jax.Array

#: Eq. 28 — adaptive clamping ranges per representation space.
CLAMP_RANGE = {"latent": 20.0, "pixel": 5.0}

#: Eq. 29 — safe-division floor for alpha_t.
ALPHA_SAFE_MIN = 0.01


@dataclasses.dataclass(frozen=True)
class ConversionConfig:
    """Numerical-stability knobs from §8.3 / §6.2."""

    data_space: Literal["latent", "pixel"] = "latent"
    alpha_min: float = ALPHA_SAFE_MIN
    #: 'analytic' uses closed-form schedule derivatives; 'fd' uses §8.3.3
    #: central finite differences with h=1e-4.
    derivative_mode: Literal["analytic", "fd"] = "analytic"
    #: Eq. 31-style adaptive dampening. 'piecewise' is the §8.3.4 table,
    #: 'sigmoid' is the §6.2 smooth variant, 'none' disables.
    velocity_scaling: Literal["piecewise", "sigmoid", "none"] = "piecewise"

    @property
    def clamp(self) -> float:
        return CLAMP_RANGE[self.data_space]


def predict_x0_from_eps(
    x_t: Array,
    eps: Array,
    schedule: Schedule,
    t: Array,
    cfg: ConversionConfig = ConversionConfig(),
) -> Array:
    """Eq. 23 with Eq. 28/29 safeguards."""
    a, s = schedule.coeffs(t)
    a_safe = jnp.maximum(a, cfg.alpha_min)
    a_safe = _left_broadcast(a_safe, x_t.ndim)
    s = _left_broadcast(s, x_t.ndim)
    x0_hat = (x_t - s * eps) / a_safe
    return jnp.clip(x0_hat, -cfg.clamp, cfg.clamp)


def velocity_scale(t: Array, mode: str) -> Array:
    """Eq. 31 (piecewise) or the §6.2 sigmoid dampening ``s(t)``."""
    t = jnp.asarray(t, jnp.float32)
    if mode == "none":
        return jnp.ones_like(t)
    if mode == "piecewise":
        return jnp.where(t > 0.85, 0.88, jnp.where(t > 0.6, 0.93, 0.96))
    if mode == "sigmoid":
        # §6.2: s(t) = min(1, 15 / (1 + e^{10 (t - 0.85)})) applied for t>0.85.
        s = jnp.minimum(1.0, 15.0 / (1.0 + jnp.exp(10.0 * (t - 0.85))))
        return jnp.where(t > 0.85, s, jnp.ones_like(t))
    raise ValueError(f"unknown velocity_scaling mode {mode!r}")


def eps_to_velocity(
    x_t: Array,
    eps: Array,
    schedule: Schedule,
    t: Array,
    cfg: ConversionConfig = ConversionConfig(),
) -> Array:
    """Full schedule-aware deterministic conversion (Eqs. 22–25 + §8.3).

    Returns the data-to-noise velocity; sampling integrates
    ``x_{t-dt} = x_t - v * dt`` from t=1 to t=0.
    """
    x0_hat = predict_x0_from_eps(x_t, eps, schedule, t, cfg)
    if cfg.derivative_mode == "fd":
        da, ds = schedule.fd_derivs(t)
    else:
        da, ds = schedule.derivs(t)
    da = _left_broadcast(da, x_t.ndim)
    ds = _left_broadcast(ds, x_t.ndim)
    v = da * x0_hat + ds * eps
    scale = _left_broadcast(velocity_scale(t, cfg.velocity_scaling), x_t.ndim)
    return scale * v


def velocity_to_x0(
    x_t: Array, v: Array, schedule: Schedule, t: Array,
    cfg: ConversionConfig = ConversionConfig(),
) -> Array:
    """Invert the velocity parameterization to an x0 estimate.

    From ``x_t = a x0 + s eps`` and ``v = a' x0 + s' eps``:
    ``x0 = (s' x_t - s v) / (s' a - s a')``.  For the linear path this is
    ``x0 = x_t - t v``.  Used by the sampler's optional x0-clamping step and
    by the diversity/FID proxies.
    """
    a, s = schedule.coeffs(t)
    da, ds = schedule.derivs(t)
    denom = ds * a - s * da
    denom = jnp.where(jnp.abs(denom) < 1e-6, jnp.sign(denom) * 1e-6 + (denom == 0) * 1e-6, denom)
    a, s, da, ds, denom = (
        _left_broadcast(c, x_t.ndim) for c in (a, s, da, ds, denom)
    )
    x0 = (ds * x_t - s * v) / denom
    return jnp.clip(x0, -cfg.clamp, cfg.clamp)


def unify_prediction(
    pred: Array,
    x_t: Array,
    t: Array,
    *,
    objective: str,
    schedule: Schedule,
    cfg: ConversionConfig = ConversionConfig(),
) -> Array:
    """Map an expert's native prediction into the common velocity space.

    FM experts pass through (they already predict velocity); DDPM experts go
    through :func:`eps_to_velocity`.  This is the per-expert arm of Fig. 2.
    """
    if objective == "fm":
        return pred
    if objective == "ddpm":
        return eps_to_velocity(x_t, pred, schedule, t, cfg)
    raise ValueError(f"unknown objective {objective!r}")


def unified_coeff_tables(
    objectives: list[str],
    schedules: list[Schedule],
    ts: Array,
    cfg: ConversionConfig = ConversionConfig(),
) -> Array:
    """Per-step, per-expert conversion coefficients ``(S, 5, K)``.

    Row order: ``(alpha, sigma, dalpha, dsigma, vscale)``.  DDPM experts get
    their schedule's coefficients plus the Eq. 31 dampening; FM experts are
    folded to the identity coefficients ``(1, 0, 0, 1, 1)`` under which the
    Eqs. 23–24 conversion reduces *exactly* to a velocity pass-through
    (``v = 0·x̂0 + 1·pred``).  One table therefore drives a single fused
    convert-and-fuse kernel for a heterogeneous expert set — computed once
    per run, gathered per step on the hot path.
    """
    ts = jnp.asarray(ts, jnp.float32)
    s = ts.shape[0]
    cols = []
    for obj, sched in zip(objectives, schedules):
        if obj == "fm":
            col = jnp.tile(
                jnp.array([1.0, 0.0, 0.0, 1.0, 1.0], jnp.float32)[:, None],
                (1, s),
            )
        elif obj == "ddpm":
            base = coeff_table(sched, ts,
                               derivative_mode=cfg.derivative_mode)  # (4, S)
            vs = velocity_scale(ts, cfg.velocity_scaling)            # (S,)
            col = jnp.concatenate([base, vs[None]], axis=0)          # (5, S)
        else:
            raise ValueError(f"unknown objective {obj!r}")
        cols.append(col)
    return jnp.stack(cols, axis=-1).transpose(1, 0, 2)               # (S, 5, K)


def snr_rebased_velocity(
    apply_fn,
    params,
    x_t: Array,
    t: Array,
    *,
    objective: str,
    expert_schedule: Schedule,
    path_schedule: Schedule,
    cond: dict | None = None,
    cfg: ConversionConfig = ConversionConfig(),
) -> Array:
    """Beyond-paper (§5.ii): SNR-matched cross-schedule conversion.

    The paper queries heterogeneous experts at the *same* native time
    (identity time map) and stabilizes with clamps/dampening.  Matching
    the noise level instead is exact for a perfect predictor:

    1. solve ``t_e`` with ``SNR_expert(t_e) = SNR_path(t)``;
    2. rescale ``x_in = x_t · s_e(t_e)/s_p(t)`` — by the SNR match this
       equals ``a_e x0 + s_e ε`` with the *same* (x0, ε) decomposition;
    3. query the expert at ``(x_in, t_e)`` in its native parameterization;
    4. recover ``(x̂0, ε̂)`` in the expert frame and rebuild the velocity
       along the sampling path: ``v = a'_p(t) x̂0 + s'_p(t) ε̂``.

    No dampening heuristics needed away from the α→0 endpoint.
    """
    from repro.core.schedules import snr_matched_time

    cond = cond or {}
    t_e = snr_matched_time(path_schedule, expert_schedule, t)
    s_p = jnp.maximum(path_schedule.sigma(t), 1e-6)
    s_e = expert_schedule.sigma(t_e)
    scale = _left_broadcast(s_e / s_p, x_t.ndim)
    x_in = x_t * scale
    pred = apply_fn(params, x_in, t_e, **cond)

    a_e, s_e_b = (
        _left_broadcast(c, x_t.ndim) for c in expert_schedule.coeffs(t_e)
    )
    if objective == "ddpm":
        eps_hat = pred
        x0_hat = jnp.clip(
            (x_in - s_e_b * eps_hat) / jnp.maximum(a_e, cfg.alpha_min),
            -cfg.clamp, cfg.clamp,
        )
    else:  # velocity in the expert frame -> invert to (x0, eps)
        x0_hat = velocity_to_x0(x_in, pred, expert_schedule, t_e, cfg)
        eps_hat = (x_in - a_e * x0_hat) / jnp.maximum(s_e_b, 1e-6)

    da_p, ds_p = path_schedule.derivs(t)
    da_p = _left_broadcast(da_p, x_t.ndim)
    ds_p = _left_broadcast(ds_p, x_t.ndim)
    return da_p * x0_hat + ds_p * eps_hat


# ---------------------------------------------------------------------------
# Checkpoint conversion (paper §2.6, Eq. 20) — pretrained ImageNet-DDPM DiT
# checkpoints initialize heterogeneous text-conditioned experts.
# ---------------------------------------------------------------------------

#: Eq. 20 transfer policy by top-level parameter group.
TRANSFER = "transfer"          # copy pretrained weights
REINIT = "reinit"              # N(0, 0.02)
DROP = "drop"                  # remove (class embeddings)
NEW = "new"                    # not in source checkpoint (text stack)

CHECKPOINT_POLICY: dict[str, str] = {
    "patch_embed": TRANSFER,
    "pos_embed": TRANSFER,
    "blocks": TRANSFER,
    "t_embed": TRANSFER,          # timestep MLP kept (Eq. 21 runtime mapping)
    "adaln_single": TRANSFER,
    "final_layer": REINIT,
    "text_proj": NEW,
    "cross_attn": NEW,            # zero-init output proj handled by model init
    "class_embed": DROP,
    "null_text_embed": NEW,
}

REINIT_STD = 0.02


def convert_checkpoint(
    pretrained: dict,
    target_template: dict,
    *,
    rng: jax.Array,
    policy: dict[str, str] | None = None,
) -> tuple[dict, dict[str, str]]:
    """Apply the Eq. 20 conversion to a parameter pytree.

    ``pretrained`` / ``target_template`` are dicts keyed by top-level group
    (``patch_embed``, ``blocks``, ...) of arbitrary pytrees.  Groups present
    in the template but absent from the policy default to:
    transfer when shapes match, otherwise keep the template's fresh init.

    Returns ``(params, report)`` where ``report`` maps group -> action taken.
    """
    policy = dict(CHECKPOINT_POLICY if policy is None else policy)
    out: dict = {}
    report: dict[str, str] = {}
    keys = jax.random.split(rng, max(len(target_template), 1))
    for i, (group, template) in enumerate(sorted(target_template.items())):
        action = policy.get(group)
        if action is None:
            same = group in pretrained and _shapes_match(
                pretrained[group], template
            )
            action = TRANSFER if same else NEW
        if action == TRANSFER and group in pretrained and _shapes_match(
            pretrained[group], template
        ):
            out[group] = jax.tree.map(
                lambda src, dst: src.astype(dst.dtype),
                pretrained[group],
                template,
            )
            report[group] = TRANSFER
        elif action == REINIT:
            leaves, treedef = jax.tree.flatten(template)
            sub = jax.random.split(keys[i], max(len(leaves), 1))
            out[group] = jax.tree.unflatten(
                treedef,
                [
                    (REINIT_STD * jax.random.normal(k, l.shape)).astype(l.dtype)
                    for k, l in zip(sub, leaves)
                ],
            )
            report[group] = REINIT
        elif action == DROP:
            report[group] = DROP
            continue
        else:
            # NEW (or transfer-miss): keep the freshly initialized template.
            out[group] = template
            report[group] = NEW
    # groups only in the source (e.g. class_embed) are dropped implicitly.
    for group in pretrained:
        if group not in target_template:
            report.setdefault(group, DROP)
    return out, report


def _shapes_match(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(x.shape == y.shape for x, y in zip(la, lb))
