"""Typed stacked-expert parameter stores: dense and quantized (int8/fp8).

Serving the heterogeneous ensemble is bandwidth-bound on the expert axis:
every routed step moves slices of the stacked expert pytree across the
``("expert", "data")`` mesh.  Until this module, "stacked params" was an
untyped convention — a plain pytree whose leaves happen to carry a leading
``(K, ...)`` expert axis — smeared across ``models/dit.py``,
``core/dispatch.py``, ``launch/sharding.py`` and ``launch/serve.py``, with
nowhere for a storage dtype, per-expert scales, or a dequantization policy
to live.

``ExpertParamStore`` makes that layer first-class.  A store owns:

* the stacked leaves (every leaf ``(K, ...)``, leading axis = expert);
* the expert count and per-leaf storage dtype;
* for quantized stores, per-expert **scales** riding the same leading
  axis — so they shard with their leaves on the mesh "expert" axis
  (``launch.sharding.expert_param_specs``).

Three access patterns cover every executor backend (``core.dispatch``):

* ``gather(idx)`` — per-sample ``(B, ...)`` or batch-uniform scalar gather
  (the ``GatheredExecutor`` paths);
* ``expert(e)`` / ``static_slice(lo, hi)`` — static expert-axis slices
  that resolve from the owning shard without an expert-axis all-gather
  (the ``GroupedExecutor`` path);
* ``materialize(dtype)`` — the full stacked pytree, for tests and
  off-hot-path consumers only.

Quantization policy (``QuantizedStore``): symmetric per-expert-per-leaf —
``scale[e] = absmax(leaf[e]) / qmax``; int8 rounds to ``[-127, 127]``, fp8
casts to ``float8_e4m3fn`` (qmax 448).  Dequantization ``scale · q`` is
fused into the hot path via the ``kernels.hetero_fuse.hetero_fuse_dequant``
Pallas kernel (``kernels.ops.dequant_params``): only the *gathered or
sliced* quantized bytes are expanded at the point of use, and the full
``(K, ...)`` stacked leaves never materialize at full precision on the
routed path (proven by test — ``tests/test_param_store.py``).

Error bounds (tested): int8 round-trip max-abs error ≤ 1/254 ≈ 4e-3 of the
per-expert-leaf absmax (gate: 1e-2); fp8 e4m3 carries 3 mantissa bits, so
the element-wise relative error is ≤ 2^-4 = 6.25e-2 (documented gate).

Elastic membership (fault tolerance): the leading expert axis is a
**capacity**, not a census.  ``pad_to_capacity`` zero-pads every leaf to
``(K_cap, ...)`` and attaches a ``(K_cap,)`` boolean ``valid`` mask — a
*data* leaf riding the same leading expert axis as the weights (so
``launch.sharding.expert_param_specs`` shards it with them).  Routing
masks invalid slots to zero weight (``core.fusion.fusion_weights``) and
plan construction remaps any invalid slot to a valid fallback expert
(``core.dispatch.make_dispatch_plan``), so an evicted or never-filled
slot costs zero forwards in the grouped executor and never appears in a
gather.  Because the mask is data — not trace structure — hot-adding,
evicting, or quarantining an expert never recompiles the sampler:
``set_expert`` / ``with_valid`` return new stores with the same
``(K_cap, ...)`` shapes, and old store objects stay immutable, so
in-flight requests admitted under an earlier membership complete
bit-identically against their snapshot.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

#: Mesh-axis name carrying every store leaf's leading expert dimension
#: (see ``launch.mesh.make_expert_mesh`` / ``launch.sharding.
#: expert_param_specs``).  ``models.dit.EXPERT_AXIS`` aliases this.
EXPERT_AXIS = "expert"

#: valid ``SamplerConfig.param_dtype`` / ``make_store`` dtype requests.
#: ``native`` keeps the checkpoint leaves untouched (bit-identical to the
#: pre-store pytree convention); ``fp32``/``bf16`` cast dense storage;
#: ``int8``/``fp8`` quantize.
PARAM_DTYPES = ("native", "fp32", "bf16", "int8", "fp8")

_DENSE_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16}
_QUANT_QMAX = {"int8": 127.0, "fp8": 448.0}


def _leaf_axes(x) -> tuple:
    return (EXPERT_AXIS,) + (None,) * (jnp.asarray(x).ndim - 1)


def _tree_nbytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


@dataclasses.dataclass(frozen=True)
class QuantLeaf:
    """One quantized stacked leaf plus its per-expert scales, un-expanded.

    The ragged backend's raw-leaf currency: ``q`` is the ``(K, ...)``
    int8/fp8 storage array and ``scale`` its ``(K,)`` float32 symmetric
    scales — exactly what ``kernels.ops.ragged_expert_matmul`` feeds the
    fused-dequant Pallas kernel, so quantized weights reach the MXU
    without ever materializing a full-precision copy.  Deliberately NOT
    a registered pytree: views are built inside a trace and consumed in
    place; tree transforms over a view must treat ``QuantLeaf`` as
    atomic (slice ``q``, keep ``scale``) rather than descending into it.
    """

    q: Any
    scale: Any
    compute_dtype: str = "float32"


def dequant_leaf(leaf, dtype=None):
    """Expand a view leaf to compute precision (float32 multiply).

    Raw array leaves (dense stores) pass through untouched.
    ``QuantLeaf``s expand with the exact ``hetero_fuse_dequant`` oracle
    arithmetic — ``q.astype(f32) · scale`` broadcast over trailing dims,
    then a cast — so a dequantized view leaf is bitwise identical to the
    same leaf resolved through ``QuantizedStore.expert``/``gather``.
    Only for leaves that are cheap to expand (embeddings, biases,
    modulation tables); matmul weights should stay quantized through
    ``kernels.ops.ragged_expert_matmul`` instead.
    """
    if not isinstance(leaf, QuantLeaf):
        return leaf
    out = leaf.q.astype(jnp.float32) * leaf.scale.astype(jnp.float32).reshape(
        leaf.scale.shape + (1,) * (leaf.q.ndim - 1)
    )
    return out.astype(jnp.dtype(dtype or leaf.compute_dtype))


class ExpertParamStore:
    """Base for stacked-expert parameter stores.

    Concrete stores are frozen registered-dataclass pytrees, so they pass
    through ``jax.jit`` / ``jax.device_put`` like the raw stacked pytree
    they replace; ``num_experts`` and the storage dtype are static
    metadata (part of the trace cache key), the leaves are data.
    """

    num_experts: int

    # -- access patterns (implemented by subclasses) ------------------------

    def gather(self, idx: Array):
        """Params for routed samples, in compute precision.

        ``idx`` is ``(B,)`` (per-sample routing — leaves come back
        ``(B, ...)`` for a vmapped apply) or a scalar (batch-uniform
        routing — one expert's params for a plain apply).
        """
        raise NotImplementedError

    def expert(self, e: int):
        """One expert's params via a *static* expert-axis index.

        On an ``("expert", "data")`` mesh the slice resolves from the
        shard owning expert ``e`` — no expert-axis all-gather.
        """
        raise NotImplementedError

    def static_slice(self, lo: int, hi: int) -> "ExpertParamStore":
        """Sub-store over experts ``[lo, hi)`` (static bounds)."""
        raise NotImplementedError

    def materialize(self, dtype=None):
        """Full stacked pytree ``(K, ...)`` in compute precision.

        Off-hot-path only (tests, checkpoint export): on the routed path
        executors must go through ``gather``/``expert`` so quantized
        stores never expand the whole stack to full precision.
        """
        raise NotImplementedError

    def ragged_view(self):
        """Raw stacked leaves for the ragged one-kernel GEMM backend.

        Returns a pytree matching the param structure whose leaves are
        either plain ``(K, ...)`` arrays (dense storage) or
        :class:`QuantLeaf` bundles of the un-expanded int8/fp8 bytes and
        their ``(K,)`` scales.  Nothing dequantizes here — the ragged
        executor hands weight leaves to
        ``kernels.ops.ragged_expert_matmul``, which fuses the scale
        multiply into the GEMM epilogue; that is the "expose raw
        quantized leaves + scales without materialization" seam.
        """
        raise NotImplementedError

    # -- shared layer metadata ----------------------------------------------

    def logical_axes(self):
        """Sharding annotation pytree matching this store's own structure.

        Every leaf — including quantized stores' per-expert scales — maps
        to ``(EXPERT_AXIS, None, ...)``: scales ride the same leading
        expert axis as the leaves they rescale, so
        ``launch.sharding.expert_param_specs`` shards them together.
        """
        raise NotImplementedError

    def nbytes(self) -> int:
        """Resident bytes of the stored representation (benchmark metric)."""
        raise NotImplementedError

    # -- elastic membership -------------------------------------------------

    def valid_mask(self) -> Array:
        """``(K,)`` bool — which capacity slots hold a live expert.

        Stores built before ``pad_to_capacity`` carry ``valid=None``,
        meaning every slot is live (the fixed-membership fast path).
        """
        v = getattr(self, "valid", None)
        if v is not None:
            return jnp.asarray(v)
        return jnp.ones((self.num_experts,), dtype=bool)

    def with_valid(self, mask) -> "ExpertParamStore":
        """New store with ``valid`` replaced (same leaves, same shapes).

        Membership changes are pure-functional: the old store object is
        untouched, so requests holding it as a snapshot stay bit-stable.
        """
        mask = None if mask is None else jnp.asarray(mask, dtype=bool)
        if mask is not None and mask.shape != (self.num_experts,):
            raise ValueError(
                f"valid mask shape {mask.shape} != ({self.num_experts},)"
            )
        return dataclasses.replace(self, valid=mask)

    def set_expert(self, e: int, params: Any) -> "ExpertParamStore":
        """New store with capacity slot ``e`` overwritten by ``params``.

        Does **not** touch ``valid`` — callers flip the slot live via
        ``with_valid`` once the write (and any router refresh) is done, so
        a half-installed expert is never routable.
        """
        raise NotImplementedError


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("stacked", "valid"),
    meta_fields=("num_experts", "storage"),
)
@dataclasses.dataclass(frozen=True)
class DenseStore(ExpertParamStore):
    """Dense stacked store — the pre-refactor behavior, typed.

    ``gather``/``expert`` emit exactly the gather ops the executors used
    to hand-roll (``s[idx]`` / ``dynamic_index_in_dim`` /
    ``index_in_dim``), so the ``native`` path is bit-identical to the raw
    stacked-pytree convention it replaces.  ``storage`` records what the
    leaves actually hold: ``'native'`` (untouched checkpoint precision)
    or the ``'fp32'``/``'bf16'`` cast ``make_store`` applied.
    """

    stacked: Any
    num_experts: int
    storage: str = "native"
    #: ``(K,)`` bool liveness mask, or ``None`` (= all slots live).  Data
    #: field: membership is traced, so flipping it never recompiles.
    valid: Any = None

    @classmethod
    def from_stacked(cls, stacked: Any,
                     storage: str = "native") -> "DenseStore":
        leaves = jax.tree.leaves(stacked)
        if not leaves:
            raise ValueError("empty stacked pytree")
        return cls(stacked=stacked, num_experts=int(leaves[0].shape[0]),
                   storage=storage)

    def gather(self, idx: Array):
        idx = jnp.asarray(idx)
        if idx.ndim == 0:
            return jax.tree.map(
                lambda s: jax.lax.dynamic_index_in_dim(s, idx, 0,
                                                       keepdims=False),
                self.stacked,
            )
        return jax.tree.map(lambda s: s[idx], self.stacked)

    def expert(self, e: int):
        return jax.tree.map(
            lambda s: jax.lax.index_in_dim(s, e, 0, keepdims=False),
            self.stacked,
        )

    def static_slice(self, lo: int, hi: int) -> "DenseStore":
        return DenseStore(
            stacked=jax.tree.map(lambda s: s[lo:hi], self.stacked),
            num_experts=hi - lo, storage=self.storage,
            valid=None if self.valid is None else self.valid[lo:hi],
        )

    def set_expert(self, e: int, params: Any) -> "DenseStore":
        stacked = jax.tree.map(
            lambda s, p: s.at[e].set(jnp.asarray(p).astype(s.dtype)),
            self.stacked, params,
        )
        return dataclasses.replace(self, stacked=stacked)

    def materialize(self, dtype=None):
        if dtype is None:
            return self.stacked
        return jax.tree.map(lambda s: s.astype(dtype), self.stacked)

    def ragged_view(self):
        return self.stacked

    def logical_axes(self) -> "DenseStore":
        return DenseStore(
            stacked=jax.tree.map(_leaf_axes, self.stacked),
            num_experts=self.num_experts, storage=self.storage,
            valid=None if self.valid is None else (EXPERT_AXIS,),
        )

    def nbytes(self) -> int:
        n = _tree_nbytes(self.stacked)
        if self.valid is not None:
            n += _tree_nbytes(self.valid)
        return n


def _quantize_leaf(x: Array, qmax: float, storage: str):
    """Symmetric per-expert quantization of one stacked leaf ``(K, ...)``."""
    x = jnp.asarray(x)
    f = x.astype(jnp.float32).reshape(x.shape[0], -1)
    absmax = jnp.max(jnp.abs(f), axis=1)
    scale = jnp.where(absmax > 0.0, absmax / qmax, 1.0)        # (K,)
    scaled = x.astype(jnp.float32) / scale.reshape(
        (-1,) + (1,) * (x.ndim - 1)
    )
    if storage == "int8":
        q = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(jnp.int8)
    else:
        q = scaled.astype(jnp.float8_e4m3fn)
    return q, scale


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("qvals", "scales", "valid"),
    meta_fields=("num_experts", "storage", "compute_dtype"),
)
@dataclasses.dataclass(frozen=True)
class QuantizedStore(ExpertParamStore):
    """int8/fp8 stacked store with per-expert-per-leaf symmetric scales.

    ``qvals`` leaves are ``(K, ...)`` in the storage dtype; ``scales``
    leaves are ``(K,)`` float32 riding the same leading expert axis (so
    they shard with their leaves).  All access paths dequantize through
    the fused ``kernels.ops.dequant_params`` (``hetero_fuse_dequant``
    Pallas kernel on TPU) **after** slicing/gathering, so only routed
    bytes expand to compute precision — the stacked leaves never
    round-trip through HBM at full precision.
    """

    qvals: Any
    scales: Any
    num_experts: int
    storage: str                 # 'int8' | 'fp8'
    compute_dtype: str = "float32"
    #: ``(K,)`` bool liveness mask, or ``None`` (= all slots live).
    valid: Any = None

    @classmethod
    def quantize(cls, stacked: Any, storage: str) -> "QuantizedStore":
        if storage not in _QUANT_QMAX:
            raise ValueError(
                f"unknown quantized storage {storage!r}; "
                f"expected one of {tuple(_QUANT_QMAX)}"
            )
        leaves = jax.tree.leaves(stacked)
        if not leaves:
            raise ValueError("empty stacked pytree")
        qmax = _QUANT_QMAX[storage]
        pairs = jax.tree.map(
            lambda x: _quantize_leaf(x, qmax, storage), stacked,
        )
        qvals = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda p: isinstance(p, tuple))
        scales = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda p: isinstance(p, tuple))
        return cls(
            qvals=qvals, scales=scales,
            num_experts=int(leaves[0].shape[0]), storage=storage,
        )

    # -- fused dequant of a gathered/sliced view ----------------------------

    def _dequant(self, q: Array, scale: Array) -> Array:
        """``scale · q`` through the fused kernel: rows = leading axis."""
        from repro.kernels import ops

        return ops.dequant_params(q, scale,
                                  out_dtype=jnp.dtype(self.compute_dtype))

    def gather(self, idx: Array):
        idx = jnp.asarray(idx)
        if idx.ndim == 0:
            def one(q, s):
                qe = jax.lax.dynamic_index_in_dim(q, idx, 0, keepdims=True)
                se = jax.lax.dynamic_index_in_dim(s, idx, 0, keepdims=True)
                return self._dequant(qe, se)[0]

            return jax.tree.map(one, self.qvals, self.scales)
        return jax.tree.map(
            lambda q, s: self._dequant(q[idx], s[idx]),
            self.qvals, self.scales,
        )

    def expert(self, e: int):
        return jax.tree.map(
            lambda q, s: self._dequant(q[e:e + 1], s[e:e + 1])[0],
            self.qvals, self.scales,
        )

    def static_slice(self, lo: int, hi: int) -> "QuantizedStore":
        return QuantizedStore(
            qvals=jax.tree.map(lambda q: q[lo:hi], self.qvals),
            scales=jax.tree.map(lambda s: s[lo:hi], self.scales),
            num_experts=hi - lo, storage=self.storage,
            compute_dtype=self.compute_dtype,
            valid=None if self.valid is None else self.valid[lo:hi],
        )

    def set_expert(self, e: int, params: Any) -> "QuantizedStore":
        qmax = _QUANT_QMAX[self.storage]
        pairs = jax.tree.map(
            lambda p: _quantize_leaf(jnp.asarray(p)[None], qmax,
                                     self.storage),
            params,
        )
        # mapping over qvals first: ``pairs``' (q, scale) tuples sit at the
        # qvals treedef's leaf positions, so flatten_up_to leaves them whole.
        qvals = jax.tree.map(
            lambda q, p: q.at[e].set(p[0][0].astype(q.dtype)),
            self.qvals, pairs,
        )
        scales = jax.tree.map(
            lambda s, p: s.at[e].set(p[1][0]),
            self.scales, pairs,
        )
        return dataclasses.replace(self, qvals=qvals, scales=scales)

    def materialize(self, dtype=None):
        out = jax.tree.map(
            lambda q, s: self._dequant(q, s), self.qvals, self.scales,
        )
        if dtype is not None:
            out = jax.tree.map(lambda x: x.astype(dtype), out)
        return out

    def ragged_view(self):
        return jax.tree.map(
            lambda q, s: QuantLeaf(q, s, self.compute_dtype),
            self.qvals, self.scales,
        )

    def logical_axes(self) -> "QuantizedStore":
        return QuantizedStore(
            qvals=jax.tree.map(_leaf_axes, self.qvals),
            scales=jax.tree.map(_leaf_axes, self.scales),
            num_experts=self.num_experts, storage=self.storage,
            compute_dtype=self.compute_dtype,
            valid=None if self.valid is None else (EXPERT_AXIS,),
        )

    def nbytes(self) -> int:
        n = _tree_nbytes(self.qvals) + _tree_nbytes(self.scales)
        if self.valid is not None:
            n += _tree_nbytes(self.valid)
        return n


# ---------------------------------------------------------------------------
# Factories
# ---------------------------------------------------------------------------


def make_store(stacked: Any, *, dtype: str = "native") -> ExpertParamStore:
    """Build a store from a stacked pytree (leaves ``(K, ...)``).

    ``dtype`` selects the storage representation (``PARAM_DTYPES``):
    ``native`` wraps the leaves untouched (bit-identical), ``fp32``/
    ``bf16`` cast dense storage, ``int8``/``fp8`` quantize with
    per-expert-per-leaf symmetric scales.
    """
    if dtype not in PARAM_DTYPES:
        raise ValueError(
            f"unknown param_dtype {dtype!r}; expected one of {PARAM_DTYPES}"
        )
    if dtype == "native":
        return DenseStore.from_stacked(stacked)
    if dtype in _DENSE_DTYPES:
        target = _DENSE_DTYPES[dtype]
        return DenseStore.from_stacked(
            jax.tree.map(lambda x: jnp.asarray(x).astype(target), stacked),
            storage=dtype,
        )
    return QuantizedStore.quantize(stacked, dtype)


def pad_to_capacity(store: ExpertParamStore,
                    capacity: int) -> ExpertParamStore:
    """Grow a store's expert axis to ``capacity`` slots, masking the pad.

    Every data leaf zero-pads along the leading expert axis (quantized
    scales pad with 1.0 so a padded slot dequantizes to exact zeros, never
    divides by zero); ``valid`` becomes ``(capacity,)`` with the original
    experts live and the pad slots dead.  ``num_experts`` afterwards means
    *capacity* — live membership is ``valid_mask().sum()``, traced data.
    A no-op (modulo attaching an explicit mask) when the store is already
    at capacity.
    """
    k = store.num_experts
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < current expert count {k}"
        )
    pad = capacity - k
    valid = jnp.concatenate([
        store.valid_mask(), jnp.zeros((pad,), dtype=bool)
    ])

    def pad_leaf(x, fill=0):
        x = jnp.asarray(x)
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    if isinstance(store, DenseStore):
        return DenseStore(
            stacked=jax.tree.map(pad_leaf, store.stacked),
            num_experts=capacity, storage=store.storage, valid=valid,
        )
    if isinstance(store, QuantizedStore):
        return QuantizedStore(
            qvals=jax.tree.map(pad_leaf, store.qvals),
            scales=jax.tree.map(lambda s: pad_leaf(s, fill=1),
                                store.scales),
            num_experts=capacity, storage=store.storage,
            compute_dtype=store.compute_dtype, valid=valid,
        )
    raise TypeError(f"cannot pad {type(store).__name__}")


def as_store(stacked_or_store: Any, *, dtype: str = "native"):
    """Coerce executor input to a store.

    An existing store passes through untouched (its storage decision is
    the caller's source of truth); a raw stacked pytree — the pre-store
    calling convention, still accepted everywhere — is wrapped via
    ``make_store``.  ``None`` stays ``None``.
    """
    if stacked_or_store is None or isinstance(stacked_or_store,
                                              ExpertParamStore):
        return stacked_or_store
    return make_store(stacked_or_store, dtype=dtype)
