"""Two-stage hierarchical k-means on semantic features (paper §6.1).

The paper extracts 1024-d DINOv2 [CLS] features and clusters in two stages:
first into 1024 fine-grained groups with standard k-means, then groups the
fine centroids into K=8 coarse clusters; every image is assigned to its
nearest coarse cluster by cosine distance.

Implemented in pure JAX so it runs on-device and shards over the data axis;
on CPU the same code is the test/reference path.  The DINOv2 extractor is a
stub (frozen random projection network) per the modality-frontend carve-out —
see ``repro/data/features.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _normalize(x: Array, eps: float = 1e-8) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def cosine_assign(feats: Array, centroids: Array) -> Array:
    """Nearest-centroid assignment under cosine distance."""
    sims = _normalize(feats) @ _normalize(centroids).T
    return jnp.argmax(sims, axis=-1)


@functools.partial(jax.jit, static_argnames=("num_clusters", "iters"))
def kmeans(
    key: jax.Array, feats: Array, *, num_clusters: int, iters: int = 25
) -> tuple[Array, Array]:
    """Spherical (cosine) k-means.  Returns ``(centroids, assignment)``."""
    n = feats.shape[0]
    feats_n = _normalize(feats.astype(jnp.float32))
    init_idx = jax.random.choice(key, n, (num_clusters,), replace=False)
    centroids = feats_n[init_idx]

    def step(centroids, _):
        assign = cosine_assign(feats_n, centroids)
        onehot = jax.nn.one_hot(assign, num_clusters, dtype=jnp.float32)
        sums = onehot.T @ feats_n                        # (K, D)
        counts = onehot.sum(axis=0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)
        return _normalize(new), None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    return centroids, cosine_assign(feats_n, centroids)


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Fitted two-stage clustering: fine centroids + fine->coarse map."""

    fine_centroids: np.ndarray      # (F, D)
    coarse_centroids: np.ndarray    # (K, D)
    fine_to_coarse: np.ndarray      # (F,)

    @property
    def num_clusters(self) -> int:
        return self.coarse_centroids.shape[0]

    def assign(self, feats: Array) -> Array:
        """Assign features to coarse clusters via their nearest fine centroid."""
        fine = cosine_assign(feats, jnp.asarray(self.fine_centroids))
        return jnp.asarray(self.fine_to_coarse)[fine]

    def assign_direct(self, feats: Array) -> Array:
        """Direct nearest-coarse-centroid assignment (paper §6.1 last step)."""
        return cosine_assign(feats, jnp.asarray(self.coarse_centroids))


def hierarchical_kmeans(
    key: jax.Array,
    feats: Array,
    *,
    num_coarse: int = 8,
    num_fine: int = 1024,
    fine_iters: int = 25,
    coarse_iters: int = 50,
) -> ClusterModel:
    """Paper §6.1 two-stage clustering.

    ``num_fine`` is clipped to the dataset size for small (test) corpora.
    """
    n = feats.shape[0]
    num_fine = int(min(num_fine, max(num_coarse, n // 4), n))
    k1, k2 = jax.random.split(key)
    fine_centroids, _ = kmeans(k1, feats, num_clusters=num_fine, iters=fine_iters)
    coarse_centroids, fine_to_coarse = kmeans(
        k2, fine_centroids, num_clusters=num_coarse, iters=coarse_iters
    )
    return ClusterModel(
        fine_centroids=np.asarray(fine_centroids),
        coarse_centroids=np.asarray(coarse_centroids),
        fine_to_coarse=np.asarray(fine_to_coarse),
    )


def partition_indices(assignment: np.ndarray, num_clusters: int) -> list[np.ndarray]:
    """Disjoint per-cluster index lists ``S_1..S_K`` (Fig. 6 data partition)."""
    assignment = np.asarray(assignment)
    return [np.nonzero(assignment == k)[0] for k in range(num_clusters)]


def cluster_balance(assignment: np.ndarray, num_clusters: int) -> np.ndarray:
    counts = np.bincount(np.asarray(assignment), minlength=num_clusters)
    return counts / max(counts.sum(), 1)
