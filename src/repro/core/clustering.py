"""Two-stage hierarchical k-means on semantic features (paper §6.1).

The paper extracts 1024-d DINOv2 [CLS] features and clusters in two stages:
first into 1024 fine-grained groups with standard k-means, then groups the
fine centroids into K=8 coarse clusters; every image is assigned to its
nearest coarse cluster by cosine distance.

Implemented in pure JAX so it runs on-device and shards over the data axis;
on CPU the same code is the test/reference path.  The DINOv2 extractor is a
stub (frozen random projection network) per the modality-frontend carve-out —
see ``repro/data/features.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _normalize(x: Array, eps: float = 1e-8) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def cosine_assign(feats: Array, centroids: Array) -> Array:
    """Nearest-centroid assignment under cosine distance."""
    sims = _normalize(feats) @ _normalize(centroids).T
    return jnp.argmax(sims, axis=-1)


def _farthest_point_init(feats_n: Array, num_clusters: int) -> Array:
    """Deterministic greedy farthest-point (k-means++-style) seeding.

    Uniform-random seeding regularly drops two initial centroids into the
    same blob, collapsing clusters and making downstream expert partitions
    / router labels unstable run-to-run.  Greedy max-min seeding is
    deterministic given the data and places one seed per well-separated
    mode: start from the point least aligned with the mean direction, then
    repeatedly take the point with the smallest maximum cosine similarity
    to any chosen seed.
    """
    n, d = feats_n.shape
    mean_dir = _normalize(jnp.mean(feats_n, axis=0, keepdims=True))
    first = jnp.argmin((feats_n @ mean_dir.T)[:, 0])
    centroids = jnp.zeros((num_clusters, d), feats_n.dtype)
    centroids = centroids.at[0].set(feats_n[first])
    max_sim = feats_n @ feats_n[first]

    def body(i, state):
        cents, max_sim = state
        nxt = jnp.argmin(max_sim)
        c = feats_n[nxt]
        cents = cents.at[i].set(c)
        return cents, jnp.maximum(max_sim, feats_n @ c)

    centroids, _ = jax.lax.fori_loop(
        1, num_clusters, body, (centroids, max_sim)
    )
    return centroids


@functools.partial(jax.jit, static_argnames=("num_clusters", "iters"))
def kmeans(
    key: jax.Array, feats: Array, *, num_clusters: int, iters: int = 25
) -> tuple[Array, Array]:
    """Spherical (cosine) k-means.  Returns ``(centroids, assignment)``.

    ``key`` is kept for API compatibility; seeding is the deterministic
    farthest-point scheme (see :func:`_farthest_point_init`), so results
    are reproducible across hosts and runs.
    """
    del key  # deterministic seeding
    feats_n = _normalize(feats.astype(jnp.float32))
    centroids = _farthest_point_init(feats_n, num_clusters)

    def step(centroids, _):
        assign = cosine_assign(feats_n, centroids)
        onehot = jax.nn.one_hot(assign, num_clusters, dtype=jnp.float32)
        sums = onehot.T @ feats_n                        # (K, D)
        counts = onehot.sum(axis=0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)
        return _normalize(new), None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    return centroids, cosine_assign(feats_n, centroids)


@dataclasses.dataclass(frozen=True)
# Host-side fitted model: the scan above carries raw centroid arrays, a
# ClusterModel never enters a carry or a jit cache key — assign() lifts
# the centroids to device per call.  # lint: allow-pytree-dataclass
class ClusterModel:
    """Fitted two-stage clustering: fine centroids + fine->coarse map."""

    # lint: allow-mutable-config (host-side, see class comment)
    fine_centroids: np.ndarray      # (F, D)
    # lint: allow-mutable-config
    coarse_centroids: np.ndarray    # (K, D)
    # lint: allow-mutable-config
    fine_to_coarse: np.ndarray      # (F,)

    @property
    def num_clusters(self) -> int:
        return self.coarse_centroids.shape[0]

    def assign(self, feats: Array) -> Array:
        """Assign features to coarse clusters via their nearest fine centroid."""
        fine = cosine_assign(feats, jnp.asarray(self.fine_centroids))
        return jnp.asarray(self.fine_to_coarse)[fine]

    def assign_direct(self, feats: Array) -> Array:
        """Direct nearest-coarse-centroid assignment (paper §6.1 last step)."""
        return cosine_assign(feats, jnp.asarray(self.coarse_centroids))


def hierarchical_kmeans(
    key: jax.Array,
    feats: Array,
    *,
    num_coarse: int = 8,
    num_fine: int = 1024,
    fine_iters: int = 25,
    coarse_iters: int = 50,
) -> ClusterModel:
    """Paper §6.1 two-stage clustering.

    ``num_fine`` is clipped to the dataset size for small (test) corpora.
    """
    n = feats.shape[0]
    num_fine = int(min(num_fine, max(num_coarse, n // 4), n))
    k1, k2 = jax.random.split(key)
    fine_centroids, _ = kmeans(k1, feats, num_clusters=num_fine, iters=fine_iters)
    coarse_centroids, fine_to_coarse = kmeans(
        k2, fine_centroids, num_clusters=num_coarse, iters=coarse_iters
    )
    return ClusterModel(
        fine_centroids=np.asarray(fine_centroids),
        coarse_centroids=np.asarray(coarse_centroids),
        fine_to_coarse=np.asarray(fine_to_coarse),
    )


def partition_indices(assignment: np.ndarray, num_clusters: int) -> list[np.ndarray]:
    """Disjoint per-cluster index lists ``S_1..S_K`` (Fig. 6 data partition)."""
    assignment = np.asarray(assignment)
    return [np.nonzero(assignment == k)[0] for k in range(num_clusters)]


def cluster_balance(assignment: np.ndarray, num_clusters: int) -> np.ndarray:
    counts = np.bincount(np.asarray(assignment), minlength=num_clusters)
    return counts / max(counts.sum(), 1)
