"""Training objectives for heterogeneous experts (paper §2.3, §2.4).

Two objective families:

* ``ddpm`` — ε-prediction (Eq. 3) under a cosine schedule,
* ``fm``   — velocity prediction (Eq. 4) under the linear interpolation path,

plus the Prop.-1 implicit timestep weights ``w_eps = alpha^2/sigma^2`` and
``w_v = 1/sigma^2`` used by the analysis benchmarks, and the diffusion
v-parameterization of Salimans & Ho (``v = alpha eps - sigma x0``) referenced
in §2.4's notation remark.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedules import Schedule, get_schedule, _left_broadcast

Array = jax.Array

# Objective identifiers (also used in configs / checkpoints metadata).
DDPM = "ddpm"
FLOW_MATCHING = "fm"


@dataclasses.dataclass(frozen=True)
class Objective:
    """A diffusion objective = (prediction target, default schedule)."""

    name: str
    default_schedule: str

    @property
    def predicts(self) -> str:
        return {"ddpm": "epsilon", "fm": "velocity"}[self.name]


def get_objective(name: str) -> Objective:
    if name == DDPM:
        return Objective(name=DDPM, default_schedule="cosine")
    if name == FLOW_MATCHING:
        return Objective(name=FLOW_MATCHING, default_schedule="linear")
    raise ValueError(f"unknown objective {name!r}")


def target_for(
    objective: str, schedule: Schedule, x0: Array, eps: Array, t: Array
) -> Array:
    """Regression target for the given objective.

    * DDPM (Eq. 3): target is ``eps``.
    * FM (Eq. 4): target is the path velocity.  For the linear path this is
      ``eps - x0``; in general ``dalpha/dt * x0 + dsigma/dt * eps`` (the same
      formula the §8.1 conversion uses, evaluated with the *true* x0/eps).
    """
    if objective == DDPM:
        return eps
    if objective == FLOW_MATCHING:
        da, ds = schedule.derivs(t)
        da = _left_broadcast(da, x0.ndim)
        ds = _left_broadcast(ds, x0.ndim)
        return da * x0 + ds * eps
    raise ValueError(f"unknown objective {objective!r}")


def mse_loss(pred: Array, target: Array) -> Array:
    """Mean squared error over all non-batch axes, then batch mean."""
    sq = jnp.square(pred.astype(jnp.float32) - target.astype(jnp.float32))
    return jnp.mean(sq)


def diffusion_loss(
    apply_fn: Callable[..., Array],
    params,
    x0: Array,
    eps: Array,
    t: Array,
    *,
    objective: str,
    schedule: Schedule,
    cond: dict | None = None,
) -> Array:
    """Per-expert isolated loss (Eq. 3 / Eq. 4).

    ``apply_fn(params, x_t, t, **cond)`` is the expert network; there is no
    cross-expert term anywhere — decentralization is structural.
    """
    x_t = schedule.perturb(x0, eps, t)
    pred = apply_fn(params, x_t, t, **(cond or {}))
    target = target_for(objective, schedule, x0, eps, t)
    return mse_loss(pred, target)


# ---------------------------------------------------------------------------
# Prop. 1 — implicit timestep weighting (paper §2.4).
# ---------------------------------------------------------------------------


def w_eps(schedule: Schedule, t: Array) -> Array:
    """Eq. 9 — ε-prediction weight ``alpha^2 / sigma^2`` (== SNR)."""
    a, s = schedule.coeffs(t)
    return (a * a) / jnp.maximum(s * s, 1e-12)


def w_v(schedule: Schedule, t: Array) -> Array:
    """Eq. 10 — velocity-prediction weight ``1 / sigma^2``."""
    _, s = schedule.coeffs(t)
    return 1.0 / jnp.maximum(s * s, 1e-12)


def weight_ratio(schedule: Schedule, t: Array) -> Array:
    """Eq. 11 — ``w_v / w_eps = 1 / alpha^2`` (>= 1, diverges as t→1)."""
    a, _ = schedule.coeffs(t)
    return 1.0 / jnp.maximum(a * a, 1e-12)


# ---------------------------------------------------------------------------
# Salimans–Ho v-parameterization (§2.4 notation remark; limitation iii).
# ---------------------------------------------------------------------------


def sh_v_target(schedule: Schedule, x0: Array, eps: Array, t: Array) -> Array:
    """Diffusion v-param target ``v = alpha_t eps - sigma_t x0`` (VP only)."""
    a, s = schedule.coeffs(t)
    a = _left_broadcast(a, x0.ndim)
    s = _left_broadcast(s, x0.ndim)
    return a * eps - s * x0


def sh_v_to_x0(schedule: Schedule, x_t: Array, v: Array, t: Array) -> Array:
    """Under VP (``alpha^2+sigma^2=1``): ``x0 = alpha x_t - sigma v``."""
    a, s = schedule.coeffs(t)
    a = _left_broadcast(a, x_t.ndim)
    s = _left_broadcast(s, x_t.ndim)
    return a * x_t - s * v


def sample_timesteps(
    key: jax.Array, batch: int, *, objective: str, dtype=jnp.float32
) -> Array:
    """Uniform timestep sampling in each objective's native domain (§6.3).

    DDPM experts: discrete ``t ~ U{0..999}``; FM experts ``t ~ U(0,1)``.
    Both returned as *continuous* native time in [0, 1] plus the discrete
    index for the embedding table (Eq. 21) is recovered downstream.
    """
    if objective == DDPM:
        idx = jax.random.randint(key, (batch,), 0, 1000)
        return idx.astype(dtype) / 999.0
    return jax.random.uniform(key, (batch,), dtype=dtype)
