"""Pluggable expert-dispatch API: ``DispatchPlan`` + executor backends.

The paper's inference-time fusion routes each sample to its top-k experts
(§3.1); *how* those routed forwards execute is a serving-engine decision
that every perf rung (grouped dispatch, quantized experts, cross-host
routing) needs to plug into.  This module is that seam:

* ``DispatchPlan`` — a traced, batch-shaped description of one step's
  routing decisions, computed once per step from the router posterior:
  per-sample expert slots and fusion weights, plus the sort-based *group*
  view of the same assignments (flat sort order, its inverse, and
  per-expert segment offsets).
* ``ExpertExecutor`` — the protocol every backend implements: turn a plan
  plus the step inputs into the raw per-slot routed ``predictions``
  (plus tiled weights/slot ids — the fused-kernel operands).  The sampler
  chooses the kernel: ``velocity`` (Eq. 1 combine through
  ``kernels.ops.fused_velocity``) on the unfused path, or the step-fused
  ``kernels.ops.fused_step`` which additionally folds the CFG combine
  and Euler update so no intermediate velocity materializes in HBM.
* Three backends:

  - ``GatheredExecutor`` — per-sample param gather + ``vmap`` (the
    original compute-sparse path, extracted): each routed slot gathers
    its expert's params per sample and runs one vmapped lane per sample.
    Batch-uniform plans (threshold router) collapse to a scalar gather.
  - ``GroupedExecutor`` — sort-based grouped execution (DDM/Paris-style):
    argsort the ``B·k`` assignments by expert, run each expert **once**
    over its contiguous segment (padded to a power-of-two bucket so the
    trace stays static-shaped; ``lax.switch`` picks the bucket at run
    time and empty segments skip the forward entirely), then unsort.
    Per-expert params come from *static* slices of the stacked pytree, so
    on an ``("expert", "data")`` mesh each expert's weights resolve from
    their resident shard instead of a per-sample dynamic-gather
    (all-gather) of ``B·k`` param copies.
  - ``DenseExecutor`` — the heterogeneous-``apply_fn`` fallback: every
    expert runs through its own apply (no stacking required); batch-
    uniform plans run only the routed expert via ``lax.switch``.

Plan invariants (tested in ``tests/test_dispatch.py``):

* ``segment_offsets`` is monotone with ``segment_offsets[0] == 0`` and
  ``segment_offsets[-1] == B·k`` (every assignment lands in exactly one
  expert's segment);
* ``unsort_order`` is the true inverse permutation of ``sort_order``;
* sorted assignment ``r`` belongs to expert ``e`` iff
  ``segment_offsets[e] <= r < segment_offsets[e+1]``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.conversion import ConversionConfig
from repro.core.param_store import DenseStore, ExpertParamStore, as_store
from repro.kernels import ops

Array = jax.Array

#: valid ``SamplerConfig.dispatch`` values (``auto`` resolves per engine
#: mode and expert-set shape, see ``resolve_dispatch``).
DISPATCH_BACKENDS = ("auto", "gathered", "grouped", "ragged", "dense")


# ---------------------------------------------------------------------------
# DispatchPlan
# ---------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("slot_idx", "slot_w", "sort_order", "unsort_order",
                 "segment_offsets"),
    meta_fields=("num_experts", "uniform"),
)
@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Traced, batch-shaped routing decisions for one sampling step.

    With ``B`` samples, ``k`` routed slots per sample and ``K`` experts,
    the ``N = B·k`` flat *assignments* are numbered ``a = s·k + j``
    (sample ``s``, slot ``j``).

    Attributes:
      slot_idx: ``(B, k)`` int32 — expert id per routed slot.
      slot_w: ``(B, k)`` — fusion weight per slot (zero-weight slots are
        legal; their forward is wasted but the fused result is exact).
      sort_order: ``(N,)`` int32 — assignment ids in expert-grouped order
        (stable argsort of the flattened ``slot_idx``; ties keep
        assignment order, so the plan is deterministic).
      unsort_order: ``(N,)`` int32 — inverse permutation:
        ``unsort_order[a]`` is assignment ``a``'s position in the sorted
        view; ``sort_order[unsort_order] == arange(N)``.
      segment_offsets: ``(K+1,)`` int32 — expert ``e``'s sorted segment is
        ``sort_order[segment_offsets[e]:segment_offsets[e+1]]``.
      num_experts: static ``K``.
      uniform: static flag — every sample routes to the same expert(s)
        (the §3.3 threshold router); executors may collapse the batch to
        a single expert forward.
    """

    slot_idx: Array
    slot_w: Array
    sort_order: Array
    unsort_order: Array
    segment_offsets: Array
    num_experts: int
    uniform: bool = False

    @property
    def batch(self) -> int:
        return self.slot_idx.shape[0]

    @property
    def slots_per_sample(self) -> int:
        return self.slot_idx.shape[1]

    @property
    def num_assignments(self) -> int:
        return self.sort_order.shape[0]


def topk_slots(weights: Array, k: int) -> tuple[Array, Array]:
    """Expert slots for routed-only execution.

    Args:
      weights: ``(B, K)`` final fusion weights (≤ k nonzero per row).
      k: number of slots to run.

    Returns:
      ``(slot_idx, slot_w)`` both ``(B, k)`` — the expert index and fusion
      weight per slot.  Slots beyond the nonzero support carry zero weight
      (their forward is wasted but the fused result is exact).
    """
    slot_w, slot_idx = jax.lax.top_k(weights, min(k, weights.shape[-1]))
    return slot_idx, slot_w


def plan_from_slots(
    slot_idx: Array,
    slot_w: Array,
    num_experts: int,
    *,
    uniform: bool = False,
) -> DispatchPlan:
    """Build a plan (including the sorted group view) from routed slots.

    The group view costs one stable argsort over the ``B·k`` assignments
    plus a scatter for the inverse permutation and a bincount-cumsum for
    the segment offsets; executors that never touch it (gathered, dense)
    let XLA dead-code-eliminate it.
    """
    flat = slot_idx.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    sort_order = jnp.argsort(flat, stable=True).astype(jnp.int32)
    unsort_order = (
        jnp.zeros((n,), jnp.int32).at[sort_order].set(
            jnp.arange(n, dtype=jnp.int32))
    )
    counts = jnp.zeros((num_experts,), jnp.int32).at[flat].add(1)
    segment_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
    )
    return DispatchPlan(
        slot_idx=slot_idx.astype(jnp.int32),
        slot_w=slot_w,
        sort_order=sort_order,
        unsort_order=unsort_order,
        segment_offsets=segment_offsets,
        num_experts=num_experts,
        uniform=uniform,
    )


def routed_slots(
    weights: Array,
    k: int,
    *,
    valid: Array | None = None,
) -> tuple[Array, Array]:
    """Top-``k`` slot selection with the elastic-membership guard.

    The slot half of :func:`make_dispatch_plan`, exposed separately for
    callers that carry raw ``(slot_idx, slot_w)`` row state across steps
    (the continuous-batching scheduler refreshes slots per request on its
    own R-phase and rebuilds the plan's group view with
    :func:`plan_from_slots` each step).

    ``valid`` (optional ``(K,)`` bool): any slot whose selected expert is
    invalid — possible only when ``k`` exceeds the live count, since
    masked fusion weights give dead slots zero probability — is remapped
    to the first valid expert with weight exactly 0, keeping the slots
    NaN-safe against whatever bytes an evicted capacity slot holds.
    """
    slot_idx, slot_w = topk_slots(weights, k)
    if valid is not None:
        valid = jnp.asarray(valid, dtype=bool)
        fallback = jnp.argmax(valid).astype(jnp.int32)
        ok = valid[slot_idx]                              # (B, k)
        slot_idx = jnp.where(ok, slot_idx, fallback)
        slot_w = jnp.where(ok, slot_w, jnp.zeros_like(slot_w))
    return slot_idx, slot_w


def make_dispatch_plan(
    weights: Array,
    k: int,
    *,
    uniform: bool = False,
    valid: Array | None = None,
) -> DispatchPlan:
    """Plan for routed execution: top-``k`` slots of the fusion weights.

    This is the §3.1 slot selection (formerly ``fusion.topk_slots``)
    folded into plan construction — the single per-step entry point for
    every routed backend.

    ``valid`` (optional ``(K,)`` bool) is the elastic-membership guard:
    any slot whose selected expert is invalid — possible only when ``k``
    exceeds the live count, since masked fusion weights give dead slots
    zero probability — is remapped to the first valid expert with weight
    exactly 0 (see :func:`routed_slots`).  The remap keeps the plan
    NaN-safe against whatever bytes an evicted/empty capacity slot
    holds: a dead expert's params are never gathered and never run a
    segment forward, and a zero-weight fallback slot contributes exact
    ``0.0`` to the fused combine.
    """
    slot_idx, slot_w = routed_slots(weights, k, valid=valid)
    return plan_from_slots(slot_idx, slot_w, weights.shape[-1],
                           uniform=uniform)


def full_dispatch_plan(weights: Array) -> DispatchPlan:
    """Plan with one slot per expert (dense execution, strategy='full').

    ``slot_idx`` is ``arange(K)`` per row and ``slot_w`` the full weight
    matrix, so slot ``j`` *is* expert ``j`` and the dense executor's
    expert-order prediction stack lines up with the fused-kernel slots.
    """
    b, num_experts = weights.shape
    slot_idx = jnp.broadcast_to(
        jnp.arange(num_experts, dtype=jnp.int32)[None], (b, num_experts)
    )
    return plan_from_slots(slot_idx, weights, num_experts)


def tile_plan(plan: DispatchPlan, g: int) -> DispatchPlan:
    """Plan for ``g`` stacked guidance branches of the same batch.

    Batched CFG concatenates the cond/uncond branches along the batch
    axis; both branches share each sample's routing, so the tiled plan
    just repeats the slots ``g`` times and rebuilds the group view over
    the ``g·B·k`` assignments.
    """
    if g == 1:
        return plan
    return plan_from_slots(
        jnp.concatenate([plan.slot_idx] * g, axis=0),
        jnp.concatenate([plan.slot_w] * g, axis=0),
        plan.num_experts,
        uniform=plan.uniform,
    )


# ---------------------------------------------------------------------------
# Executor protocol + shared helpers
# ---------------------------------------------------------------------------


@runtime_checkable
class ExpertExecutor(Protocol):
    """Backend turning a plan + step inputs into routed predictions.

    ``predictions`` receives the pre-CFG batch ``x``/``tb`` of size ``B``
    with grouped conditioning ``cond_g`` (leaves ``(B, g, ...)`` from
    ``sampling._cfg_grouped_cond``; ``g=2`` when CFG branches are batched,
    else 1) plus the step's ``(5, K)`` unified-coefficient table, and
    returns the raw per-slot native predictions ``(k, g·B, *latent)`` in
    ``[cond; uncond]`` branch-major order together with the tiled fusion
    weights and slot indices (both ``(g·B, k)``) — the exact operands of
    the fused kernels.  How those feed a kernel is the *sampler's*
    decision: the unfused path runs ``kernels.ops.fused_velocity`` (via
    ``velocity`` below) and combines CFG + Euler as separate ops; the
    step-fused hot path hands the same operands to
    ``kernels.ops.fused_step``, which folds CFG combine and the Euler
    update into the convert-and-fuse kernel so no intermediate velocity
    ``u`` ever materializes in HBM.

    ``velocity`` is the unfused convenience form: ``predictions``
    followed by the Eq. 1 convert-and-fuse, returning the fused velocity
    ``(g·B, *latent)``.
    """

    name: str

    def predictions(
        self,
        plan: DispatchPlan,
        x: Array,
        tb: Array,
        cond_g: dict,
        g: int,
        tab: Array,
    ) -> tuple[Array, Array, Array]:
        ...

    def velocity(
        self,
        plan: DispatchPlan,
        x: Array,
        tb: Array,
        cond_g: dict,
        g: int,
        tab: Array,
    ) -> Array:
        ...


class _FusedVelocity:
    """Shared unfused ``velocity``: ``predictions`` + convert-and-fuse."""

    def velocity(self, plan, x, tb, cond_g, g, tab):
        preds, w_all, idx_all = self.predictions(plan, x, tb, cond_g, g,
                                                 tab)
        return _fused(preds, _tile(x, g), w_all, idx_all, tab, self.conv)


def _tile(a: Array, g: int) -> Array:
    return a if g == 1 else jnp.concatenate([a] * g, axis=0)


def _flatten_groups(cond_g: dict, g: int) -> dict:
    """``(B, g, ...)`` grouped cond -> ``(g·B, ...)`` branch-major flat."""
    return {
        key: jnp.moveaxis(v, 1, 0).reshape((g * v.shape[0],) + v.shape[2:])
        for key, v in cond_g.items()
    }


def slot_coef(tab: Array, idx_all: Array) -> Array:
    """Gather the ``(5, K)`` step table into per-slot form ``(5, k, Bx)``.

    The coefficient operand shared by ``kernels.ops.fused_velocity`` and
    the step-fused ``kernels.ops.fused_step``.
    """
    return jnp.moveaxis(tab[:, idx_all], 1, 2)


def slot_coef_rows(tabs: Array, idx_all: Array) -> Array:
    """Per-row variant of :func:`slot_coef` for mixed-timestep batches.

    Each batch row carries its *own* ``(5, K)`` step table (``tabs`` is
    ``(Bx, 5, K)`` — row ``r``'s slice of the per-run ``(S, 5, K)``
    table at that row's current timestep), and the gather picks row
    ``r``'s routed-slot columns from row ``r``'s table:
    ``out[c, j, r] = tabs[r, c, idx_all[r, j]]``, returned ``(5, k,
    Bx)``.  When every row holds the same table this is bitwise equal to
    ``slot_coef(tab, idx_all)`` — the lockstep path is the uniform
    special case.
    """
    g = jnp.take_along_axis(tabs, idx_all[:, None, :], axis=2)  # (Bx, 5, k)
    return jnp.moveaxis(g, 0, 2)                                # (5, k, Bx)


def _fused(
    preds: Array,        # (k, Bx, *latent) per-slot native predictions
    x_all: Array,        # (Bx, *latent)
    w_all: Array,        # (Bx, k)
    idx_all: Array,      # (Bx, k)
    tab: Array,          # (5, K)
    conv: ConversionConfig,
) -> Array:
    """Per-slot coefficient gather + fused convert-and-fuse kernel."""
    return ops.fused_velocity(
        preds, x_all, w_all, slot_coef(tab, idx_all),
        clamp=conv.clamp, alpha_min=conv.alpha_min,
    )


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# GatheredExecutor — per-sample gather + vmap (the original routed path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GatheredExecutor(_FusedVelocity):
    """Per-sample param gather + vmap over routed slots.

    Each of the ``k`` slots gathers its expert's params per sample
    (``store.gather(slot_idx[:, j])`` — leaves come back ``(B, ...)``)
    and runs one vmapped model instance per sample; the ``g`` guidance
    branches share the sample's latent *and* routed expert, so they run
    inside the same vmapped instance and the params are gathered once,
    not per branch.  Batch-uniform plans collapse to a scalar gather and
    a single plain forward.  Params resolve through an
    ``ExpertParamStore``: a ``DenseStore`` emits the exact gather ops
    this executor used to hand-roll, while a ``QuantizedStore`` gathers
    int8/fp8 bytes and dequantizes only the routed slices through the
    fused ``hetero_fuse_dequant`` kernel.
    """

    apply_fn: Callable[..., Array]
    store: ExpertParamStore
    conv: ConversionConfig
    name: str = "gathered"

    def _vmapped(self, g: int):
        apply_fn = self.apply_fn

        def one(p1, x1, t1, c1):
            xg = jnp.broadcast_to(x1[None], (g,) + x1.shape)
            tg = jnp.full((g,), t1)
            return apply_fn(p1, xg, tg, **c1)             # (g, *latent)

        return jax.vmap(one)

    def predictions(self, plan, x, tb, cond_g, g, tab):
        b = x.shape[0]
        k = plan.slots_per_sample
        w_all = _tile(plan.slot_w, g)
        idx_all = _tile(plan.slot_idx, g)
        if plan.uniform:
            # Whole batch routes to one expert: scalar gather, one forward.
            p = self.store.gather(plan.slot_idx[0, 0])
            cond_all = _flatten_groups(cond_g, g)
            preds = self.apply_fn(p, _tile(x, g), _tile(tb, g),
                                  **cond_all)[None]
            return preds, w_all, idx_all
        vmapped = self._vmapped(g)
        cols = []
        for j in range(k):
            pj = self.store.gather(plan.slot_idx[:, j])
            cols.append(vmapped(pj, x, tb, cond_g))       # (B, g, *latent)
        preds = jnp.moveaxis(jnp.stack(cols), 2, 1)       # (k, g, B, ...)
        preds = preds.reshape((k, g * b) + preds.shape[3:])
        return preds, w_all, idx_all


# ---------------------------------------------------------------------------
# GroupedExecutor — sort-based grouped execution (DDM/Paris-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GroupedExecutor(_FusedVelocity):
    """Sort assignments by expert; one segment pass per resident expert.

    Pipeline per step (all static-shaped so it traces once under scan):

    1. flatten the ``g`` guidance branches to ``Bx = g·B`` rows and tile
       the plan (both branches share each sample's routing);
    2. gather the ``N = Bx·k`` assignment rows into expert-sorted order
       (a cheap gather of *latents*, not params) and zero-pad the sorted
       buffer to the next power of two ``Np``;
    3. for each expert ``e`` (static Python loop): pick the padded
       power-of-two bucket covering its segment length with
       ``lax.switch`` and run ONE forward over that bucket slice — empty
       segments take the 0-bucket branch and skip the forward entirely.
       Params come from a *static* slice ``store.expert(e)``, so on an
       ``("expert", "data")`` mesh the weights resolve from the shard
       that owns expert ``e`` instead of a per-sample dynamic-gather
       (expert-axis all-gather) of ``B·k`` param copies; a
       ``QuantizedStore`` dequantizes exactly that resident slice inline
       (fused ``hetero_fuse_dequant``), so only int8/fp8 bytes sit
       stacked in HBM;
    4. scatter each bucket's valid rows back into a flat prediction
       buffer (out-of-segment bucket rows are dropped), unsort, and fuse
       through the same ``fused_velocity`` kernel as every other backend.

    Per-step expert forwards: at most one per expert with a non-empty
    segment — ≤ ``K`` resident experts, vs ``B·k`` vmapped per-sample
    lanes on the gathered path.  Bucket overshoot bounds wasted rows at
    < 2× the true segment length.
    """

    apply_fn: Callable[..., Array]
    store: ExpertParamStore
    conv: ConversionConfig
    name: str = "grouped"

    def predictions(self, plan, x, tb, cond_g, g, tab):
        b = x.shape[0]
        k = plan.slots_per_sample
        n_experts = plan.num_experts
        x_all = _tile(x, g)
        t_all = _tile(tb, g)
        cond_all = _flatten_groups(cond_g, g)
        p = tile_plan(plan, g)
        n = p.num_assignments                              # g·B·k
        np2 = _next_pow2(n)
        off = p.segment_offsets

        # Sorted assignment rows (gathers of latents/cond, not params).
        sample_ids = p.sort_order // k                     # (N,)
        xs = x_all[sample_ids]
        ts = t_all[sample_ids]
        cs = {key: v[sample_ids] for key, v in cond_all.items()}
        if np2 > n:
            pad = [(0, np2 - n)]
            xs = jnp.pad(xs, pad + [(0, 0)] * (xs.ndim - 1))
            ts = jnp.pad(ts, pad)
            cs = {key: jnp.pad(v, pad + [(0, 0)] * (v.ndim - 1))
                  for key, v in cs.items()}

        out_sd = jax.eval_shape(
            lambda p_, x_, t_, c_: self.apply_fn(p_, x_, t_, **c_),
            self.store.expert(0),
            xs[:1], ts[:1], {key: v[:1] for key, v in cs.items()},
        )
        buf = jnp.zeros((np2,) + out_sd.shape[1:], out_sd.dtype)

        sizes = [1 << j for j in range(np2.bit_length())]  # 1..np2
        thresholds = jnp.array([0] + sizes[:-1], jnp.int32)

        # Dense stores: one cheap static slice per expert, hoisted out of
        # the switch (slicing it once per bucket branch would only bloat
        # the already branch-heavy grouped trace).  Quantized stores:
        # slice+dequant trace INSIDE each branch instead, so an expert
        # with an empty segment skips its fused dequant along with the
        # forward.
        dense_slices = (
            [self.store.expert(e) for e in range(n_experts)]
            if isinstance(self.store, DenseStore) else None
        )

        def _branches(e):
            def run(size):
                def branch(buf):
                    params_e = dense_slices[e] if dense_slices is not None \
                        else self.store.expert(e)
                    start = jnp.minimum(off[e], np2 - size)
                    xb = jax.lax.dynamic_slice_in_dim(xs, start, size)
                    tb_ = jax.lax.dynamic_slice_in_dim(ts, start, size)
                    cb = {
                        key: jax.lax.dynamic_slice_in_dim(v, start, size)
                        for key, v in cs.items()
                    }
                    pred = self.apply_fn(params_e, xb, tb_, **cb)
                    pos = start + jnp.arange(size, dtype=jnp.int32)
                    valid = (pos >= off[e]) & (pos < off[e + 1])
                    # invalid rows target index np2 -> dropped by scatter
                    tgt = jnp.where(valid, pos, np2)
                    return buf.at[tgt].set(pred.astype(buf.dtype),
                                           mode="drop")
                return branch

            # branch 0: empty segment — no forward at all.
            return [lambda buf: buf] + [run(s) for s in sizes]

        for e in range(n_experts):
            seg_len = off[e + 1] - off[e]
            bucket_id = jnp.sum(seg_len > thresholds)
            buf = jax.lax.switch(bucket_id, _branches(e), buf)

        preds_flat = buf[p.unsort_order]                   # (N, *latent)
        preds = preds_flat.reshape((g * b, k) + preds_flat.shape[1:])
        preds = jnp.moveaxis(preds, 1, 0)                  # (k, g·B, ...)
        return preds, p.slot_w, p.slot_idx


# ---------------------------------------------------------------------------
# RaggedExecutor — one-kernel ragged grouped GEMM (pair-major)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RaggedExecutor(_FusedVelocity):
    """Pair-major ragged execution: all experts' segments in one pass.

    Walks the same expert-sorted segment layout as the grouped backend,
    but at *pair* granularity instead of row granularity: the ``g``
    guidance replicas of a (sample, slot) assignment share the latent,
    the timestep and the routed expert (``tile_plan`` repeats slots per
    branch), so the sorted ``N = g·B·k`` rows regroup into ``P = B·k``
    pairs of ``g`` replicas each.  The executor hands the
    ``ragged_apply_fn`` one representative latent per pair plus the
    per-pair expert ids derived from ``segment_offsets`` (via the
    plan's sort), and the apply runs every dense layer as ONE ragged
    grouped GEMM over all resident experts
    (``kernels.ops.ragged_expert_matmul`` →
    ``kernels.ragged_gemm.ragged_gemm`` on TPU):

    * no per-expert ``lax.switch`` branches, no power-of-two bucket
      padding — work scales with actual assignments, and empty segments
      / dead validity slots cost zero kernel tiles;
    * weights resolve per row *tile* from the raw stacked leaves
      (``store.ragged_view()``) — quantized stores contract on int8/fp8
      operands with the dequant scale fused into the GEMM epilogue,
      never materializing full-precision copies;
    * the conditioning-independent prefix of the network computes once
      per pair and broadcasts to the replicas (the grouped backend's
      black-box ``apply_fn`` contract cannot see that structure).

    Dense float32 stores are bitwise-identical to the grouped backend;
    quantized stores match within the store's quantization error.
    Membership (``valid``) stays traced data: hot add/evict reaches
    this executor as new plan/store *values* under the same trace.
    """

    ragged_apply_fn: Callable[..., Array]
    store: ExpertParamStore
    conv: ConversionConfig
    name: str = "ragged"

    def predictions(self, plan, x, tb, cond_g, g, tab):
        b = x.shape[0]
        k = plan.slots_per_sample
        x_all = _tile(x, g)
        t_all = _tile(tb, g)
        cond_all = _flatten_groups(cond_g, g)
        p = tile_plan(plan, g)
        n = p.num_assignments                              # g·B·k
        npair = n // g                                     # B·k

        # Pair view of the sorted assignments: sorted row r is replica
        # ``gidx`` of pair ``pair`` (sample-major pair ids, slot minor).
        sample_ids = p.sort_order // k                     # (N,) in [0, g·B)
        gidx = sample_ids // b                             # guidance branch
        base = sample_ids % b                              # sample in [0, B)
        slot = p.sort_order % k
        pair = base * k + slot                             # (N,) pair id
        # pg_pos[q, j] = sorted position of pair q's replica j — exists
        # and is unique because tile_plan repeats each slot per branch.
        pg_pos = jnp.zeros((npair, g), jnp.int32).at[pair, gidx].set(
            jnp.arange(n, dtype=jnp.int32)
        )
        rep = pg_pos[:, 0]                                 # representative
        row_e = p.slot_idx.reshape(-1)[p.sort_order]       # (N,) expert/row
        pe = row_e[rep]                                    # (P,) expert/pair

        xs = x_all[sample_ids][rep]                        # (P, *latent)
        ts = t_all[sample_ids][rep]                        # (P,)
        cs = {key: v[sample_ids][pg_pos] for key, v in cond_all.items()}

        view = self.store.ragged_view()
        out = self.ragged_apply_fn(view, xs, ts, cs, pe, g)  # (P·g, ...)
        out = out.reshape((npair, g) + out.shape[1:])
        preds_sorted = out[pair, gidx]                     # (N, *latent)
        preds_flat = preds_sorted[p.unsort_order]
        preds = preds_flat.reshape((g * b, k) + preds_flat.shape[1:])
        preds = jnp.moveaxis(preds, 1, 0)                  # (k, g·B, ...)
        return preds, p.slot_w, p.slot_idx


# ---------------------------------------------------------------------------
# DenseExecutor — heterogeneous apply_fn fallback
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DenseExecutor(_FusedVelocity):
    """Run every expert through its own ``apply_fn`` (no stacking needed).

    The fallback for expert sets the sparse backends cannot stack
    (heterogeneous architectures / param structures).  Batch-uniform
    plans (threshold router) still run only the routed expert, via
    ``lax.switch`` over the expert closures.
    """

    apply_fns: Sequence[Callable[..., Array]]
    params: Sequence
    conv: ConversionConfig
    name: str = "dense"

    def predictions(self, plan, x, tb, cond_g, g, tab):
        x_all = _tile(x, g)
        t_all = _tile(tb, g)
        cond_all = _flatten_groups(cond_g, g)
        w_all = _tile(plan.slot_w, g)
        idx_all = _tile(plan.slot_idx, g)
        if plan.uniform:
            idx0 = plan.slot_idx[0, 0]
            branches = [
                functools.partial(
                    lambda fn, p, op: fn(p, op[0], op[1], **op[2]), fn, p,
                )
                for fn, p in zip(self.apply_fns, self.params)
            ]
            preds = jax.lax.switch(
                idx0, branches, (x_all, t_all, cond_all)
            )[None]
        else:
            preds = jnp.stack([
                fn(p, x_all, t_all, **cond_all)
                for fn, p in zip(self.apply_fns, self.params)
            ])
        return preds, w_all, idx_all


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------


def resolve_dispatch(
    dispatch: str, mode: str, stackable: bool, uniform: bool = False,
    ragged_ok: bool = False,
) -> str:
    """Map a ``SamplerConfig.dispatch`` request to a concrete backend.

    Args:
      dispatch: requested backend (``DISPATCH_BACKENDS``).
      mode: resolved engine mode (``'routed'`` or ``'dense'`` — the
        reference engine never reaches executor selection).
      stackable: stacked single-pytree params are available (homogeneous
        apply_fn + identical param structure, as a raw stacked pytree or
        an ``ExpertParamStore``).
      uniform: the plan is batch-uniform (§3.3 threshold router) — every
        sample routes to the same expert(s).
      ragged_ok: the expert set publishes a shared ``ragged_apply_fn``
        (``ExpertSpec``) so the one-kernel ragged GEMM backend can run.

    ``auto`` prefers the **ragged** backend whenever the expert set can
    run it (params stack, per-sample routing, a published
    ``ragged_apply_fn``): one ragged grouped GEMM per dense layer
    replaces the grouped backend's per-expert ``lax.switch`` branches
    and power-of-two bucket padding, is bitwise-identical to grouped
    for dense float32 stores, and measures ≥1.15× grouped img/s on the
    tracked configuration (``BENCH_sampler.json`` ``ragged`` section).
    Expert sets without a ragged apply keep the previous preference
    order: grouped (1.22× faster than gathered on the same tracked
    config) when params stack and routing is per-sample; batch-uniform
    plans fall back to gathered, whose scalar-gather path runs exactly
    one forward with none of the bucket machinery; non-stackable expert
    sets fall back to dense.  Explicit ``gathered``/``grouped``/
    ``ragged`` raise a clear error when their preconditions don't hold,
    instead of silently degrading.
    """
    if dispatch not in DISPATCH_BACKENDS:
        raise ValueError(
            f"unknown dispatch backend {dispatch!r}; "
            f"expected one of {DISPATCH_BACKENDS}"
        )
    if mode == "dense":
        if dispatch in ("gathered", "grouped", "ragged"):
            raise ValueError(
                f"dispatch={dispatch!r} requires routed execution "
                f"(strategy in top1/topk/threshold with a routable expert "
                f"set); this configuration resolved to the dense engine"
            )
        return "dense"
    if dispatch == "auto":
        if not stackable:
            return "dense"
        if uniform:
            return "gathered"
        return "ragged" if ragged_ok else "grouped"
    if dispatch in ("gathered", "grouped", "ragged") and not stackable:
        raise ValueError(
            f"dispatch={dispatch!r} needs a shared apply_fn with stackable "
            f"params (see models.dit.stack_expert_params); heterogeneous "
            f"expert sets must use dispatch='dense'"
        )
    if dispatch == "ragged" and not ragged_ok:
        raise ValueError(
            "dispatch='ragged' needs a shared ragged_apply_fn on every "
            "ExpertSpec (see models.dit.make_ragged_expert_apply) and "
            "per-sample routing; this expert set does not publish one"
        )
    return dispatch


def make_executor(
    backend: str,
    *,
    apply_fns: Sequence[Callable[..., Array]],
    params: Sequence,
    stacked_params,
    conv: ConversionConfig,
    ragged_apply_fn: Callable[..., Array] | None = None,
) -> ExpertExecutor:
    """Instantiate the executor for a resolved backend name.

    ``stacked_params`` may be a raw stacked pytree (the pre-store calling
    convention, wrapped into a bit-identical ``DenseStore``) or any
    ``ExpertParamStore`` (e.g. a ``QuantizedStore`` for int8/fp8 expert
    weights).  ``ragged_apply_fn`` is the shared pair-major forward
    required by the ``ragged`` backend (``ExpertSpec.ragged_apply_fn``).
    """
    if backend in ("gathered", "grouped", "ragged"):
        store = as_store(stacked_params)
        if store is None:
            raise ValueError(
                f"dispatch={backend!r} needs stacked params or an "
                f"ExpertParamStore; got None"
            )
        if backend == "gathered":
            return GatheredExecutor(apply_fns[0], store, conv)
        if backend == "ragged":
            if ragged_apply_fn is None:
                raise ValueError(
                    "dispatch='ragged' needs a shared ragged_apply_fn "
                    "(see models.dit.make_ragged_expert_apply)"
                )
            return RaggedExecutor(ragged_apply_fn, store, conv)
        return GroupedExecutor(apply_fns[0], store, conv)
    if backend == "dense":
        if params is None:
            raise ValueError(
                "dispatch='dense' runs each expert through its own params "
                "list, which this engine no longer holds (a quantized "
                "ExpertParamStore replaced the full-precision per-expert "
                "params); use a routed strategy or param_dtype='native'"
            )
        return DenseExecutor(tuple(apply_fns), tuple(params), conv)
    raise ValueError(f"unknown executor backend {backend!r}")
