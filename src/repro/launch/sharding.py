"""GSPMD partition rules for the model zoo + DiT experts.

Strategy (DESIGN.md §5): 2D "FSDP × TP" —

* column-parallel weights (attention q/k/v, FFN up/gate, SSM in_proj,
  MoE up/gate): last dim on "model", second-to-last on "data";
* row-parallel weights (attention o, FFN down, SSM out_proj, MoE down):
  last dim on "data", second-to-last on "model";
* embeddings: feature dim on "model";
* norms / scalars / small tables: replicated;
* batch dims of inputs/caches on ("pod","data") (pod folds into data);
* batch-1 long-context decode: KV-cache *sequence* axis shards on "data"
  (sequence-parallel cache attention), SSM-state heads on "model".

GSPMD tolerates non-divisible dims (pads); every d_model/d_ff/kv_dim in
the assigned configs is divisible by 16 regardless.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes
from repro.models.config import DiTConfig, LMConfig

# Leaf-name → (trailing-dims spec builder). `dp` = data axes tuple.
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w1", "in_proj", "vision_proj",
        "text_proj", "mlp1", "mlp2", "out", "mod", "cls_head"}
_ROW = {"wo", "w_down", "w2", "out_proj"}
# The unembed projection only TP-shards its vocab dim: FSDP-sharding its
# d_model (contraction) dim on "data" collides with batch-on-"data" in the
# CE backward and GSPMD re-replicates the global batch (measured 12×
# memory-traffic blowup on internlm2 train_4k — see EXPERIMENTS.md §Perf).
_COL_TP_ONLY = {"unembed"}


def _path_names(path) -> list[str]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
    return names


def _rule_for(names: list[str], ndim: int, dp) -> P:
    """Trailing-dim partition rule; leading (stacked-layer) dims -> None."""
    dpa = dp if len(dp) > 1 else dp[0]
    owner = None
    for n in reversed(names):
        if (n in _COL or n in _ROW or n in _COL_TP_ONLY
                or n in ("emb", "router", "conv_w", "table", "block_embed")):
            owner = n
            break
    if ndim <= 1:
        return P()
    if owner == "emb":
        # embedding tables (V, D) / pos tables (S, D): shard feature dim.
        return _pad(P("model"), ndim, trailing=1)
    if owner == "table":
        return P(*([None] * ndim))
    if owner == "router":                    # MoE gate: replicate (small)
        return P(*([None] * ndim))
    if owner == "conv_w":                    # (K, C): shard channels
        return _pad(P("model"), ndim, trailing=1)
    if owner == "block_embed":               # (L, 6, d)
        return P(*([None] * ndim))
    if owner in _COL_TP_ONLY:
        if ndim >= 2:
            return _pad(P(None, "model"), ndim, trailing=2)
        return P("model")
    if owner in _COL:
        if ndim >= 2:
            return _pad(P(dpa, "model"), ndim, trailing=2)
        return P("model")
    if owner in _ROW:
        if ndim >= 2:
            return _pad(P("model", dpa), ndim, trailing=2)
        return P(dpa)
    # biases / norms / A_log / dt_bias / D / unknowns: replicate.
    return P(*([None] * ndim))


def _pad(spec: P, ndim: int, trailing: int) -> P:
    return P(*([None] * (ndim - trailing) + list(spec)))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments whose mesh size doesn't divide the dim.

    jit in_shardings require exact divisibility (unlike internal GSPMD
    propagation); any non-divisible assignment falls back to replication
    of that dim.
    """
    out = []
    for i, axis in enumerate(spec):
        if axis is None or i >= len(shape):
            out.append(None if i >= len(shape) else axis)
            continue
        if shape[i] % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    # pad/trim to ndim
    out = out[: len(shape)] + [None] * (len(shape) - len(out))
    return P(*out)


def param_specs(params_shape: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching an eval_shape'd param tree.

    ``fsdp=False`` (default): TP-only weight sharding + pure data
    parallelism — fits every arch below ~8B.  ``fsdp=True``: weight
    matrices additionally shard over the data axis (storage); models must
    run under the launch.fsdp gather-before-use policy.
    """
    dp = data_axes(mesh)

    def leaf(path, x):
        names = _path_names(path)
        # bias vectors follow their weight's last-dim sharding.
        if names[-1] == "b":
            w_spec = _rule_for(names[:-1] + ["w"], 2, dp)
            last = w_spec[-1] if len(w_spec) else None
            spec = P(last)
        elif names[-1] == "w":
            spec = _rule_for(names[:-1], x.ndim, dp)
        else:
            spec = _rule_for(names, x.ndim, dp)
        if not fsdp:
            dset = set(dp)
            spec = P(*[
                None if (a in dset or isinstance(a, tuple)) else a
                for a in spec
            ])
        return sanitize_spec(spec, x.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def param_shardings(params_shape: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_shape, mesh, fsdp=fsdp),
    )


# ---------------------------------------------------------------------------
# Input/batch/cache specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: LMConfig, mesh: Mesh, batch: dict) -> dict:
    """Shard batch dicts: leading batch dim over (pod, data)."""
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    ndev = 1
    for a in dp:
        ndev *= mesh.shape[a]

    def leaf(x):
        b = x.shape[0]
        if b % ndev == 0:
            return P(dpa, *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree.map(leaf, batch)


def _first_divisible(shape, dims: list[int], mesh: Mesh, axis) -> int | None:
    """First dim (by priority) divisible by the mesh axis size."""
    n = _axis_size(mesh, axis)
    for d in dims:
        if d < len(shape) and shape[d] % n == 0 and shape[d] >= n:
            return d
    return None


def cache_specs(cfg: LMConfig, mesh: Mesh, cache: dict, batch: int) -> dict:
    """KV/SSM cache sharding.

    Batch shards over (pod, data) when divisible; otherwise (long_500k,
    batch=1) the cache *sequence* axis shards over "data"
    (sequence-parallel attention over the cache).
    """
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    ndev = 1
    for a in dp:
        ndev *= mesh.shape[a]
    batch_ok = batch % ndev == 0

    def spec_for(name, x):
        nd = x.ndim
        parts: list = [None] * nd
        if name in ("k", "v", "cross_k", "cross_v"):  # (L|G, B, S, H, hd)
            if batch_ok:
                parts[1] = dpa
            elif x.shape[2] % _axis_size(mesh, dpa) == 0:
                parts[2] = dpa                   # sequence-parallel cache
            # model axis: prefer heads (Megatron TP); when kv heads don't
            # divide (GQA with few kv heads), fall back to
            # sequence-parallel cache (flash-decode style), then head_dim.
            prio = [3] + ([2] if parts[2] is None else []) + [4]
            d = _first_divisible(x.shape, prio, mesh, "model")
            if d is not None:
                parts[d] = "model"
        elif name == "pos":                      # (B, S)
            if batch_ok:
                parts[0] = dpa
            elif x.shape[1] % _axis_size(mesh, dpa) == 0:
                parts[1] = dpa
        elif name == "ssm":                      # (L, B, H, P, N)
            if batch_ok:
                parts[1] = dpa
            d = _first_divisible(x.shape, [2, 3, 4], mesh, "model")
            if d is not None:
                parts[d] = "model"
        elif name == "conv":                     # (L, B, K-1, C)
            if batch_ok:
                parts[1] = dpa
            if x.shape[3] % _axis_size(mesh, "model") == 0:
                parts[3] = "model"
        return sanitize_spec(P(*parts), x.shape, mesh)

    return {k: spec_for(k, v) for k, v in cache.items()}


# ---------------------------------------------------------------------------
# Expert-parallel serving specs (("expert", "data") mesh, launch.serve)
# ---------------------------------------------------------------------------


def expert_param_specs(
    stacked: Any, mesh: Mesh, *, logical_axes: Any = None
) -> Any:
    """PartitionSpec pytree for stacked expert params (leaves ``(K, ...)``).

    Accepts a raw stacked pytree or any ``core.param_store.
    ExpertParamStore`` (stores are registered pytrees): a quantized
    store's per-expert scale arrays are just more ``(K,)`` leaves, so
    they shard over the mesh "expert" axis **together with the int8/fp8
    leaves they rescale** — a static expert slice resolves both from the
    same resident shard.

    The leading expert axis shards over the mesh's "expert" axis so each
    device group holds only ``K / n_expert_shards`` resident experts; all
    trailing (weight) dims replicate — the routed engine's per-step gather
    of the k selected experts' params then lowers to an all-gather over
    the expert axis of just those slices.

    ``logical_axes`` optionally supplies per-leaf axis-name annotations
    (``models.dit.stacked_param_logical_axes`` / ``ExpertParamStore.
    logical_axes``); by default every leaf is assumed to carry the
    stacked layout's leading "expert" axis.  Non-divisible K falls back
    to replication (``sanitize_spec``), which keeps the degenerate
    1-shard mesh bit-identical to unsharded serving.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    if logical_axes is None:
        ax_leaves = [("expert",) + (None,) * (x.ndim - 1) for x in leaves]
    else:
        # annotation leaves are axis-name tuples — themselves pytrees, so
        # flatten with an explicit is_leaf instead of zipping tree_maps.
        ax_leaves = jax.tree.leaves(
            logical_axes, is_leaf=lambda n: isinstance(n, tuple)
        )
        if len(ax_leaves) != len(leaves):
            raise ValueError("logical_axes does not match the stacked pytree")

    def leaf(x, axes):
        spec = P(*[a if a in mesh.axis_names else None for a in axes])
        return sanitize_spec(spec, x.shape, mesh)

    return jax.tree.unflatten(
        treedef, [leaf(x, a) for x, a in zip(leaves, ax_leaves)]
    )


def expert_param_shardings(
    stacked: Any, mesh: Mesh, *, logical_axes: Any = None
) -> Any:
    return to_shardings(
        mesh, expert_param_specs(stacked, mesh, logical_axes=logical_axes)
    )


def dispatch_plan_sharding(mesh: Mesh) -> NamedSharding:
    """Executor-aware placement for ``core.dispatch.DispatchPlan`` arrays.

    Routing metadata (per-sample slot indices/weights, the expert-sorted
    assignment order, per-expert segment offsets) replicates across the
    mesh: every shard needs the full plan to slice its resident experts'
    groups (grouped backend), gather its param slices (gathered backend),
    or build the pair-major per-row expert ids that drive the one-kernel
    ragged GEMM's weight gathers (ragged backend — the per-tile expert
    ids are derived from the plan's sort order, so the plan must be
    whole on every shard), and the arrays are O(B·k) ints — replication
    costs nothing next to the latents.  Constraining them explicitly
    keeps GSPMD from threading a sharded batch axis into the executor's
    per-expert branches, which would force collectives inside every
    bucket branch (grouped) or every weight gather (ragged).
    """
    return NamedSharding(mesh, P())


def serve_batch_spec(mesh: Mesh, shape: tuple[int, ...]) -> P:
    """Request-batch spec on the expert mesh: leading dim over "data".

    Falls back to replication when the batch doesn't divide the data axis
    (jit in_shardings need exact divisibility).  Rank-0/size-0 leaves
    (PRNG keys, the no-text static filler) replicate.
    """
    if not shape or 0 in shape:
        return P(*([None] * len(shape)))
    return sanitize_spec(
        P("data", *([None] * (len(shape) - 1))), shape, mesh
    )


def rolling_state_shardings(
    mesh: Mesh, shape: tuple[int, ...]
) -> tuple[NamedSharding, NamedSharding]:
    """Shardings for a rolling batch's ``(latent, row-state)`` buffers.

    The continuous scheduler (``repro.serving``) carries four
    ``(B_cap, ...)``-leading buffers across ticks: the latent ``x``
    shards like any request batch (leading dim over "data",
    :func:`serve_batch_spec`); the per-row scalar state — ``t_idx``,
    ``slot_idx``, ``slot_w`` — replicates, exactly like the
    ``DispatchPlan`` arrays it feeds: O(B·k) ints/floats that every
    shard needs whole to build its per-step plan, so splitting them
    would buy nothing and cost a collective inside the step.

    Returns ``(latent_sharding, row_state_sharding)``.
    """
    lat = NamedSharding(mesh, serve_batch_spec(mesh, shape))
    return lat, NamedSharding(mesh, P())


def dit_batch_specs(mesh: Mesh, batch: dict) -> dict:
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    return jax.tree.map(
        lambda x: P(dpa, *([None] * (x.ndim - 1))), batch
    )


def to_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
