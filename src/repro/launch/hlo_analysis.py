"""HLO-level analysis: collective bytes + three-term roofline.

cost_analysis() gives FLOPs/bytes of the (per-device, SPMD-partitioned)
module but NOT collective traffic; that is recovered by parsing the
optimized HLO text and summing the result-shape bytes of every collective
op, weighted by its wire cost:

    all-reduce          2·(n−1)/n ≈ 2   (ring: reduce-scatter + all-gather)
    all-gather          (n−1)/n   ≈ 1
    reduce-scatter      (n−1)/n   ≈ 1
    all-to-all          (n−1)/n   ≈ 1
    collective-permute  1

Replica-group sizes are parsed when present; the asymptotic factor is used
otherwise.  This is the §Roofline 'collective_bytes' source.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * nb


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: dict
    count_by_type: dict

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.bytes_by_type.values()))


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum wire bytes of every collective in optimized HLO text."""
    bytes_by: dict[str, float] = {}
    count_by: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_shapes, dtype, dims, op = m.groups()
        if tuple_shapes is not None:
            size = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_shapes)
            )
        else:
            size = _shape_bytes(dtype, dims)
        gm = _GROUP_RE.search(line)
        if gm:
            n = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            n = 0
        if op == "all-reduce":
            factor = 2.0 * (n - 1) / n if n > 1 else 2.0
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            factor = (n - 1) / n if n > 1 else 1.0
        else:  # collective-permute
            factor = 1.0
        bytes_by[op] = bytes_by.get(op, 0.0) + size * factor
        count_by[op] = count_by.get(op, 0) + 1
    return CollectiveStats(bytes_by, count_by)


def compiled_bytes_accessed(compiled) -> float:
    """Total HBM traffic (bytes accessed) of a compiled XLA executable.

    ``compiled`` is the result of ``jax.jit(fn).lower(*args).compile()``.
    XLA's ``cost_analysis`` reports the memory-traffic estimate the
    compiler itself used ("bytes accessed"); returns 0.0 when the backend
    provides no estimate.  Divide by the step count for a per-step
    HBM-bytes figure — the metric the step-fused sampler section of
    ``BENCH_sampler.json`` tracks.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return 0.0
    # Older jax versions return a one-element list of dicts.
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return 0.0
    return float(ca.get("bytes accessed", 0.0))


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    """Three roofline terms, seconds per step per chip (§Roofline)."""

    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    peak_flops: float
    hbm_bw: float
    ici_bw: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def model_flops(cfg, shape, params_total: int, active_params: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D for a train step;
    2·N·D_tokens for inference (forward only)."""
    n = active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
