"""Production mesh construction (TPU v5e target).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism across the ICI-disjoint pods (DCN).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; everything else
sees the real single CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests (same axis names as single-pod)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-sharding axes for this mesh ('pod' folds into data)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def mesh_devices(mesh) -> int:
    return mesh.devices.size


# --- TPU v5e hardware constants (per chip) — roofline denominators ---------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
