"""Production mesh construction (TPU v5e target).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism across the ICI-disjoint pods (DCN).

Serving additionally uses an ("expert", "data") mesh
(``make_expert_mesh``): the stacked expert pytree's leading K axis shards
over "expert" (each device group holds K / n_expert_shards resident
experts) while request batches shard over "data".

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; everything else
sees the real single CPU device).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests (same axis names as single-pod)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_expert_mesh(n_expert_shards: int = 1, n_data_shards: int | None = None):
    """Expert-parallel serving mesh with axes ``("expert", "data")``.

    ``n_expert_shards`` partitions the stacked expert pytree's leading K
    axis (param storage: K / n_expert_shards resident experts per device
    group); ``n_data_shards`` partitions the request batch.  When
    ``n_data_shards`` is None the remaining devices fold into "data" so
    the mesh covers every visible device.  A (1, 1) mesh is the valid
    degenerate single-host case (bit-identical to unsharded serving).

    Unlike ``make_production_mesh`` this tolerates using a *prefix* of the
    visible devices (e.g. 2 expert shards on a 3-device host), so CPU
    hosts forced to N devices via ``--xla_force_host_platform_device_count``
    (the ``launch/dryrun.py`` trick) can stand up any smaller topology.
    """
    if n_expert_shards < 1:
        raise ValueError(f"n_expert_shards must be >= 1, got {n_expert_shards}")
    ndev = jax.device_count()
    if n_data_shards is None:
        n_data_shards = max(1, ndev // n_expert_shards)
    if n_data_shards < 1:
        raise ValueError(f"n_data_shards must be >= 1, got {n_data_shards}")
    need = n_expert_shards * n_data_shards
    if need > ndev:
        raise ValueError(
            f"mesh ({n_expert_shards}, {n_data_shards}) needs {need} "
            f"devices but only {ndev} are visible"
        )
    if need == ndev:
        return jax.make_mesh((n_expert_shards, n_data_shards),
                             ("expert", "data"))
    devices = np.asarray(jax.devices()[:need]).reshape(
        n_expert_shards, n_data_shards
    )
    return jax.sharding.Mesh(devices, ("expert", "data"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-sharding axes for this mesh ('pod' folds into data)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def mesh_devices(mesh) -> int:
    return mesh.devices.size


# --- TPU v5e hardware constants (per chip) — roofline denominators ---------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
