"""Chaos soak harness for the serving resilience layer.

Drives a few hundred ticks of mixed traffic through a
:class:`~repro.serving.resilience.ResilientScheduler` under a **seeded**
fault schedule — every fault class the resilience layer claims to
survive, fired together:

* **expert poisoning** — ``faults.poison_expert_runtime`` NaN-fills a
  resident expert mid-soak (silent bit-rot: no load-time check fires);
  the breaker must attribute the escapes, trip the slot into PROBATION
  without a retrace, and — once the slot is healed — auto-restore it via
  a passing canary probe.
* **dispatch failures** — injected launch crashes on scheduled ticks;
  only the offending bucket may fail, its residents re-queue under the
  requeue cap behind the exponential-backoff window.
* **slow launches** — on scheduled ticks the compiled call burns more
  fake wall clock than ``tick_budget_s``; the *real* watchdog path must
  trip and isolate the bucket.
* **deadline pressure** — a slice of the traffic carries ``max_steps``
  or ``deadline_s`` bounds it cannot meet and must land in
  DEADLINE_EXCEEDED, never hang.
* **kill-and-restore** — a scheduler is abandoned mid-flight and
  rebuilt from its journal; the restored run's outputs must be
  **bitwise identical** to an uninterrupted twin's.

Verdict (printed as one JSON line, consumed by the CI chaos-smoke
step): zero hung requests, terminal states ⊆ {DONE, FAILED,
DEADLINE_EXCEEDED}, requeues bounded by the cap, traces bounded by the
static bucket-shape budget, breaker trip→probe→restore observed, and
journal-restore parity exact.

Everything is deterministic: traffic and fault schedules come from one
``numpy`` Generator seeded by ``--seed``, time comes from a fake
monotonic clock, and request keys are folds of one base PRNGKey.

Run standalone::

  PYTHONPATH=src python -m repro.launch.chaos --ticks 220 --out /tmp/chaos
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SamplerConfig
from repro.launch.faults import heal_expert_runtime, poison_expert_runtime
from repro.launch.serve import ServingEngine
from repro.launch.sharded_parity import toy_ensemble
from repro.serving import (
    QueueBackpressure,
    ResiliencePolicy,
    ResilientScheduler,
)

#: grid size of the soak sampler — long enough that requests overlap
#: faults mid-flight, short enough that 200+ ticks stay a smoke test.
NUM_STEPS = 6
TEXT_TAILS = (None, (5, 6))
#: conditioning shape introduced only after the poison tick, so its
#: bucket snapshots the poisoned store (pre-existing buckets pin their
#: admission-epoch snapshot and would mask the fault).
POISON_TAIL = (7, 6)


class FakeClock:
    """Deterministic monotonic clock: a fixed increment per read, plus
    explicit ``advance`` for injected stalls."""

    def __init__(self, dt: float = 1e-3) -> None:
        self.t = 0.0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class ChaosScheduler(ResilientScheduler):
    """ResilientScheduler + a seeded launch-fault injector.

    Faults inject at the compiled-launch seam (the function the tick
    actually calls), so the watchdog/failure handling under test is the
    real production path, not a shortcut around it.
    """

    def __init__(self, engine, *, fail_ticks=(), slow_ticks=(),
                 **kwargs) -> None:
        super().__init__(engine, **kwargs)
        self.fail_ticks = set(fail_ticks)
        self.slow_ticks = set(slow_ticks)

    def _get_rolling_compiled(self, has_text, text_tail):
        fn = super()._get_rolling_compiled(has_text, text_tail)
        if self.step_count in self.fail_ticks:
            def crashing(*a):
                raise RuntimeError("chaos: injected dispatch failure")
            return crashing
        if self.step_count in self.slow_ticks \
                and self.policy.tick_budget_s is not None:
            def stalled(*a):
                # the launch itself burns the budget — the parent
                # watchdog times it on its own clock reads
                self.clock.advance(2.0 * self.policy.tick_budget_s)
                return fn(*a)
            return stalled
        return fn


def build_engine(k: int = 8, capacity: int = 8,
                 max_request_requeues: int = 2) -> ServingEngine:
    """Fresh elastic toy engine; deterministic (same params each call),
    which is what makes the kill-and-restore twin comparison exact."""
    experts, params, router_fn, latent = toy_ensemble(k)
    sampler = SamplerConfig(num_steps=NUM_STEPS, cfg_scale=3.0,
                            strategy="topk", top_k=2)
    return ServingEngine(
        experts=experts, expert_params=params, router_fn=router_fn,
        latent_shape=latent, sampler=sampler, capacity=capacity,
        max_request_requeues=max_request_requeues,
    )


def _text(key, batch: int, tail: tuple[int, ...]):
    return jax.random.normal(key, (batch,) + tail, jnp.float32)


# --------------------------------------------------------------------------
# Phase A: the soak
# --------------------------------------------------------------------------


def run_soak(ticks: int, seed: int, journal_dir: str) -> dict:
    rng = np.random.default_rng(seed)
    eng = build_engine()
    policy = ResiliencePolicy(tick_budget_s=0.25, probe_base_ticks=2,
                              seed=seed)
    poison_tick = ticks * 3 // 10
    heal_tick = ticks * 6 // 10
    fail_ticks = sorted(rng.choice(  # lint: allow-host-sync — numpy rng
        np.arange(5, ticks - 10), size=max(3, ticks // 40),
        replace=False,
    ).tolist())
    slow_ticks = sorted(rng.choice(  # lint: allow-host-sync — numpy rng
        np.arange(5, ticks - 10), size=max(2, ticks // 60),
        replace=False,
    ).tolist())
    sched = ChaosScheduler(
        eng, policy=policy, journal_dir=journal_dir,
        max_resident=4, clock=FakeClock(),
        fail_ticks=fail_ticks, slow_ticks=slow_ticks,
    )

    base_key = jax.random.PRNGKey(seed)
    handles = []
    shed = 0
    clean_params = None
    # the toy router's logits grow with slot index, so the top slot is
    # routed by essentially every sample — poisoning it guarantees the
    # NaN escape actually reaches resolved latents
    poison_slot = 7

    for tick in range(ticks):
        if tick == poison_tick:
            clean_params = poison_expert_runtime(eng, poison_slot)
        if tick == heal_tick and clean_params is not None:
            heal_expert_runtime(eng, poison_slot, clean_params)
        # mixed traffic: ~0-2 submits per tick, varied shape + bounds
        for _ in range(int(rng.integers(0, 3))):
            n = len(handles)
            key = jax.random.fold_in(base_key, n)
            batch = int(rng.integers(1, 3))
            if tick >= poison_tick and rng.random() < 0.4:
                tail = POISON_TAIL
            else:
                tail = TEXT_TAILS[int(rng.integers(0, len(TEXT_TAILS)))]
            text = None if tail is None else _text(key, batch, tail)
            kw: dict = {}
            r = rng.random()
            if r < 0.15:
                kw["max_steps"] = int(rng.integers(2, 5))  # can't finish
            elif r < 0.25:
                kw["deadline_s"] = 0.02                    # ~2 ticks wall
            elif r < 0.35:
                kw["max_steps"] = 10 * NUM_STEPS           # generous
            try:
                handles.append(sched.submit(key, text, batch, **kw))
            except QueueBackpressure:
                shed += 1
        sched.step()

    # drain — bounded, so a hung request fails loudly instead of looping
    sched.run_until_idle(max_steps=ticks + 600)
    # let outstanding probations resolve (the healed slot must come back)
    extra = 0
    while sched.breaker.probation and extra < 300:
        sched.step()
        extra += 1

    terminal = {"DONE", "FAILED", "DEADLINE_EXCEEDED"}
    states = {h.state for h in handles}
    assert states <= terminal, f"hung/unknown request states: {states}"
    for h in handles:
        assert h.requeues <= eng.max_request_requeues + 1, \
            f"seq={h.seq} requeued {h.requeues}x past the cap"
        if h.state == "DONE":
            assert np.isfinite(np.asarray(h.result())).all(), \
                f"seq={h.seq} resolved non-finite latents"
    s = eng.stats
    assert s["breaker_trips"] >= 1, "poisoning never tripped the breaker"
    assert s["breaker_restores"] >= 1, "no slot ever restored from probation"
    assert s["deadline_exceeded"] >= 1, "deadline pressure never expired"
    assert s["watchdog_trips"] >= 1, "slow launches never tripped watchdog"
    assert eng.expert_health[poison_slot] == "ACTIVE", \
        f"healed slot stuck {eng.expert_health[poison_slot]}"
    # trace budget: one rolling trace per conditioning shape + the
    # batch-1 canary sampler; membership churn must never retrace.
    trace_budget = len(TEXT_TAILS) + 1 + 1
    assert s["traces"] <= trace_budget, \
        f"{s['traces']} traces > budget {trace_budget}: membership or " \
        f"fault handling is retracing"

    done = sum(h.state == "DONE" for h in handles)
    return {
        "ticks": sched.step_count,
        "submitted": len(handles),
        "shed": shed,
        "done": done,
        "failed": sum(h.state == "FAILED" for h in handles),
        "deadline_exceeded": sum(
            h.state == "DEADLINE_EXCEEDED" for h in handles
        ),
        "breaker_trips": s["breaker_trips"],
        "breaker_probes": s["breaker_probes"],
        "breaker_restores": s["breaker_restores"],
        "watchdog_trips": s["watchdog_trips"],
        "request_requeues": s["request_requeues"],
        "journal_snapshots": s["journal_snapshots"],
        "traces": s["traces"],
        "membership": eng.membership_line(),
    }


# --------------------------------------------------------------------------
# Phase B: kill-and-restore bitwise parity
# --------------------------------------------------------------------------


def run_kill_restore(seed: int, journal_dir: str,
                     kill_at: int = 3) -> dict:
    """Crash a journaled scheduler mid-flight; the restored run must be
    bitwise identical to an uninterrupted twin."""
    base_key = jax.random.PRNGKey(1000 + seed)
    policy = ResiliencePolicy(snapshot_every=1, seed=seed)

    def submit_traffic(sched):
        out = []
        out.append(sched.submit(jax.random.fold_in(base_key, 0), None, 1))
        k1 = jax.random.fold_in(base_key, 1)
        out.append(sched.submit(k1, _text(k1, 2, (5, 6)), 2))
        out.append(sched.submit(jax.random.fold_in(base_key, 2), None, 1,
                                max_steps=10 * NUM_STEPS))
        return out

    # the run that dies: journaled, killed (abandoned) after `kill_at`
    # ticks with every request mid-flight
    d_dead = os.path.join(journal_dir, "dead")
    eng1 = build_engine()
    sched1 = ResilientScheduler(eng1, policy=policy, journal_dir=d_dead,
                                max_resident=4, clock=FakeClock())
    submit_traffic(sched1)
    for _ in range(kill_at):
        sched1.step()
    assert sched1.num_resident > 0, "kill point must be mid-flight"
    del sched1  # crash: no drain, no close

    # the twin that never dies
    eng2 = build_engine()
    sched2 = ResilientScheduler(eng2, policy=policy, journal_dir=None,
                                max_resident=4, clock=FakeClock())
    twin = submit_traffic(sched2)
    sched2.run_until_idle()
    twin_out = {h.seq: np.asarray(h.result()) for h in twin}

    # restore onto a fresh engine from the dead run's journal
    eng3 = build_engine()
    sched3 = ResilientScheduler.restore(eng3, d_dead, policy=policy,
                                        clock=FakeClock())
    assert sched3.step_count == kill_at
    restored = {r.seq: r for b in sched3._buckets.values()
                for r in b.resident_requests()}
    restored.update({r.seq: r for r in sched3._queue})
    assert set(restored) == set(twin_out), \
        f"restore lost requests: {sorted(restored)} != {sorted(twin_out)}"
    sched3.run_until_idle()

    mismatched = [
        seq for seq, h in restored.items()
        if not np.array_equal(np.asarray(h.result()), twin_out[seq])
    ]
    assert not mismatched, \
        f"restored outputs diverge from uninterrupted twin: {mismatched}"
    return {
        "kill_at": kill_at,
        "requests": len(restored),
        "bitwise_identical": True,
    }


# --------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--ticks", type=int, default=220,
                    help="soak length in scheduler ticks (>= 200 in CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="journal/artifact dir (default: a temp dir)")
    args = ap.parse_args(argv)

    out_dir = args.out or tempfile.mkdtemp(prefix="repro_chaos_")
    os.makedirs(out_dir, exist_ok=True)
    verdict = {"seed": args.seed, "out": out_dir}
    verdict["soak"] = run_soak(
        args.ticks, args.seed, os.path.join(out_dir, "soak")
    )
    verdict["kill_restore"] = run_kill_restore(
        args.seed, os.path.join(out_dir, "restore")
    )
    with open(os.path.join(out_dir, "chaos_verdict.json"), "w") as f:
        json.dump(verdict, f, indent=2)
    print(json.dumps(verdict))


if __name__ == "__main__":
    main()
