"""Multi-device parity check for the sharded serving engine.

Runs in a subprocess with a forced multi-device CPU host (the in-process
test suite must keep the single real CPU device — see tests/conftest.py)
and asserts, for the same seed:

  1. degenerate 1×1 mesh  == unsharded engine   (bit-identical)
  2. expert-sharded mesh  (N, 1)  == unsharded  (numerical, atol 1e-5)
  3. data-sharded mesh    (1, N)  == unsharded  (numerical, atol 1e-5)
  4. grouped dispatch (sort-based segment execution, core.dispatch) on
     the expert-sharded AND data-sharded meshes == unsharded gathered
     (atol 1e-5) — each shard executes its resident experts' groups
  5. cross-request batching on the sharded engine: coalesced
     submit()/flush() slices == per-request generate() outputs
  6. quantized expert store (core.param_store, param_dtype='int8') on
     the expert-sharded mesh: every per-expert scale array shards over
     the "expert" axis together with the int8 leaf it rescales (each
     shard holds K/ndev scale entries), and sampling matches the dense
     unsharded engine (atol 1e-4 — the toy leaves quantize exactly)
  7. step-fused sampling + plan reuse (SamplerConfig.step_fused /
     plan_refresh_every, kernels.ops.fused_step): the step-fused R=1
     engine is bit-identical to the unfused baseline on expert- AND
     data-sharded meshes, and a plan-reused (R=2) sharded engine matches
     the plan-reused unsharded engine (atol 1e-5 — same config across
     mesh layouts; R>1 is not expected to match per-step routing)
  8. masked elastic membership (ServingEngine capacity=...) on the
     expert-sharded AND data-sharded meshes: the capacity-padded
     store's validity mask shards over the "expert" axis with its
     store, padded slots contribute nothing (full-capacity output ==
     the dense K-expert baseline), and evicting a live expert on each
     sharded engine matches the same eviction on the unsharded elastic
     engine
  9. ragged one-kernel dispatch (core.dispatch 'ragged' +
     kernels.ragged_gemm) on the expert-sharded AND data-sharded
     meshes: a small DiT ensemble publishing a shared ragged_apply_fn
     matches its dispatch='grouped' unsharded baseline (atol 1e-5),
     and hot evict + hot add on an elastic ragged engine stay
     retrace-free (engine ``stats["traces"]`` does not move across
     membership changes)

``--dit`` swaps the toy closed-form experts for real (reduced) DiT
experts — slower, exercised by the slow-marked test variant.

Usage (standalone):
  PYTHONPATH=src REPRO_PARITY_DEVICES=2 python -m repro.launch.sharded_parity
"""

import os
import sys

# MUST precede any jax import: jax locks the device count at first init.
# (Same trick as launch/dryrun.py.)  Guarded on jax being absent so the
# test suite can import the toy-ensemble helpers below without mutating
# XLA_FLAGS in a process whose device count is already locked.
if "jax" not in sys.modules:
    _N_DEV = int(os.environ.get("REPRO_PARITY_DEVICES", "2"))
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + f" --xla_force_host_platform_device_count={_N_DEV}"
        ).strip()

import argparse
import dataclasses
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExpertSpec, SamplerConfig
from repro.launch.serve import ServingEngine
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2
from repro.training import expert_metadata, save_checkpoint

KEY = jax.random.PRNGKey(0)


def toy_apply(params, x, t, *, text_emb=None, drop_mask=None, **_):
    null = jnp.float32(0.07)
    if text_emb is None:
        cond_term = null
    else:
        ct = text_emb.mean(axis=(1, 2))[:, None, None, None]
        if drop_mask is not None:
            ct = jnp.where(drop_mask[:, None, None, None], null, ct)
        cond_term = ct
    return x * params["a"] + params["b"] + cond_term


def toy_ensemble(k=4):
    """Closed-form stackable ensemble shared with tests/test_sharded_serving."""
    params = [
        {"a": jnp.float32(0.7 + 0.06 * i), "b": jnp.float32(0.01 * i)}
        for i in range(k)
    ]
    experts = [
        ExpertSpec(
            f"e{i}", "ddpm" if i % 2 == 0 else "fm",
            "cosine" if i % 2 == 0 else "linear", toy_apply, i,
        )
        for i in range(k)
    ]

    def router_fn(x, t):
        logits = (
            jnp.tile(jnp.arange(float(k))[None], (x.shape[0], 1))
            + x.mean(axis=(1, 2, 3))[:, None]
        )
        return jax.nn.softmax(logits, axis=-1)

    return experts, params, router_fn, (4, 4, 2)


def _dit_ensemble(k=4):
    cfg = dit_b2().reduced(latent_size=8)
    apply_fn = D.make_expert_apply(cfg)
    experts, params = [], []
    for i in range(k):
        obj = "ddpm" if i % 2 == 0 else "fm"
        experts.append(ExpertSpec(
            f"e{i}", obj, "cosine" if obj == "ddpm" else "linear",
            apply_fn, i,
        ))
        params.append(D.init(cfg, jax.random.PRNGKey(10 + i)))
    rcfg = router_b2(num_clusters=k).reduced(latent_size=8)
    router_fn = D.make_router_fn(rcfg, D.init(rcfg, jax.random.PRNGKey(99)))
    latent = (cfg.latent_size, cfg.latent_size, cfg.latent_channels)
    return experts, params, router_fn, latent, cfg


def _engine(experts, params, router_fn, latent, sampler, **shards):
    return ServingEngine(
        experts=experts, expert_params=params, router_fn=router_fn,
        latent_shape=latent, sampler=sampler, **shards,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dit", action="store_true",
                    help="use real reduced-DiT experts instead of the toy "
                         "closed-form ensemble")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()

    ndev = jax.device_count()
    assert ndev >= 2, f"need a forced multi-device host, got {ndev}"

    if args.dit:
        experts, params, router_fn, latent, cfg = _dit_ensemble()
        text = jax.random.normal(
            KEY, (args.batch, cfg.text_len, cfg.text_dim)
        )
    else:
        experts, params, router_fn, latent = toy_ensemble()
        text = jax.random.normal(KEY, (args.batch, 5, 6))
    sampler = SamplerConfig(num_steps=args.steps, cfg_scale=3.0,
                            strategy="topk", top_k=2)

    base = _engine(experts, params, router_fn, latent, sampler)
    ref = np.asarray(base.generate(KEY, text, args.batch))
    assert np.isfinite(ref).all()

    # 1. degenerate 1×1 mesh: the single-host path is the 1-shard case.
    degen = _engine(experts, params, router_fn, latent, sampler,
                    n_expert_shards=1, n_data_shards=1)
    out = np.asarray(degen.generate(KEY, text, args.batch))
    assert np.array_equal(out, ref), "1x1 mesh must be bit-identical"

    # 2. expert-parallel placement: K/ndev resident experts per device.
    esh = _engine(experts, params, router_fn, latent, sampler,
                  n_expert_shards=ndev, n_data_shards=1)
    out = np.asarray(esh.generate(KEY, text, args.batch))
    np.testing.assert_allclose(out, ref, atol=1e-5)

    # 3. data-parallel batch sharding.
    dsh = _engine(experts, params, router_fn, latent, sampler,
                  n_expert_shards=1, n_data_shards=ndev)
    out = np.asarray(dsh.generate(KEY, text, args.batch))
    np.testing.assert_allclose(out, ref, atol=1e-5)

    # 4. grouped dispatch (sort-based segment execution) on both mesh
    #    layouts: the GroupedExecutor must match the gathered baseline
    #    while resolving each expert's params from its resident shard.
    #    (toy ensemble only: the grouped trace compiles one bucket branch
    #    per power-of-two segment size per expert, which on real DiT
    #    experts would dominate the slow-variant's subprocess budget).
    grouped_checked = not args.dit
    if grouped_checked:
        gsampler = dataclasses.replace(sampler, dispatch="grouped")
        for shards in ((ndev, 1), (1, ndev)):
            gsh = _engine(experts, params, router_fn, latent, gsampler,
                          n_expert_shards=shards[0], n_data_shards=shards[1])
            out = np.asarray(gsh.generate(KEY, text, args.batch))
            np.testing.assert_allclose(out, ref, atol=1e-5)

    # 5. cross-request batching on the expert-sharded engine: coalesced
    #    slices must match what each request would get from generate().
    k1, k2 = jax.random.PRNGKey(7), jax.random.PRNGKey(8)
    h1 = esh.submit(k1, text[:1], 1)
    h2 = esh.submit(k2, text[1:], args.batch - 1)
    dispatches = esh.flush()
    assert dispatches == 1, f"expected 1 merged dispatch, got {dispatches}"
    r1 = np.asarray(base.generate(k1, text[:1], 1))
    r2 = np.asarray(base.generate(k2, text[1:], args.batch - 1))
    np.testing.assert_allclose(np.asarray(h1.result()), r1, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2.result()), r2, atol=1e-5)

    # 6. quantized expert store (core.param_store) on the expert mesh:
    #    every per-expert scale array shards over the "expert" axis
    #    together with the int8 leaf it rescales, and the quantized
    #    engine matches the dense unsharded baseline.
    quantized_checked = not args.dit
    if quantized_checked:
        qsampler = dataclasses.replace(sampler, param_dtype="int8")
        qsh = _engine(experts, params, router_fn, latent, qsampler,
                      n_expert_shards=ndev, n_data_shards=1)
        assert qsh.expert_params is None, \
            "quantized engine must drop the full-precision per-expert list"
        store = qsh.param_store
        k_experts = store.num_experts
        for q, s in zip(jax.tree.leaves(store.qvals),
                        jax.tree.leaves(store.scales)):
            assert q.sharding.spec[0] == "expert", q.sharding
            assert s.sharding.spec[0] == "expert", (
                f"scale array must shard with its leaf on the expert "
                f"axis, got {s.sharding}"
            )
            local = s.addressable_shards[0].data.shape[0]
            assert local == k_experts // ndev, (
                f"each shard must hold K/ndev={k_experts // ndev} scale "
                f"entries, got {local}"
            )
        out = np.asarray(qsh.generate(KEY, text, args.batch))
        np.testing.assert_allclose(out, ref, atol=1e-4)

    # 7. step fusion + plan reuse across mesh layouts.  The unsharded
    #    baseline `ref` above already runs the step-fused default
    #    (SamplerConfig.step_fused=True), so: (a) an explicitly UNFUSED
    #    sharded engine must still match it bit-for-bit at R=1 (the
    #    fused kernel is exact, sharded or not); (b) a plan-reused (R=2)
    #    sharded engine must match the plan-reused unsharded engine —
    #    the carried DispatchPlan replicates across the mesh and must
    #    not diverge from the single-device carry.
    step_fusion_checked = not args.dit
    if step_fusion_checked:
        unfused = dataclasses.replace(sampler, step_fused=False)
        for shards in ((ndev, 1), (1, ndev)):
            ufsh = _engine(experts, params, router_fn, latent, unfused,
                           n_expert_shards=shards[0],
                           n_data_shards=shards[1])
            out = np.asarray(ufsh.generate(KEY, text, args.batch))
            np.testing.assert_allclose(out, ref, atol=1e-5)

        reuse = dataclasses.replace(sampler, plan_refresh_every=2)
        ref_reuse = np.asarray(
            _engine(experts, params, router_fn, latent, reuse)
            .generate(KEY, text, args.batch)
        )
        assert np.isfinite(ref_reuse).all()
        for shards in ((ndev, 1), (1, ndev)):
            rsh = _engine(experts, params, router_fn, latent, reuse,
                          n_expert_shards=shards[0],
                          n_data_shards=shards[1])
            out = np.asarray(rsh.generate(KEY, text, args.batch))
            np.testing.assert_allclose(out, ref_reuse, atol=1e-5)

    # 8. masked elastic membership on the expert-sharded mesh.  The
    #    capacity-padded store carries a (K_cap,) validity mask that
    #    shards over "expert" with the params it masks; padded slots
    #    must contribute nothing (full-capacity == dense baseline), and
    #    a mid-life eviction must behave identically sharded/unsharded.
    elastic_checked = not args.dit
    if elastic_checked:
        cap = len(experts) + ndev
        el_ref = _engine(experts, params, router_fn, latent, sampler,
                         capacity=cap)
        el_ref.evict_expert(3)
        masked_ref = np.asarray(el_ref.generate(KEY, text, args.batch))
        assert not np.array_equal(masked_ref, ref), \
            "evicting a routed expert must change the output"
        for shards in ((ndev, 1), (1, ndev)):
            el_sh = _engine(experts, params, router_fn, latent, sampler,
                            n_expert_shards=shards[0],
                            n_data_shards=shards[1], capacity=cap)
            if shards[0] == ndev:
                vmask = el_sh.param_store.valid
                assert vmask.sharding.spec[0] == "expert", (
                    f"validity mask must shard over the expert axis "
                    f"with its store, got {vmask.sharding}"
                )
            out = np.asarray(el_sh.generate(KEY, text, args.batch))
            np.testing.assert_allclose(out, ref, atol=1e-5)
            el_sh.evict_expert(3)
            out = np.asarray(el_sh.generate(KEY, text, args.batch))
            np.testing.assert_allclose(out, masked_ref, atol=1e-5)

    # 9. ragged one-kernel dispatch across mesh layouts.  The ragged
    #    backend needs the pair-major DiT forward (models.dit.
    #    make_ragged_expert_apply), so this step always builds its own
    #    small reduced-DiT ensemble (independent of --dit) whose
    #    ExpertSpecs publish one shared ragged_apply_fn.  The ragged
    #    engine must match the unsharded dispatch='grouped' baseline on
    #    the expert- AND data-sharded meshes, and elastic membership
    #    changes (hot evict, hot add) under ragged dispatch must reuse
    #    the compiled step — stats["traces"] must not move.
    ragged_checked = True
    r_cfg = dit_b2().reduced(d_model=64, num_heads=2, text_dim=16,
                             text_len=4, latent_size=8)
    r_apply = D.make_expert_apply(r_cfg)
    r_ragged = D.make_ragged_expert_apply(r_cfg)
    r_k = 4
    r_experts = [
        ExpertSpec(
            f"r{i}", "ddpm" if i % 2 == 0 else "fm",
            "cosine" if i % 2 == 0 else "linear", r_apply, i,
            ragged_apply_fn=r_ragged,
        )
        for i in range(r_k)
    ]
    # Fresh-init DiT predicts exact zeros (§2.5 zero-init output layers),
    # which would make every expert's params inert and the evict/add
    # assertions below vacuous — jitter every leaf so predictions depend
    # on the slot params (same trick as benchmarks/bench_sampler.py).
    def _jitter(tree, key):
        leaves, treedef = jax.tree.flatten(tree)
        keys = jax.random.split(key, len(leaves))
        return treedef.unflatten([
            leaf + 0.02 * jax.random.normal(k, leaf.shape, leaf.dtype)
            for leaf, k in zip(leaves, keys)
        ])

    r_params = [_jitter(D.init(r_cfg, jax.random.PRNGKey(40 + i)),
                        jax.random.PRNGKey(50 + i))
                for i in range(r_k)]

    def r_router(x, t):
        logits = (
            jnp.tile(jnp.arange(float(r_k))[None], (x.shape[0], 1))
            + x.mean(axis=(1, 2, 3))[:, None]
        )
        return jax.nn.softmax(logits, axis=-1)

    r_latent = (r_cfg.latent_size, r_cfg.latent_size,
                r_cfg.latent_channels)
    r_text = jax.random.normal(
        KEY, (args.batch, r_cfg.text_len, r_cfg.text_dim)
    )
    r_sampler = dataclasses.replace(sampler, dispatch="ragged")
    r_ref = np.asarray(
        _engine(r_experts, r_params, r_router, r_latent,
                dataclasses.replace(sampler, dispatch="grouped"))
        .generate(KEY, r_text, args.batch)
    )
    assert np.isfinite(r_ref).all()
    for shards in ((ndev, 1), (1, ndev)):
        rgsh = _engine(r_experts, r_params, r_router, r_latent,
                       r_sampler, n_expert_shards=shards[0],
                       n_data_shards=shards[1])
        out = np.asarray(rgsh.generate(KEY, r_text, args.batch))
        np.testing.assert_allclose(out, r_ref, atol=1e-5)

    # Retrace-free elastic membership under ragged dispatch: evicting
    # a routed expert and hot-adding a replacement both flow through
    # the validity mask / stacked store — shapes never change, so the
    # compiled ragged step must be reused as-is.
    r_el = _engine(r_experts, r_params, r_router, r_latent, r_sampler,
                   n_expert_shards=ndev, n_data_shards=1,
                   capacity=r_k + ndev)
    full = np.asarray(r_el.generate(KEY, r_text, args.batch))
    np.testing.assert_allclose(full, r_ref, atol=1e-5)
    traces0 = r_el.stats["traces"]
    r_el.evict_expert(2)
    evicted = np.asarray(r_el.generate(KEY, r_text, args.batch))
    assert not np.array_equal(evicted, full), \
        "evicting a routed expert must change the ragged output"
    assert np.isfinite(evicted).all()
    ck = os.path.join(tempfile.mkdtemp(prefix="ragged_parity_"),
                      "r_new.npz")
    save_checkpoint(
        ck, _jitter(D.init(r_cfg, jax.random.PRNGKey(77)),
                    jax.random.PRNGKey(78)),
        metadata=expert_metadata(
            name="r_new", objective="fm", schedule="linear",
            cluster_id=2, arch="dit-reduced",
        ),
    )
    r_el.add_expert(ck, slot=2)
    added = np.asarray(r_el.generate(KEY, r_text, args.batch))
    assert not np.array_equal(added, evicted), \
        "hot-adding into a routed slot must change the ragged output"
    assert np.isfinite(added).all()
    assert r_el.stats["traces"] == traces0, (
        f"membership changes under ragged dispatch must not retrace: "
        f"{traces0} -> {r_el.stats['traces']}"
    )

    print(json.dumps({
        "devices": ndev, "dit": bool(args.dit),
        "batch": args.batch, "steps": args.steps,
        "parity": "ok",
        "grouped_parity": "ok" if grouped_checked else "skipped",
        "quantized_parity": "ok" if quantized_checked else "skipped",
        "step_fusion_parity": "ok" if step_fusion_checked else "skipped",
        "elastic_masked_parity": "ok" if elastic_checked else "skipped",
        "ragged_parity": "ok" if ragged_checked else "skipped",
        "coalesced_requests": esh.stats["batched_requests"],
        "merged_batches": esh.stats["merged_batches"],
    }))


if __name__ == "__main__":
    main()
