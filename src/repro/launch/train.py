"""Training launcher.

Two modes:

* ``--mode expert``: train ONE decentralized diffusion expert (the paper's
  unit of work — one contributor, one GPU/pod slice, zero synchronization
  with other experts).  ``--objective ddpm|fm`` selects the heterogeneous
  objective, ``--cluster`` the data partition.
* ``--mode lm``: train an assigned LM architecture (``--arch``) on the
  synthetic token pipeline — the smoke-scale end-to-end driver.

On the CPU container this runs reduced configs by default
(``--full`` uses the real config — intended for actual TPU slices).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode expert \
      --objective ddpm --cluster 0 --steps 200
  PYTHONPATH=src python -m repro.launch.train --mode lm \
      --arch mamba2-2.7b --steps 20
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_dit_config
from repro.data import SyntheticSpec, fit_clusters, lm_batch
from repro.data.pipeline import ExpertDataStream, RouterDataStream
from repro.models import dit as D
from repro.models import zoo
from repro.training import (
    AdamWConfig,
    ExpertTrainer,
    RouterTrainer,
    adamw_init,
    expert_metadata,
    save_checkpoint,
)
from repro.training.trainer import make_lm_train_step


def train_expert(args) -> None:
    spec = SyntheticSpec(num_categories=args.clusters,
                         latent_size=args.latent_size)
    cm, assign = fit_clusters(
        spec, corpus_size=args.corpus, num_clusters=args.clusters,
        num_fine=min(256, args.corpus // 4),
    )
    cfg = get_dit_config(args.dit)
    if not args.full:
        cfg = cfg.reduced(latent_size=args.latent_size)
    params = D.init(cfg, jax.random.PRNGKey(args.seed))
    schedule = "cosine" if args.objective == "ddpm" else "linear"
    trainer = ExpertTrainer(
        apply_fn=D.make_expert_apply(cfg),
        objective=args.objective,
        schedule_name=schedule,
        opt=AdamWConfig(learning_rate=args.lr,
                        warmup_steps=min(100, args.steps // 10)),
    )
    state = trainer.init_state(params)
    stream = ExpertDataStream(spec, cm, cluster_id=args.cluster,
                              batch_size=args.batch, seed=args.seed)
    t0 = time.time()
    for i in range(args.steps):
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), i)
        state, metrics = trainer.train_step(state, key,
                                            stream.next_batch(i))
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:6d} loss {metrics['loss']:.4f} "
                  f"lr {metrics['lr']:.2e} ({time.time()-t0:.1f}s)")
    if args.out:
        save_checkpoint(
            args.out, state.ema,
            metadata=expert_metadata(
                name=f"expert{args.cluster}", objective=args.objective,
                schedule=schedule, cluster_id=args.cluster,
                arch=cfg.name, step=state.step,
            ),
        )
        print(f"saved EMA checkpoint -> {args.out}")


def train_lm(args) -> None:
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = zoo.init(cfg, jax.random.PRNGKey(args.seed))
    opt = AdamWConfig(learning_rate=args.lr, warmup_steps=5)
    opt_state = adamw_init(params)
    step_fn = make_lm_train_step(cfg, opt)
    for i in range(args.steps):
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed), i)
        batch = lm_batch(key, args.batch, args.seq_len, cfg.vocab_size)
        if cfg.arch_type == "audio":
            from repro.models.frontend_stubs import audio_frame_embeddings
            batch["audio_embeds"] = audio_frame_embeddings(
                cfg, args.batch, seed=i
            )
        if cfg.arch_type == "vlm":
            from repro.models.frontend_stubs import vision_patch_embeddings
            batch["vision_embeds"] = vision_patch_embeddings(
                cfg, args.batch, seed=i
            )
        params, opt_state, loss, metrics = step_fn(params, opt_state, batch)
        print(f"step {i:4d} loss {float(loss):.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("expert", "lm"), default="expert")
    # expert mode
    ap.add_argument("--objective", choices=("ddpm", "fm"), default="fm")
    ap.add_argument("--cluster", type=int, default=0)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--dit", default="dit-b2")
    ap.add_argument("--latent-size", type=int, default=8)
    ap.add_argument("--corpus", type=int, default=1024)
    ap.add_argument("--out", default="")
    # lm mode
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--seq-len", type=int, default=128)
    # shared
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full (TPU-scale) config")
    args = ap.parse_args()
    if args.mode == "expert":
        train_expert(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
