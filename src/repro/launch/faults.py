"""Deterministic fault-injection harness for elastic serving.

The paper's deployment model (decentralized, unreliable contributors)
makes three fault classes routine rather than exceptional:

  1. **Bad artifacts** — checkpoints arrive truncated, scrambled,
     shape-mismatched against the ensemble, or carrying non-finite
     params.  The writers below *manufacture* each class from a good
     checkpoint, byte-deterministically (no RNG), so tests can assert
     the exact quarantine behavior.
  2. **Membership churn mid-traffic** — an expert is evicted or
     hot-added between a request's ``submit()`` and its ``flush()``.
     The engine must serve the in-flight request bit-identically to its
     admission-time membership snapshot.
  3. **Dispatch failures** — one coalesced group blows up at flush
     time.  The failure must stay inside that group: healthy groups
     dispatch, the poisoned group re-queues up to the cap, then fails
     loudly on its own handles.

Run standalone (forced multi-device CPU host, same trick as
``sharded_parity``)::

  PYTHONPATH=src REPRO_FAULT_DEVICES=2 python -m repro.launch.faults

which executes the liveness-under-faults scenario end to end and prints
a one-line JSON verdict (consumed by the CI fault-smoke step).
"""

import os
import sys

# MUST precede any jax import: jax locks the device count at first init.
# Guarded on jax being absent so the test suite can import the fault
# writers without mutating XLA_FLAGS in an already-initialized process.
if "jax" not in sys.modules:
    _N_DEV = int(os.environ.get("REPRO_FAULT_DEVICES", "2"))
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + f" --xla_force_host_platform_device_count={_N_DEV}"
        ).strip()

import json
import tempfile

import numpy as np

__all__ = [
    "truncate_checkpoint",
    "scramble_checkpoint",
    "poison_checkpoint_nonfinite",
    "mismatch_checkpoint_shapes",
    "poison_expert_runtime",
    "heal_expert_runtime",
    "FlushFaultInjector",
    "main",
]


# --- checkpoint corruption writers (byte-deterministic, in place) -----------


def truncate_checkpoint(path: str, frac: float = 0.5) -> str:
    """Cut the artifact off mid-archive, as a dropped transfer would."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with open(path, "rb") as f:
        blob = f.read()
    keep = max(1, int(len(blob) * frac))
    with open(path, "wb") as f:
        f.write(blob[:keep])
    return path


def scramble_checkpoint(path: str) -> str:
    """Replace the artifact with deterministic non-zip bytes."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    size = os.path.getsize(path)
    junk = (b"\xde\xad\xbe\xef" * (size // 4 + 1))[:size]
    with open(path, "wb") as f:
        f.write(junk)
    return path


def _rewrite_npz(path, mutate):
    """Load flat members, apply ``mutate(flat)``, re-save in place."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path, allow_pickle=False) as z:
        flat = {k: np.asarray(z[k]) for k in z.files}
    mutate(flat)
    np.savez(path, **flat)
    return path


def poison_checkpoint_nonfinite(path: str, leaf: int = 0) -> str:
    """Set one element of one float leaf to NaN (bit-rot / diverged
    training); the archive itself stays perfectly well-formed."""

    def mutate(flat):
        keys = [k for k in sorted(flat) if k != "__metadata__"
                and np.issubdtype(flat[k].dtype, np.floating)]
        k = keys[leaf % len(keys)]
        arr = flat[k].copy()
        arr.reshape(-1)[0] = np.nan
        flat[k] = arr

    return _rewrite_npz(path, mutate)


def mismatch_checkpoint_shapes(path: str) -> str:
    """Double one leaf's length — a checkpoint from a *different*
    architecture than the ensemble it claims to join."""

    def mutate(flat):
        k = sorted(k for k in flat if k != "__metadata__")[0]
        flat[k] = np.concatenate(
            [flat[k].reshape(-1), flat[k].reshape(-1)]
        )

    return _rewrite_npz(path, mutate)


# --- runtime store corruption (silent bit-rot on a resident expert) ---------


def poison_expert_runtime(engine, slot: int):
    """NaN-fill one resident expert's float leaves *in the live store*.

    Models silent runtime corruption: the checkpoint passed every
    load-time check, then device memory went bad.  Deliberately bypasses
    ``add_expert`` validation and does NOT bump the membership epoch —
    from the engine's point of view nothing happened, which is exactly
    the fault class the circuit breaker must catch from non-finite
    *outputs*.  Returns the clean host-side params pytree so the fault
    can later be healed with :func:`heal_expert_runtime`.
    """
    import jax

    store = engine.param_store
    clean = jax.tree.map(np.array, store.expert(slot))

    def nanify(p):
        # host-side leaf rewrite (clean is already a host pytree)
        p = np.asarray(p)  # lint: allow-host-sync
        if np.issubdtype(p.dtype, np.floating):
            return np.full_like(p, np.nan)
        return p

    poisoned = jax.tree.map(nanify, clean)
    engine.param_store = engine._put_store(store.set_expert(slot, poisoned))
    return clean


def heal_expert_runtime(engine, slot: int, clean_params) -> None:
    """Write clean params back into slot ``slot`` (inverse of
    :func:`poison_expert_runtime`).  Leaves the validity mask and health
    state untouched — if the breaker put the slot in PROBATION, the next
    passing canary probe is what restores it to service."""
    engine.param_store = engine._put_store(
        engine.param_store.set_expert(slot, clean_params)
    )


# --- flush-failure injection ------------------------------------------------


class FlushFaultInjector:
    """Raise inside ``_dispatch_group`` on chosen call numbers.

    Deterministic: counts dispatch-group invocations (1-based) on the
    wrapped engine and raises ``RuntimeError`` when the count is in
    ``fail_on``; every other call passes through.  Use as a context
    manager::

        with FlushFaultInjector(engine, fail_on={1}):
            engine.flush()          # first group fails, rest dispatch
    """

    def __init__(self, engine, fail_on=(1,), exc_type=RuntimeError):
        self.engine = engine
        self.fail_on = set(fail_on)
        self.exc_type = exc_type
        self.calls = 0
        self._orig = None

    def _wrapped(self, has_text, text_tail, reqs):
        self.calls += 1
        if self.calls in self.fail_on:
            raise self.exc_type(
                f"injected dispatch failure (call {self.calls})"
            )
        return self._orig(has_text, text_tail, reqs)

    def __enter__(self):
        self._orig = self.engine._dispatch_group
        self.engine._dispatch_group = self._wrapped
        return self

    def __exit__(self, *exc):
        self.engine._dispatch_group = self._orig
        self._orig = None
        return False


# --- liveness-under-faults scenario (CI smoke) ------------------------------


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core import SamplerConfig
    from repro.launch.serve import ServingEngine
    from repro.launch.sharded_parity import toy_ensemble
    from repro.models import dit as D
    from repro.models.config import dit_b2, router_b2
    from repro.training import (
        expert_metadata, load_checkpoint, save_checkpoint,
    )

    ndev = jax.device_count()
    assert ndev >= 2, f"need a forced multi-device host, got {ndev}"
    KEY = jax.random.PRNGKey(0)
    verdict = {"devices": ndev}

    # --- A. quarantine at assembly: a directory with corrupt artifacts
    # still serves, holes masked, on the forced expert-sharded mesh.
    cfg = dit_b2().reduced(latent_size=8)
    rcfg = router_b2(num_clusters=4).reduced(latent_size=8)
    with tempfile.TemporaryDirectory() as d:
        for cid in (0, 1, 3):
            save_checkpoint(
                os.path.join(d, f"expert{cid}.npz"),
                D.init(cfg, jax.random.PRNGKey(10 + cid)),
                metadata=expert_metadata(
                    name=f"e{cid}", objective="fm", schedule="linear",
                    cluster_id=cid, arch=cfg.name,
                ),
            )
        # cid 2 truncated (leaves a hole → masked EMPTY slot), plus one
        # pure-garbage artifact that never yields a cluster id at all.
        save_checkpoint(
            os.path.join(d, "expert2.npz"),
            D.init(cfg, jax.random.PRNGKey(12)),
            metadata=expert_metadata(
                name="e2", objective="fm", schedule="linear",
                cluster_id=2, arch=cfg.name,
            ),
        )
        truncate_checkpoint(os.path.join(d, "expert2.npz"), 0.5)
        with open(os.path.join(d, "expert9.npz"), "wb") as f:
            f.write(b"not an archive")
        save_checkpoint(
            os.path.join(d, "router.npz"),
            D.init(rcfg, jax.random.PRNGKey(99)),
        )
        eng = ServingEngine.from_checkpoint_dir(
            d, dit_cfg=cfg, router_cfg=rcfg,
            sampler=SamplerConfig(num_steps=2, cfg_scale=3.0,
                                  strategy="topk", top_k=2),
            on_bad_checkpoint="skip",
            n_expert_shards=ndev, n_data_shards=1,
        )
        assert eng.elastic and eng.num_live_experts == 3
        assert len(eng.quarantine) == 2, eng.quarantine
        assert eng.expert_health[2] == "EMPTY"
        text = jax.random.normal(KEY, (2, cfg.text_len, cfg.text_dim))
        out = np.asarray(eng.generate(KEY, text, 2))
        assert np.isfinite(out).all()
        assert "quarantined=2" in eng.membership_line()
    verdict["assembly_quarantine"] = "ok"

    # --- B. membership churn mid-traffic on the toy elastic engine:
    # hot-add + evict between submit() and flush(); the in-flight
    # request must match its admission-time snapshot bit-for-bit.
    experts, params, router_fn, latent = toy_ensemble(8)
    sampler = SamplerConfig(num_steps=4, cfg_scale=3.0,
                            strategy="topk", top_k=2)
    eng = ServingEngine(
        experts=experts[:6], expert_params=params[:6],
        router_fn=router_fn, latent_shape=latent, sampler=sampler,
        capacity=8, n_expert_shards=ndev, n_data_shards=1,
    )
    # deterministic harness conditioning — same text every run by design
    text = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 6))  # lint: allow-prng-key
    admitted = np.asarray(eng.generate(KEY, text, 2))
    h_old = eng.submit(KEY, text, 2)            # admitted under epoch 0
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "expert6.npz")
        save_checkpoint(ck, params[6], metadata=expert_metadata(
            name="e6", objective=experts[6].objective,
            schedule=experts[6].schedule, cluster_id=6, arch="toy",
        ))
        slot = eng.add_expert(ck)
    assert slot == 6
    eng.evict_expert(2)
    h_new = eng.submit(KEY, text, 2)            # admitted under epoch 2
    assert eng.flush() == 2                     # one dispatch per epoch
    old = np.asarray(h_old.result())
    new = np.asarray(h_new.result())
    assert np.array_equal(old, admitted), \
        "in-flight request must be bit-identical to its admission plan"
    assert not np.array_equal(new, old), \
        "post-churn request must see the new membership"
    assert np.isfinite(new).all()
    assert eng.num_live_experts == 6
    verdict["inflight_snapshot"] = "ok"

    # Graceful retire: masked immediately, DRAINING until the next
    # flush completes, then the slot is reusable.
    h = eng.submit(jax.random.PRNGKey(5), text, 2)
    eng.retire_expert(5)
    assert eng.expert_health[5] == "DRAINING"
    eng.flush()
    assert np.isfinite(np.asarray(h.result())).all()
    assert eng.expert_health[5] == "EVICTED"
    verdict["retire_drain"] = "ok"

    # --- C. bad artifacts at add_expert time: every corruption class is
    # rejected with a named error, quarantined, and leaves the slot dead.
    q0 = eng.stats["quarantined_checkpoints"]
    with tempfile.TemporaryDirectory() as d:
        bad = []
        for i, corrupt in enumerate((
            truncate_checkpoint, scramble_checkpoint,
            poison_checkpoint_nonfinite, mismatch_checkpoint_shapes,
        )):
            p = os.path.join(d, f"bad{i}.npz")
            save_checkpoint(p, params[7], metadata=expert_metadata(
                name=f"bad{i}", objective="fm", schedule="linear",
                cluster_id=7, arch="toy",
            ))
            bad.append(corrupt(p))
        for p in bad:
            try:
                eng.add_expert(p)
            except ValueError:
                pass
            else:
                raise AssertionError(f"{p}: corrupt artifact was admitted")
    assert eng.stats["quarantined_checkpoints"] == q0 + 4
    assert eng.expert_health[2] == "EVICTED"    # slot untouched by failures
    verdict["add_expert_quarantine"] = "ok"

    # --- D. flush-failure isolation: the injected failure takes down
    # only its own group; the healthy group dispatches the same flush.
    h_text = eng.submit(jax.random.PRNGKey(6), text, 2)
    h_uncond = eng.submit(jax.random.PRNGKey(7), None, 2)
    with FlushFaultInjector(eng, fail_on={1}) as inj:
        ok = eng.flush()
    assert ok == 1 and inj.calls == 2, (ok, inj.calls)
    done = [h for h in (h_text, h_uncond) if h.state == "DONE"]
    queued = [h for h in (h_text, h_uncond) if h.state == "QUEUED"]
    assert len(done) == 1 and len(queued) == 1
    assert np.isfinite(np.asarray(done[0].result())).all()
    assert eng.flush() == 1                     # re-queued group recovers
    assert queued[0].state == "DONE"
    # and a *persistent* failure exhausts the cap onto the handle:
    h_poison = eng.submit(jax.random.PRNGKey(8), text, 2)
    with FlushFaultInjector(eng, fail_on={1, 2}):
        eng.flush()
        eng.flush()
    assert h_poison.state == "FAILED"
    try:
        h_poison.result()
    except RuntimeError as e:
        assert "injected dispatch failure" in str(e)
    else:
        raise AssertionError("FAILED handle must raise from result()")
    verdict["flush_isolation"] = "ok"

    verdict["membership"] = eng.membership_line()
    print(json.dumps(verdict))


if __name__ == "__main__":
    main()
