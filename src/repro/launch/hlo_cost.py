"""Static cost model over optimized HLO text (the dry-run 'profiler').

``compiled.cost_analysis()`` counts each while-loop *body* once, which
under-counts scan-over-layers programs by ~L×.  This module re-derives the
three roofline inputs by walking the HLO call graph:

* **FLOPs** — every ``dot`` contributes ``2 · |result| · |contracting|``
  (convolutions likewise, from window size); summed per computation and
  multiplied through ``while`` trip counts (parsed from the loop-condition
  constant — jax scans lower to counted loops).
* **HBM bytes** — fusion boundaries are the memory-traffic model: each
  materializing instruction (fusion, dot, scatter, copy, ...) reads its
  operands and writes its result once.  Elementwise chains inside a fusion
  are free, exactly as on the real TPU.
* **Collective bytes** — result-shape bytes of each collective × wire
  factor (all-reduce 2·(n−1)/n, others (n−1)/n), multiplied through trip
  counts.

This is a *static* model: it assumes no cross-iteration caching and
perfect fusion-internal locality.  Those assumptions are also what the
§Perf napkin math uses, so baseline and optimized variants are compared
under one consistent model.
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"(%?[\w\.\-]+(?:,\s*%?[\w\.\-]+)*)"
)
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops that materialize an HBM round-trip at fusion boundaries
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_list(type_str: str) -> list[tuple[str, list[int]]]:
    return [
        (d, [int(x) for x in dims.split(",") if x.strip()])
        for d, dims in _SHAPE_RE.findall(type_str)
    ]


def _nbytes(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dtype, dims in shapes:
        nb = _DTYPE_BYTES.get(dtype, 0)
        n = 1
        for d in dims:
            n *= d
        total += n * nb
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    text: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict          # name -> list[(dtype, dims)]


def _parse_instr(line: str) -> Instr | None:
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    # result type = leading type expression; opcode follows it.
    om = re.match(r"((?:\([^)]*\))|(?:[\w]+\[[\d,]*\](?:\{[^}]*\})?))\s+"
                  r"([\w\-]+)", rhs)
    if not om:
        return None
    rtype, opcode = om.groups()
    # operand names inside the first (...) after opcode
    pstart = rhs.find(opcode) + len(opcode)
    operands: list[str] = []
    if pstart < len(rhs) and rhs[pstart:].lstrip().startswith("("):
        depth = 0
        buf = []
        for ch in rhs[rhs.find("(", pstart):]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            if ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                buf.append(ch)
        args = "".join(buf)
        operands = re.findall(r"%([\w\.\-]+)", args)
    return Instr(name, rtype, opcode, operands, line)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line.strip()) if line.rstrip().endswith("{") \
            else None
        if h and ("->" in line):
            cur = Computation(h.group(1), [], {})
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins:
            cur.instrs.append(ins)
            cur.shapes[ins.name] = _shape_list(ins.result_type)
    return comps


def _trip_count(cond: Computation) -> int:
    """jax scans lower to `i < C` conditions; take the compare constant."""
    const_vals: dict[str, int] = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.text)
            if m:
                const_vals[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.opcode == "compare":
            for op in ins.operands:
                if op in const_vals and const_vals[op] > 0:
                    return const_vals[op]
    # fall back to any positive constant, else 1
    pos = [v for v in const_vals.values() if v > 0]
    return max(pos) if pos else 1


def _dot_flops(ins: Instr, comp: Computation) -> float:
    result = _shape_list(ins.result_type)
    out_elems = 1
    for _, dims in result:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.text)
    if not m or not ins.operands:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x.strip()]
    lhs = comp.shapes.get(ins.operands[0])
    if not lhs:
        return 2.0 * out_elems
    ldims = lhs[0][1]
    k = 1
    for c in cdims:
        if c < len(ldims):
            k *= ldims[c]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    result = _shape_list(ins.result_type)
    out_elems = 1
    for _, dims in result:
        for d in dims:
            out_elems *= d
    if len(ins.operands) >= 2:
        rhs = comp.shapes.get(ins.operands[1])
        if rhs:
            k = 1
            for d in rhs[0][1]:
                k *= d
            # kernel elems include output-feature dim already in result
            return 2.0 * out_elems * max(
                k // max(result[0][1][-1] if result[0][1] else 1, 1), 1
            )
    return 2.0 * out_elems


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "CostTotals":
        return CostTotals(
            self.flops * k, self.hbm_bytes * k, self.collective_bytes * k,
            {t: v * k for t, v in self.collective_by_type.items()},
        )

    def add(self, o: "CostTotals") -> None:
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        for t, v in o.collective_by_type.items():
            self.collective_by_type[t] = (
                self.collective_by_type.get(t, 0.0) + v
            )


_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _wire_factor(op: str, line: str) -> float:
    n = 0
    gm = _GROUP_RE.search(line)
    if gm:
        n = len([x for x in gm.group(1).split(",") if x.strip()])
    else:
        g2 = _GROUP_V2_RE.search(line)
        if g2:
            n = int(g2.group(2))
    if op == "all-reduce":
        return 2.0 * (n - 1) / n if n > 1 else 2.0
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n if n > 1 else 1.0
    return 1.0


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, CostTotals] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
                if m:
                    entry = m.group(1)
                break
        if entry is None:
            # fall back: computation with most instructions
            entry = max(self.comps, key=lambda c: len(self.comps[c].instrs))
        self.entry = entry

    def totals(self) -> CostTotals:
        return self._visit(self.entry)

    def _visit(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = CostTotals()
        self._memo[name] = total
        if comp is None:
            return total
        for ins in comp.instrs:
            op = ins.opcode
            # --- flops ---
            if op == "dot":
                total.flops += _dot_flops(ins, comp)
            elif op == "convolution":
                total.flops += _conv_flops(ins, comp)
            # --- collectives ---
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                size = _nbytes(_shape_list(ins.result_type))
                f = _wire_factor(base, ins.text)
                total.collective_bytes += size * f
                total.collective_by_type[base] = (
                    total.collective_by_type.get(base, 0.0) + size * f
                )
            # --- hbm traffic at fusion boundaries ---
            if op in ("while", "conditional", "call"):
                pass  # loop carries stay resident; bodies are counted below
            elif op in ("dynamic-slice", "gather"):
                # reads only the slice, not the sliced-from buffer
                total.hbm_bytes += 2 * _nbytes(_shape_list(ins.result_type))
            elif op in ("dynamic-update-slice", "scatter"):
                # touches only the update region (read+write)
                upd = (
                    _nbytes(comp.shapes.get(ins.operands[-1], []))
                    if ins.operands else 0
                )
                total.hbm_bytes += 2 * upd
            elif op not in _SKIP_BYTES and not op.endswith("-done"):
                result_shapes = _shape_list(ins.result_type)
                out_b = _nbytes(result_shapes)
                in_b = 0
                aliased = False
                for o in ins.operands:
                    oshapes = comp.shapes.get(o, [])
                    if (
                        op == "fusion" and not aliased
                        and "dynamic-update-slice" in ins.text
                        and oshapes == result_shapes
                    ):
                        # in-place accumulator (lax.map/scan stacking):
                        # aliased with the result; only the updated slice
                        # moves.  Skip the buffer read AND the buffer write.
                        aliased = True
                        continue
                    in_b += _nbytes(oshapes)
                if aliased:
                    out_b = 0
                total.hbm_bytes += out_b + in_b
            # --- called computations ---
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.text)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.text)
                if bm:
                    trips = (
                        _trip_count(self.comps[cm.group(1)])
                        if cm and cm.group(1) in self.comps else 1
                    )
                    total.add(self._visit(bm.group(1)).scaled(trips))
            elif op in ("call", "fusion", "custom-call"):
                m = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", ins.text)
                if m and op == "call":
                    total.add(self._visit(m.group(1)))
                elif m and op == "fusion":
                    # fusion internals: count dot flops only (bytes are the
                    # fusion boundary, already counted above).
                    sub = self._visit(m.group(1))
                    total.flops += sub.flops
                    total.collective_bytes += sub.collective_bytes
            elif op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.text)
                if m:
                    branches = re.findall(r"%?([\w\.\-]+)", m.group(1))
                    subs = [self._visit(b) for b in branches if b in self.comps]
                    if subs:
                        # worst-case branch
                        best = max(subs, key=lambda s: s.flops + s.hbm_bytes)
                        total.add(best)
        return total
