import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above MUST stay first (before any jax-importing module):
jax locks the device count at first init, and only the dry-run wants 512
placeholder host devices.

For each (arch, shape, mesh):
  * build the step fn (train_step / prefill / serve_step),
  * jit with explicit in/out shardings from launch.sharding,
  * .lower(**ShapeDtypeStruct specs)  — no allocation,
  * .compile()                        — proves the distribution config,
  * record memory_analysis / cost_analysis / collective schedule,
  * derive the §Roofline terms.

Results are written to benchmarks/artifacts/dryrun/*.json and summarized
into EXPERIMENTS.md by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 pairs
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, InputShape
from repro.launch import steps as S
from repro.launch.hlo_analysis import (
    Roofline,
    collective_bytes,
    model_flops,
)
from repro.launch.hlo_cost import HloCostModel
from repro.launch import fsdp
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    data_axes,
    make_production_mesh,
    mesh_devices,
)
from repro.launch.sharding import (
    batch_specs,
    cache_specs,
    param_shardings,
    param_specs,
    to_shardings,
)
from jax.sharding import NamedSharding, PartitionSpec as P

ART_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts",
    "dryrun",
)


def _active_params(cfg, total: int) -> int:
    """Active params per token (MoE uses top-k of E experts)."""
    if not cfg.num_experts:
        return total
    expert = 3 * cfg.d_model * cfg.d_ff * cfg.num_layers
    inactive = expert * (cfg.num_experts - cfg.num_experts_per_tok)
    return total - inactive


def dryrun_one(
    arch: str, shape_name: str, *, multi_pod: bool = False,
    save: bool = True, cfg_override=None, tag: str = "",
) -> dict:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh_devices(mesh)
    t0 = time.time()

    p_shapes = S.param_shapes(cfg)
    fsdp_on = bool(getattr(cfg, "fsdp_params", False))
    p_shard = param_shardings(p_shapes, mesh, fsdp=fsdp_on)
    if fsdp_on:
        fsdp.install(mesh, param_specs(p_shapes, mesh, fsdp=True),
                     data_axes(mesh))
    else:
        fsdp.clear()
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_shapes))
    specs = S.input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            o_shapes = S.opt_shapes(cfg)
            # optimizer state shards exactly like params (mu/nu), step repl.
            ps = param_specs(p_shapes, mesh, fsdp=fsdp_on)
            o_spec = type(o_shapes)(step=P(), mu=ps, nu=ps)
            o_shard = to_shardings(mesh, o_spec)
            b_spec = batch_specs(cfg, mesh, specs["batch"])
            b_shard = to_shardings(mesh, b_spec)
            step = S.make_train_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard,
                               NamedSharding(mesh, P())),
            )
            lowered = jitted.lower(p_shapes, o_shapes, specs["batch"])
        elif shape.kind == "prefill":
            b_spec = batch_specs(cfg, mesh, specs["batch"])
            b_shard = to_shardings(mesh, b_spec)
            step = S.make_prefill_step(cfg)
            jitted = jax.jit(
                step, in_shardings=(p_shard, b_shard),
            )
            lowered = jitted.lower(p_shapes, specs["batch"])
        else:
            rcfg, cache_len = S.cfg_for_shape(cfg, shape)
            c_spec = cache_specs(rcfg, mesh, specs["cache"],
                                 shape.global_batch)
            c_shard = to_shardings(mesh, c_spec)
            tok_spec = batch_specs(cfg, mesh,
                                   {"token": specs["token"],
                                    "pos": specs["pos"]})
            tok_shard = to_shardings(mesh, tok_spec)
            step = S.make_serve_step(cfg, shape)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, tok_shard["token"],
                              tok_shard["pos"]),
            )
            lowered = jitted.lower(
                p_shapes, specs["cache"], specs["token"], specs["pos"]
            )

        compiled = lowered.compile()

    fsdp.clear()
    compile_s = time.time() - t0

    # --- analyses ---
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception:  # CPU backend may not implement it
        mem_info = {}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)          # flat (no trip counts) — raw log
    model = HloCostModel(hlo)
    totals = model.totals()               # trip-count-aware static model

    flops_dev = totals.flops
    bytes_dev = totals.hbm_bytes
    roof = Roofline(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=totals.collective_bytes,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW,
    )
    active = _active_params(cfg, n_params)
    mflops = model_flops(cfg, shape, n_params, active)
    mflops_dev = mflops / ndev
    useful = mflops_dev / flops_dev if flops_dev else 0.0

    # analytic per-device param/opt bytes (sanity vs memory_analysis)
    pbytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(p_shapes)
    )
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": ndev,
        "params_total": n_params,
        "params_active": active,
        "compile_s": round(compile_s, 1),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_info,
        "collectives": {
            "bytes_by_type": totals.collective_by_type,
            "count_by_type": coll.count_by_type,        # static op counts
            "total_wire_bytes": totals.collective_bytes,
            "flat_wire_bytes": coll.total_wire_bytes,   # w/o trip counts
        },
        "roofline": roof.as_dict(),
        "model_flops_total": mflops,
        "model_flops_per_device": mflops_dev,
        "useful_flops_ratio": useful,
        "param_bytes_global": pbytes,
        "param_bytes_per_device_est": pbytes / ndev,
        "tag": tag,
    }
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(
            ART_DIR, f"{arch}_{shape_name}_{result['mesh']}{suffix}.json"
        )
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    pairs: list[tuple[str, str]]
    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in pairs:
        try:
            r = dryrun_one(arch, shape, multi_pod=args.multi_pod)
            roof = r["roofline"]
            print(
                f"OK  {arch:20s} {shape:12s} {r['mesh']:8s} "
                f"compile={r['compile_s']:6.1f}s "
                f"compute={roof['compute_s']:9.3e}s "
                f"memory={roof['memory_s']:9.3e}s "
                f"coll={roof['collective_s']:9.3e}s "
                f"dominant={roof['dominant']:10s} "
                f"useful={r['useful_flops_ratio']:.2f}"
            )
            if r["memory_analysis"]:
                print(f"    memory_analysis: {r['memory_analysis']}")
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} {shape}: {e}")
            if not args.continue_on_error:
                traceback.print_exc()
                raise
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
