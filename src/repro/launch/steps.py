"""Step functions + input specs for launch (train / prefill / decode).

Everything here is shape-only-safe: ``input_specs`` returns
ShapeDtypeStructs (no allocation) and the step builders close over configs
only, so ``jax.jit(...).lower(**specs)`` works for the 512-device dry-run
exactly as it would on real hardware.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models import zoo
from repro.models.config import LMConfig
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

Array = jax.Array


def cfg_for_shape(cfg: LMConfig, shape: InputShape) -> tuple[LMConfig, int]:
    """Resolve the (config variant, cache length) for an input shape.

    decode_32k keeps the full seq_len cache (ring-buffering disabled);
    long_500k uses the sub-quadratic variant: ring-buffer window for
    attention archs (cfg.decode_window / native sliding_window), O(1)
    state for SSM.  See DESIGN.md §Arch-applicability.
    """
    if shape.kind != "decode":
        return cfg, shape.seq_len
    if cfg.arch_type == "ssm":
        return cfg, 0
    window = cfg.decode_window or cfg.sliding_window
    if shape.seq_len > 100_000:
        if not window:
            raise ValueError(
                f"{cfg.name} has no sub-quadratic variant for {shape.name}"
            )
        return dataclasses.replace(cfg, decode_window=window), window
    # 32k decode: full cache, exact attention (window masking still applies
    # for natively-SWA archs through cfg.sliding_window).
    return dataclasses.replace(cfg, decode_window=0), shape.seq_len


def input_specs(cfg: LMConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.arch_type == "audio":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), cfg.activation_dtype
            )
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_prefix_len, cfg.d_model), cfg.activation_dtype
            )
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.arch_type == "audio":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), cfg.activation_dtype
            )
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_prefix_len, cfg.d_model), cfg.activation_dtype
            )
        return {"batch": batch}
    # decode: ONE new token against a seq_len cache.
    rcfg, cache_len = cfg_for_shape(cfg, shape)
    cache_shapes = jax.eval_shape(
        lambda: zoo.make_cache(rcfg, b, max(cache_len, 1))
    )
    return {
        "cache": cache_shapes,
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def param_shapes(cfg: LMConfig) -> Any:
    return jax.eval_shape(lambda k: zoo.init(cfg, k), jax.random.PRNGKey(0))


def opt_shapes(cfg: LMConfig) -> Any:
    p = param_shapes(cfg)
    return jax.eval_shape(adamw_init, p)


def make_train_step(cfg: LMConfig, opt: AdamWConfig | None = None,
                    *, microbatches: int = 1):
    """Full optimizer step.

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split along its leading axis and scanned, so peak activation memory
    scales with the microbatch (§Perf lever for the ≥33B trains) at the
    cost of re-running the per-microbatch collectives sequentially.

    CAVEAT (measured, EXPERIMENTS.md §Perf iteration 4): under GSPMD the
    in-jit reshape of the data-sharded batch axis re-replicates the batch
    (all roofline terms ×4 on deepseek-67b).  Use only with externally
    pre-split microbatches until the sharded-reshape fix lands.
    """
    opt = opt or AdamWConfig()

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: zoo.loss_fn(cfg, p, batch), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )

            def acc(carry, one):
                loss_sum, grads = carry
                (loss, _), g = grad_fn(params, one)
                grads = jax.tree.map(jnp.add, grads, g)
                return (loss_sum + loss, grads), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zero), mb
            )
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
        params, opt_state, om = adamw_update(opt, grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: LMConfig):
    def prefill_step(params, batch):
        return zoo.prefill(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: LMConfig, shape: InputShape):
    rcfg, _ = cfg_for_shape(cfg, shape)

    def serve_step(params, cache, token, pos):
        return zoo.decode_step(rcfg, params, cache, token, pos)

    return serve_step
