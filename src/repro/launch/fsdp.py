"""Explicit FSDP: gather-weights-before-use hook.

Storage sharding for large archs puts weight matrices on
P("data", "model") (see launch.sharding).  Left to implicit GSPMD
propagation, the contraction-dim×batch-dim conflict can make the
partitioner re-replicate *activations* instead of weights (measured 8–12×
memory-traffic blowup on internlm2 train_4k — EXPERIMENTS.md §Perf).  The
FSDP contract is the opposite: all-gather the (small) weight shard right
before use and keep activations sharded.

Models call ``maybe_unshard(block_params, name)`` on each scanned layer
slice; by default it is the identity.  The launch layer installs a policy
built from the parameter PartitionSpecs: a ``with_sharding_constraint``
that strips every data-axis assignment from weight leaves, so XLA
materializes the all-gather of exactly one layer's weights per scan
iteration (the FSDP weights-prefetch pattern).
"""

from __future__ import annotations

import threading

import jax

_state = threading.local()


def maybe_unshard(tree, name: str = "blocks"):
    policies = getattr(_state, "policies", None)
    if not policies or name not in policies:
        return tree
    return policies[name](tree)


def _strip_data(axis, drop: set):
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a not in drop)
        return kept if kept else None
    return None if axis in drop else axis


def make_policy(mesh, specs_tree, data_axes: tuple[str, ...]):
    """Build an unshard policy for one stacked-blocks spec subtree."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    drop = set(data_axes)
    spec_leaves = jax.tree.leaves(
        specs_tree, is_leaf=lambda s: isinstance(s, P)
    )

    def policy(tree):
        leaves, treedef = jax.tree.flatten(tree)
        out = []
        for x, spec in zip(leaves, spec_leaves):
            if not hasattr(x, "ndim"):
                out.append(x)
                continue
            trailing = list(spec)[-x.ndim:] if len(spec) else []
            trailing = [None] * (x.ndim - len(trailing)) + [
                _strip_data(a, drop) for a in trailing
            ]
            if any(a is not None for a in trailing):
                sh = NamedSharding(mesh, P(*trailing))
            else:
                sh = NamedSharding(mesh, P(*([None] * x.ndim)))
            out.append(jax.lax.with_sharding_constraint(x, sh))
        return treedef.unflatten(out)

    return policy


def install(mesh, param_spec_tree: dict, data_axes: tuple[str, ...],
            block_keys: tuple[str, ...] = ("blocks", "enc_blocks",
                                           "dec_blocks", "cross_attn")):
    policies = {}
    for k in block_keys:
        if isinstance(param_spec_tree, dict) and k in param_spec_tree:
            policies[k] = make_policy(mesh, param_spec_tree[k], data_axes)
    _state.policies = policies


def clear() -> None:
    _state.policies = None
