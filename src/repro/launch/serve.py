"""Serving engine for heterogeneous decentralized diffusion.

Loads a directory of self-describing expert checkpoints (each carries its
objective / schedule / cluster metadata — §5 limitation iv) plus a router
checkpoint, and serves batched text-to-image requests with the paper's
Fig. 2 pipeline on the compute-sparse hot path: router posterior → Top-K
expert selection → **routed-expert-only** native predictions (stacked
params + gather dispatch; CFG batched along the batch axis) → one fused
schedule-aware ε→v-and-combine kernel per Euler step.

Serving properties:

* **compute-sparse** — only the routed experts run each step (k forwards
  instead of K; 1 forward with batched CFG instead of 2), matching the
  paper's claim that Top-K routing pays single-model cost at ensemble
  quality.  Heterogeneous-architecture expert sets fall back to the dense
  fused path automatically.
* **pluggable dispatch** — ``SamplerConfig.dispatch`` selects the expert
  executor backend (``core.dispatch``): ``gathered`` (per-sample param
  gather + vmap, the default), ``grouped`` (sort-based grouped execution:
  one segment pass per resident expert instead of ``B·k`` vmapped lanes —
  the DDM/Paris-style serving layout), or ``dense``.  The per-step
  ``DispatchPlan`` replicates across the mesh
  (``launch.sharding.dispatch_plan_sharding``) while grouped segment
  params resolve from *static* expert slices of the stacked pytree, so
  each shard executes its resident experts' groups without a per-sample
  params all-gather.
* **quantized experts** — ``SamplerConfig.param_dtype`` (CLI
  ``--param-dtype``) stores the stacked expert pytree as a typed
  ``core.param_store.ExpertParamStore``: ``int8``/``fp8`` quantize on
  load with per-expert symmetric scales (~4x fewer resident expert-param
  bytes than fp32), the full-precision per-expert list is dropped, and
  routed slices dequantize through the fused ``hetero_fuse_dequant``
  Pallas kernel — stacked leaves never round-trip through HBM at full
  precision.
* **step-fused** — ``SamplerConfig.step_fused`` (default on) folds the
  CFG combine and the Euler update into the convert-and-fuse kernel
  (``kernels.ops.fused_step``): one fused kernel launch per step, the
  latent read once and written once instead of three latent-sized HBM
  round-trips; ``--no-step-fuse`` restores the unfused op chain.
* **plan reuse** — ``SamplerConfig.plan_refresh_every`` / CLI
  ``--plan-refresh R`` recomputes the router posterior + ``DispatchPlan``
  only every R-th Euler step (posteriors change slowly in t), carrying
  the plan through the scan; R=1 is bit-identical to per-step routing
  and ``stats['plan_refreshes']`` counts refresh work.
* **conditioning cache** — a content-hash-keyed LRU
  (``cond_cache_size`` / ``--cond-cache``) dedupes text embeddings
  across ``submit()``/``generate()`` calls, so the intra-prompt-diversity
  workload (one prompt, many seeds) holds one resident buffer per
  distinct prompt; ``stats['cond_cache_hits'/'cond_cache_misses']``
  expose the behavior.
* **retrace-free** — ``ServingEngine`` caches a jitted sampling function
  per (batch size, latent shape, sampler config, conditioning signature)
  with the noise buffer donated, so repeated requests with the same shape
  never recompile; ``engine.stats['traces']`` exposes the compile count.
* **sharded** — ``n_expert_shards`` / ``n_data_shards`` place the engine
  on an expert-parallel mesh (topology below) so a host never needs to
  hold the full ensemble's parameters per device.
* **cross-request batching** — ``submit()`` enqueues requests and
  ``flush()`` coalesces compatible ones (same latent shape and sampler
  config — engine invariants — plus the same conditioning signature) into
  one sharded batch, slicing per-request outputs back out, so concurrent
  small requests share a single compiled sampler dispatch.

Topology
--------
The sharded engine lives on an ``("expert", "data")`` mesh
(``launch.mesh.make_expert_mesh``):

* the stacked expert pytree (leaves ``(K, ...)``,
  ``models.dit.stack_expert_params``) shards its leading K axis over
  "expert" — each device group holds ``K / n_expert_shards`` resident
  experts (DDM/Paris-style placement: experts are *placed across*
  devices, not replicated per host);
* request batches (initial noise, text embeddings, the evolving latent
  state) shard their leading batch dim over "data";
* per-step routed dispatch gathers the k selected experts' params from
  their owning shards — GSPMD lowers the stacked-axis gather to an
  all-gather of just those slices over the "expert" axis — and the fused
  velocity/Euler update runs data-parallel on the batch shards
  (``core.sampling`` re-constrains the latent to the "data" axis every
  step);
* the single-host path is the degenerate 1×1 mesh (or ``mesh=None``) and
  is bit-identical to unsharded serving.

Also exposes ``ServingEngine`` programmatically (used by examples/ and the
benchmark harness).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import hashlib
import os
import re
import time
from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    DenseStore,
    ExpertSpec,
    SamplerConfig,
    make_store,
    params_are_stackable,
    sample_ensemble,
)
from repro.launch.mesh import make_expert_mesh
from repro.launch.sharding import (
    dispatch_plan_sharding,
    expert_param_shardings,
    serve_batch_spec,
)
from repro.models import dit as D
from repro.models.config import DiTConfig, dit_b2, router_b2
from repro.training import load_checkpoint

#: ``expert7.npz`` / ``expert_07.npz`` → checkpoint index 7 (ordering
#: fallback when the metadata carries no ``cluster_id``).
_EXPERT_IDX_RE = re.compile(r"expert[_-]?(\d+)")


@dataclasses.dataclass
class PendingRequest:
    """Handle returned by ``ServingEngine.submit``; resolved by ``flush``."""

    key: jax.Array
    text_emb: jnp.ndarray | None
    batch_size: int
    _result: jnp.ndarray | None = None
    done: bool = False

    def result(self) -> jnp.ndarray:
        if not self.done:
            raise RuntimeError(
                "request not yet flushed — submit() only enqueues; call "
                "ServingEngine.flush() to execute the batched dispatch "
                "before reading result()"
            )
        return self._result


@dataclasses.dataclass
class ServingEngine:
    experts: list[ExpertSpec]
    expert_params: list
    router_fn: object | None
    latent_shape: tuple[int, int, int]
    sampler: SamplerConfig = dataclasses.field(default_factory=SamplerConfig)
    #: 'auto' | 'routed' | 'dense' | 'reference' (see core.sample_ensemble)
    engine: str = "auto"
    #: expert-parallel mesh placement (see module docstring "Topology").
    #: Defaults (1, None) keep the classic unsharded single-device path;
    #: setting either stands up an ("expert", "data") mesh — a forced 1×1
    #: mesh is the degenerate case and stays bit-identical.
    n_expert_shards: int = 1
    n_data_shards: int | None = None
    #: cross-request conditioning cache: max distinct text embeddings /
    #: cond pytrees kept resident, keyed by content hash and evicted LRU.
    #: The paper's intra-prompt-diversity workload re-submits the SAME
    #: prompt embedding across many requests (different seeds), so repeat
    #: ``submit()``/``generate()`` calls reuse one device buffer instead
    #: of re-transferring + re-retaining a copy per request.  Applies to
    #: HOST (numpy) inputs only — device-resident ``jax.Array``
    #: embeddings pass through unhashed (no forced device→host copy).
    #: 0 disables.
    cond_cache_size: int = 64

    def __post_init__(self) -> None:
        self._compiled: dict = {}
        self._queue: list[PendingRequest] = []
        self._cond_cache: OrderedDict[tuple, jnp.ndarray] = OrderedDict()
        self.stats = {"traces": 0, "requests": 0,
                      "merged_batches": 0, "batched_requests": 0,
                      "cond_cache_hits": 0, "cond_cache_misses": 0,
                      "plan_refreshes": 0}
        self.homogeneous = len(self.experts) <= 1 or (
            all(e.apply_fn is self.experts[0].apply_fn for e in self.experts)
            and params_are_stackable(self.expert_params)
        )
        # Typed stacked-expert store (core.param_store): the routed
        # engine's dispatch substrate.  ``sampler.param_dtype`` selects
        # the storage — 'native' keeps checkpoint precision
        # (bit-identical), 'int8'/'fp8' quantize with per-expert scales
        # (~4x fewer resident expert-param bytes vs fp32).
        pd = self.sampler.param_dtype
        quantized = pd in ("int8", "fp8")
        if pd != "native":
            # The store only serves ROUTED execution: a dense/reference
            # engine (heterogeneous set, strategy='full', single expert,
            # engine override) runs from the per-expert params list at
            # native precision — accepting param_dtype there would either
            # lie about resident bytes (cast dtypes: unused store built
            # next to the fp32 list) or construct an engine whose every
            # generate() fails later (quantized dtypes drop that list).
            # Reject at construction, where strategy/engine are known.
            routed_capable = (
                self.homogeneous and len(self.experts) > 1
                and self.sampler.strategy in ("top1", "topk", "threshold")
                and self.engine in ("auto", "routed")
            )
            if not routed_capable:
                raise ValueError(
                    f"param_dtype={pd!r} changes the stacked expert "
                    f"store's storage, which only routed execution uses: "
                    f"it needs a homogeneous ensemble of ≥ 2 experts "
                    f"(shared apply_fn + stackable params), strategy in "
                    f"top1/topk/threshold, and engine auto/routed — got "
                    f"{len(self.experts)} expert(s), homogeneous="
                    f"{self.homogeneous}, strategy="
                    f"{self.sampler.strategy!r}, engine={self.engine!r}"
                )
        self.param_store = (
            make_store(D.stack_expert_params(self.expert_params), dtype=pd)
            if self.homogeneous and self.expert_params else None
        )
        if quantized:
            # The quantized store IS the resident representation: drop
            # the full-precision per-expert list so the ~4x byte saving
            # is real, not an extra copy.  (The dense fallback and the
            # reference engine need that list; they raise clearly.)
            self.expert_params = None
        self.mesh = None
        if self.n_expert_shards != 1 or self.n_data_shards is not None:
            if self.n_expert_shards > 1 and \
                    len(self.experts) % self.n_expert_shards != 0:
                # sanitize_spec would silently fall back to replicating
                # the expert axis — zero memory savings while reporting a
                # sharded mesh; make the misconfiguration loud instead.
                raise ValueError(
                    f"n_expert_shards={self.n_expert_shards} does not "
                    f"divide the {len(self.experts)}-expert ensemble; "
                    f"expert placement would silently replicate"
                )
            self.mesh = make_expert_mesh(self.n_expert_shards,
                                         self.n_data_shards)
            if self.param_store is not None:
                # Stores are registered pytrees: the quantized scales are
                # (K,) leaves annotated with the same leading "expert"
                # axis, so they shard with the leaves they rescale.
                self.param_store = jax.device_put(
                    self.param_store,
                    expert_param_shardings(
                        self.param_store, self.mesh,
                        logical_axes=self.param_store.logical_axes(),
                    ),
                )

    @property
    def stacked_params(self):
        """Back-compat view of the dispatch substrate.

        Dense stores expose their raw stacked pytree (the pre-store
        convention); quantized stores return the store itself — reading
        full-precision stacked leaves out of a quantized engine would
        defeat its resident-byte budget.
        """
        if isinstance(self.param_store, DenseStore):
            return self.param_store.stacked
        return self.param_store

    @classmethod
    def from_checkpoint_dir(
        cls, ckpt_dir: str, *, dit_cfg: DiTConfig,
        router_cfg: DiTConfig | None = None,
        sampler: SamplerConfig | None = None,
        engine: str = "auto",
        param_dtype: str | None = None,
        n_expert_shards: int = 1,
        n_data_shards: int | None = None,
        cond_cache_size: int = 64,
    ) -> "ServingEngine":
        """Assemble an engine from a directory of expert checkpoints.

        Experts are ordered **numerically by cluster id** (from each
        checkpoint's metadata, falling back to the ``expert<N>.npz``
        filename index), never lexicographically — with ≥10 experts
        ``sorted(glob(...))`` would load ``expert10`` before ``expert2``
        and silently scramble the router's positional cluster→expert
        mapping.  Duplicate or non-contiguous cluster ids raise.

        ``param_dtype`` (overrides ``sampler.param_dtype`` when given)
        selects the stacked-store storage: ``'int8'``/``'fp8'`` quantize
        **on load** and drop the full-precision per-expert list, so an
        8-expert ensemble holds ~¼ the resident expert-param bytes of
        the fp32 checkpoints it was assembled from.
        """
        apply_fn = D.make_expert_apply(dit_cfg)
        paths = glob.glob(os.path.join(ckpt_dir, "expert*.npz"))
        if not paths:
            raise FileNotFoundError(f"no expert*.npz under {ckpt_dir}")
        loaded: list[tuple[int, str, object, dict]] = []
        for path in paths:
            p, meta = load_checkpoint(path)
            cid = int(meta.get("cluster_id", -1))
            if cid < 0:
                m = _EXPERT_IDX_RE.search(os.path.basename(path))
                if m is None:
                    raise ValueError(
                        f"{path}: no cluster_id metadata and no numeric "
                        f"index in the filename — cannot place this expert"
                    )
                cid = int(m.group(1))
            loaded.append((cid, path, p, meta))
        seen: dict[int, str] = {}
        for cid, path, _, _ in loaded:
            if cid in seen:
                raise ValueError(
                    f"duplicate cluster_id {cid}: {seen[cid]} and {path}"
                )
            seen[cid] = path
        want = range(len(loaded))
        if set(seen) != set(want):
            raise ValueError(
                f"expert checkpoints must cover cluster ids 0..{len(loaded) - 1} "
                f"exactly (the router posterior's columns are positional); "
                f"got {sorted(seen)} — missing {sorted(set(want) - set(seen))}"
            )
        loaded.sort(key=lambda item: item[0])
        experts, params = [], []
        for cid, path, p, meta in loaded:
            experts.append(ExpertSpec(
                name=meta.get("name", os.path.basename(path)),
                objective=meta["objective"],
                schedule=meta["schedule"],
                apply_fn=apply_fn,
                cluster_id=cid,
            ))
            params.append(p)
        router_fn = None
        router_path = os.path.join(ckpt_dir, "router.npz")
        if router_cfg is not None and os.path.exists(router_path):
            rp, _ = load_checkpoint(router_path)
            router_fn = D.make_router_fn(router_cfg, rp)
        sampler = sampler if sampler is not None else SamplerConfig()
        if param_dtype is not None:
            sampler = dataclasses.replace(sampler, param_dtype=param_dtype)
        return cls(
            experts=experts, expert_params=params, router_fn=router_fn,
            latent_shape=(dit_cfg.latent_size, dit_cfg.latent_size,
                          dit_cfg.latent_channels),
            sampler=sampler,
            engine=engine,
            n_expert_shards=n_expert_shards, n_data_shards=n_data_shards,
            cond_cache_size=cond_cache_size,
        )

    # -- cross-request conditioning cache -----------------------------------

    def _cached_cond(self, text_emb):
        """Content-hash-keyed LRU over conditioning arrays.

        Requests carrying byte-identical embeddings (the common case for
        the paper's intra-prompt-diversity workload: one prompt, many
        seeds) resolve to ONE resident device buffer; distinct contents
        evict least-recently-used.  ``stats['cond_cache_hits'/'..misses']``
        expose the behavior.  Hashing happens on host bytes, off the
        compiled hot path — and therefore only for HOST inputs: an
        embedding already resident on device (``jax.Array``) passes
        through untouched, because hashing it would force a blocking
        device→host transfer per request just to dedupe a buffer the
        caller is already sharing.
        """
        if text_emb is None:
            return None
        if isinstance(text_emb, jax.Array) or self.cond_cache_size <= 0:
            return jnp.asarray(text_emb)
        arr = np.asarray(text_emb)
        key = (arr.shape, str(arr.dtype),
               hashlib.sha1(arr.tobytes()).hexdigest())
        cached = self._cond_cache.get(key)
        if cached is not None:
            self._cond_cache.move_to_end(key)
            self.stats["cond_cache_hits"] += 1
            return cached
        self.stats["cond_cache_misses"] += 1
        val = jnp.asarray(arr)
        self._cond_cache[key] = val
        while len(self._cond_cache) > self.cond_cache_size:
            self._cond_cache.popitem(last=False)
        return val

    def _count_plan_refreshes(self) -> None:
        """One sampler dispatch refreshes the plan ceil(S/R) times (the
        i % R == 0 steps of the scan) — deterministic, so counted exactly
        without a runtime callback on the hot path."""
        r = max(1, self.sampler.plan_refresh_every)
        self.stats["plan_refreshes"] += -(-self.sampler.num_steps // r)

    # -- retrace-free compiled-sampler cache --------------------------------

    def _get_compiled(self, batch_size: int, has_text: bool) -> Callable:
        """Jitted sampler keyed by everything that changes the trace.

        The initial-noise buffer is donated — XLA reuses it for the
        evolving latent state instead of allocating a fresh buffer per
        request.  On a sharded engine the noise/text inputs carry
        explicit "data"-axis shardings and the latent state is pinned to
        them throughout the scan.
        """
        cache_key = (batch_size, self.latent_shape, self.sampler,
                     self.engine, has_text)
        fn = self._compiled.get(cache_key)
        if fn is None:
            shape = (batch_size,) + self.latent_shape
            latent_sharding = None
            plan_sharding = None
            jit_kwargs: dict = {}
            if self.mesh is not None:
                lat_spec = serve_batch_spec(self.mesh, shape)
                latent_sharding = NamedSharding(self.mesh, lat_spec)
                plan_sharding = dispatch_plan_sharding(self.mesh)
                batch_sharded = len(lat_spec) > 0 and lat_spec[0] is not None
                text_spec = P("data") if (has_text and batch_sharded) else P()
                jit_kwargs["in_shardings"] = (
                    NamedSharding(self.mesh, P()),        # PRNG key
                    latent_sharding,                      # initial noise
                    NamedSharding(self.mesh, text_spec),  # text embeddings
                )

            def _sample(key, noise, text_emb):
                self.stats["traces"] += 1      # runs at trace time only
                cond = {"text_emb": text_emb} if has_text else None
                null = {"text_emb": None} if has_text else None
                return sample_ensemble(
                    key, self.experts, self.expert_params, self.router_fn,
                    shape, cond=cond, null_cond=null, config=self.sampler,
                    engine=self.engine, init_noise=noise,
                    stacked_params=self.param_store,
                    latent_sharding=latent_sharding,
                    plan_sharding=plan_sharding,
                )

            # donation is a no-op (with a warning) on CPU; only request it
            # where XLA can actually alias the buffer.
            donate = () if jax.default_backend() == "cpu" else (1,)
            fn = jax.jit(_sample, donate_argnums=donate, **jit_kwargs)
            self._compiled[cache_key] = fn
        return fn

    def generate(
        self, key, batch_text_emb: jnp.ndarray | None, batch_size: int,
    ) -> jnp.ndarray:
        self.stats["requests"] += 1
        has_text = batch_text_emb is not None
        fn = self._get_compiled(batch_size, has_text)
        noise = jax.random.normal(
            key, (batch_size,) + self.latent_shape, dtype=jnp.float32
        )
        if has_text:
            batch_text_emb = self._cached_cond(batch_text_emb)
        else:
            batch_text_emb = jnp.zeros((0,), jnp.float32)   # static filler
        self._count_plan_refreshes()
        return fn(key, noise, batch_text_emb)

    # -- cross-request batching queue ---------------------------------------

    def submit(
        self, key, text_emb: jnp.ndarray | None = None,
        batch_size: int | None = None,
    ) -> PendingRequest:
        """Enqueue a request; returns a handle resolved by ``flush()``.

        Noise is derived from the request's own key at flush time, so a
        coalesced request produces the same samples it would have produced
        through ``generate`` with that key.
        """
        if batch_size is None:
            batch_size = text_emb.shape[0] if text_emb is not None else 1
        if text_emb is not None and text_emb.shape[0] != batch_size:
            raise ValueError(
                f"text_emb batch {text_emb.shape[0]} != batch_size "
                f"{batch_size}"
            )
        req = PendingRequest(key=key, text_emb=self._cached_cond(text_emb),
                             batch_size=batch_size)
        self._queue.append(req)
        self.stats["requests"] += 1
        return req

    def flush(self) -> int:
        """Run all queued requests, coalescing compatible ones.

        Latent shape and sampler config are engine invariants, so within
        one engine compatibility reduces to the conditioning signature
        (text present + trailing text shape).  Each group becomes ONE
        batched sampler dispatch; the merged batch is padded up to a
        power-of-two bucket (bounding compile count under varying request
        mixes) that is also a multiple of the mesh "data" axis on a
        sharded engine (so the batch dim always shards cleanly), and
        per-request slices (padding dropped) are written back to the
        handles.  Returns the number of merged dispatches.
        """
        if not self._queue:
            return 0
        groups: dict[tuple, list[PendingRequest]] = {}
        for req in self._queue:
            sig = (req.text_emb is not None,
                   tuple(req.text_emb.shape[1:])
                   if req.text_emb is not None else ())
            groups.setdefault(sig, []).append(req)
        self._queue = []
        pending = list(groups.items())
        for gi, ((has_text, text_tail), reqs) in enumerate(pending):
            try:
                self._dispatch_group(has_text, text_tail, reqs)
            except Exception:
                # re-queue this and every unprocessed group so a failed
                # dispatch (compile error, OOM on a new bucket size)
                # doesn't strand the other handles undone forever.
                for _, rs in pending[gi:]:
                    self._queue.extend(rs)
                raise
        return len(pending)

    def _dispatch_group(
        self, has_text: bool, text_tail: tuple, reqs: list[PendingRequest],
    ) -> None:
        total = sum(r.batch_size for r in reqs)
        # Bucket the merged batch to the next power of two (and a
        # "data"-axis multiple on a sharded engine): varying request
        # mixes then land on O(log max_batch) compiled sizes instead
        # of one compile per distinct total, keeping the engine
        # retrace-free under real traffic.
        bucket = 1 << (total - 1).bit_length()
        if self.mesh is not None:
            nd = self.mesh.shape["data"]
            bucket += (-bucket) % nd
        pad = bucket - total
        noise = [
            jax.random.normal(
                r.key, (r.batch_size,) + self.latent_shape, jnp.float32
            )
            for r in reqs
        ]
        if pad:
            noise.append(jnp.zeros((pad,) + self.latent_shape, jnp.float32))
        noise = jnp.concatenate(noise, axis=0)
        if has_text:
            text = [jnp.asarray(r.text_emb) for r in reqs]
            if pad:
                text.append(jnp.zeros((pad,) + text_tail, text[0].dtype))
            text = jnp.concatenate(text, axis=0)
        else:
            text = jnp.zeros((0,), jnp.float32)             # static filler
        fn = self._get_compiled(total + pad, has_text)
        self._count_plan_refreshes()
        out = fn(reqs[0].key, noise, text)
        self.stats["merged_batches"] += 1
        self.stats["batched_requests"] += len(reqs)
        off = 0
        for r in reqs:
            r._result = out[off:off + r.batch_size]
            r.done = True
            off += r.batch_size


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog="shards > 1 need that many visible devices — on a CPU host "
               "set XLA_FLAGS=--xla_force_host_platform_device_count=N "
               "before launching (as launch/dryrun.py does)."
    )
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--cfg-scale", type=float, default=7.5)
    ap.add_argument("--strategy", default="topk",
                    choices=("top1", "topk", "full", "threshold"))
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "routed", "dense", "reference"))
    ap.add_argument("--dispatch", default="auto",
                    choices=("auto", "gathered", "grouped", "dense"),
                    help="expert-dispatch executor backend "
                         "(core.dispatch): per-sample gather+vmap vs "
                         "sort-based grouped segment execution")
    ap.add_argument("--param-dtype", default="native",
                    choices=("native", "fp32", "bf16", "int8", "fp8"),
                    help="stacked expert-param storage "
                         "(core.param_store): int8/fp8 quantize on load "
                         "with per-expert scales and dequantize routed "
                         "slices through the fused Pallas kernel "
                         "(~4x fewer resident expert-param bytes)")
    ap.add_argument("--plan-refresh", type=int, default=1,
                    help="recompute the router posterior + DispatchPlan "
                         "only every R-th Euler step, carrying the plan "
                         "through the scan in between (R=1 = per-step "
                         "routing, bit-identical to the classic path; "
                         "R>1 trades bounded drift for skipping the "
                         "router forward on the other steps)")
    ap.add_argument("--no-step-fuse", action="store_true",
                    help="disable the step-fused kernel (CFG combine + "
                         "Euler update folded into convert-and-fuse) and "
                         "run the unfused three-op chain instead")
    ap.add_argument("--cond-cache", type=int, default=64,
                    help="cross-request conditioning LRU capacity "
                         "(content-hash-keyed text-embedding reuse "
                         "across submit()/generate() calls; 0 disables)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--latent-size", type=int, default=8)
    ap.add_argument("--expert-shards", type=int, default=1)
    ap.add_argument("--data-shards", type=int, default=None)
    ap.add_argument("--coalesce", action="store_true",
                    help="drive requests through submit()/flush() instead "
                         "of per-request generate()")
    args = ap.parse_args()

    dit_cfg = dit_b2()
    rcfg = router_b2()
    if args.reduced:
        dit_cfg = dit_cfg.reduced(latent_size=args.latent_size)
        rcfg = rcfg.reduced(latent_size=args.latent_size)
    engine = ServingEngine.from_checkpoint_dir(
        args.ckpt_dir, dit_cfg=dit_cfg, router_cfg=rcfg,
        sampler=SamplerConfig(
            num_steps=args.steps, cfg_scale=args.cfg_scale,
            strategy=args.strategy, top_k=args.top_k,
            dispatch=args.dispatch, param_dtype=args.param_dtype,
            step_fused=not args.no_step_fuse,
            plan_refresh_every=args.plan_refresh,
        ),
        engine=args.engine,
        n_expert_shards=args.expert_shards, n_data_shards=args.data_shards,
        cond_cache_size=args.cond_cache,
    )
    print(f"loaded {len(engine.experts)} experts "
          f"({[e.objective for e in engine.experts]}) "
          f"homogeneous={engine.homogeneous} "
          f"mesh={dict(engine.mesh.shape) if engine.mesh else None}")
    if args.coalesce:
        t0 = time.time()
        handles = []
        for r in range(args.requests):
            key = jax.random.PRNGKey(r)
            # host-side ndarray, as a remote text encoder would deliver —
            # the form the conditioning cache hashes and dedupes
            text = np.asarray(jax.random.normal(
                key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
            ))
            handles.append(engine.submit(key, text))
        engine.flush()
        outs = [jax.block_until_ready(h.result()) for h in handles]
        dt = time.time() - t0
        n = sum(o.shape[0] for o in outs)
        print(f"coalesced {len(handles)} requests -> "
              f"{engine.stats['merged_batches']} dispatch(es): "
              f"{n} imgs in {dt:.2f}s ({n / dt:.1f} img/s) "
              f"traces={engine.stats['traces']}")
        print(f"cache: cond_hits={engine.stats['cond_cache_hits']} "
              f"cond_misses={engine.stats['cond_cache_misses']} "
              f"plan_refreshes={engine.stats['plan_refreshes']} "
              f"(R={args.plan_refresh}, {args.steps} steps/dispatch)")
        return
    for r in range(args.requests):
        key = jax.random.PRNGKey(r)
        t0 = time.time()
        # host-side ndarray, as a remote text encoder would deliver
        text = np.asarray(jax.random.normal(
            key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
        ))
        out = engine.generate(key, text, args.batch)
        out = jax.block_until_ready(out)
        dt = time.time() - t0
        print(f"request {r}: {out.shape} in {dt:.2f}s "
              f"({args.batch / dt:.1f} img/s) "
              f"traces={engine.stats['traces']} "
              f"finite={bool(np.isfinite(np.asarray(out)).all())}")
    print(f"cache: cond_hits={engine.stats['cond_cache_hits']} "
          f"cond_misses={engine.stats['cond_cache_misses']} "
          f"plan_refreshes={engine.stats['plan_refreshes']} "
          f"(R={args.plan_refresh}, {args.steps} steps/request)")


if __name__ == "__main__":
    main()
