"""Serving engine for heterogeneous decentralized diffusion.

Loads a directory of self-describing expert checkpoints (each carries its
objective / schedule / cluster metadata — §5 limitation iv) plus a router
checkpoint, and serves batched text-to-image requests with the paper's
Fig. 2 pipeline on the compute-sparse hot path: router posterior → Top-K
expert selection → **routed-expert-only** native predictions (stacked
params + gather dispatch; CFG batched along the batch axis) → one fused
schedule-aware ε→v-and-combine kernel per Euler step.

Serving properties:

* **compute-sparse** — only the routed experts run each step (k forwards
  instead of K; 1 forward with batched CFG instead of 2), matching the
  paper's claim that Top-K routing pays single-model cost at ensemble
  quality.  Heterogeneous-architecture expert sets fall back to the dense
  fused path automatically.
* **pluggable dispatch** — ``SamplerConfig.dispatch`` selects the expert
  executor backend (``core.dispatch``): ``gathered`` (per-sample param
  gather + vmap, the default), ``grouped`` (sort-based grouped execution:
  one segment pass per resident expert instead of ``B·k`` vmapped lanes —
  the DDM/Paris-style serving layout), or ``dense``.  The per-step
  ``DispatchPlan`` replicates across the mesh
  (``launch.sharding.dispatch_plan_sharding``) while grouped segment
  params resolve from *static* expert slices of the stacked pytree, so
  each shard executes its resident experts' groups without a per-sample
  params all-gather.
* **quantized experts** — ``SamplerConfig.param_dtype`` (CLI
  ``--param-dtype``) stores the stacked expert pytree as a typed
  ``core.param_store.ExpertParamStore``: ``int8``/``fp8`` quantize on
  load with per-expert symmetric scales (~4x fewer resident expert-param
  bytes than fp32), the full-precision per-expert list is dropped, and
  routed slices dequantize through the fused ``hetero_fuse_dequant``
  Pallas kernel — stacked leaves never round-trip through HBM at full
  precision.
* **step-fused** — ``SamplerConfig.step_fused`` (default on) folds the
  CFG combine and the Euler update into the convert-and-fuse kernel
  (``kernels.ops.fused_step``): one fused kernel launch per step, the
  latent read once and written once instead of three latent-sized HBM
  round-trips; ``--no-step-fuse`` restores the unfused op chain.
* **plan reuse** — ``SamplerConfig.plan_refresh_every`` / CLI
  ``--plan-refresh R`` recomputes the router posterior + ``DispatchPlan``
  only every R-th Euler step (posteriors change slowly in t), carrying
  the plan through the scan; R=1 is bit-identical to per-step routing
  and ``stats['plan_refreshes']`` counts refresh work.
* **conditioning cache** — a content-hash-keyed LRU
  (``cond_cache_size`` / ``--cond-cache``) dedupes text embeddings
  across ``submit()``/``generate()`` calls, so the intra-prompt-diversity
  workload (one prompt, many seeds) holds one resident buffer per
  distinct prompt; ``stats['cond_cache_hits'/'cond_cache_misses']``
  expose the behavior.
* **retrace-free** — ``ServingEngine`` caches a jitted sampling function
  per (batch size, latent shape, sampler config, conditioning signature)
  with the noise buffer donated, so repeated requests with the same shape
  never recompile; ``engine.stats['traces']`` exposes the compile count.
* **sharded** — ``n_expert_shards`` / ``n_data_shards`` place the engine
  on an expert-parallel mesh (topology below) so a host never needs to
  hold the full ensemble's parameters per device.
* **cross-request batching** — ``submit()`` enqueues requests and
  ``flush()`` coalesces compatible ones (same latent shape and sampler
  config — engine invariants — plus the same conditioning signature) into
  one sharded batch, slicing per-request outputs back out, so concurrent
  small requests share a single compiled sampler dispatch.

Topology
--------
The sharded engine lives on an ``("expert", "data")`` mesh
(``launch.mesh.make_expert_mesh``):

* the stacked expert pytree (leaves ``(K, ...)``,
  ``models.dit.stack_expert_params``) shards its leading K axis over
  "expert" — each device group holds ``K / n_expert_shards`` resident
  experts (DDM/Paris-style placement: experts are *placed across*
  devices, not replicated per host);
* request batches (initial noise, text embeddings, the evolving latent
  state) shard their leading batch dim over "data";
* per-step routed dispatch gathers the k selected experts' params from
  their owning shards — GSPMD lowers the stacked-axis gather to an
  all-gather of just those slices over the "expert" axis — and the fused
  velocity/Euler update runs data-parallel on the batch shards
  (``core.sampling`` re-constrains the latent to the "data" axis every
  step);
* the single-host path is the degenerate 1×1 mesh (or ``mesh=None``) and
  is bit-identical to unsharded serving.

Also exposes ``ServingEngine`` programmatically (used by examples/ and the
benchmark harness).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import hashlib
import os
import re
import time
from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    DenseStore,
    ExpertSpec,
    SamplerConfig,
    coeff_tables_cached,
    make_store,
    pad_to_capacity,
    params_are_stackable,
    sample_ensemble,
)
from repro.launch.mesh import make_expert_mesh
from repro.launch.sharding import (
    dispatch_plan_sharding,
    expert_param_shardings,
    serve_batch_spec,
)
from repro.models import dit as D
from repro.models.config import DiTConfig, dit_b2, router_b2
from repro.serving.resilience import (
    DeadlineExceeded,
    RequestFailed,
    RequestTimeout,
)
from repro.training import load_checkpoint

#: ``expert7.npz`` / ``expert_07.npz`` → checkpoint index 7 (ordering
#: fallback when the metadata carries no ``cluster_id``).
_EXPERT_IDX_RE = re.compile(r"expert[_-]?(\d+)")

#: Per-capacity-slot health states (elastic membership):
#: ``EMPTY`` — never-filled capacity padding (zero params, masked);
#: ``ACTIVE`` — live, routable;
#: ``DRAINING`` — ``retire_expert``: masked immediately (no NEW routing)
#: but held until the next ``flush()`` completes the in-flight requests
#: admitted under it, then transitions to ``EVICTED``;
#: ``QUARANTINED`` — masked because its artifact/params failed integrity
#: checks (recorded in ``ServingEngine.quarantine``);
#: ``PROBATION`` — masked by the circuit breaker (``trip_expert``:
#: rolling fault score crossed the trip threshold); canary probes on a
#: backoff schedule move it back to ``ACTIVE`` via ``restore_expert``
#: (see ``repro.serving.resilience``);
#: ``EVICTED`` — masked by ``evict_expert``; the slot is reusable by
#: ``add_expert``.
EXPERT_HEALTH_STATES = ("EMPTY", "ACTIVE", "DRAINING", "QUARANTINED",
                        "PROBATION", "EVICTED")


def _validate_expert_params(params, template, path: str) -> None:
    """Integrity gate for a contributor checkpoint's param pytree.

    Raises ``ValueError`` naming the file and the reason: tree-structure
    or leaf-shape mismatch against the ensemble's slot template, or
    non-finite (NaN/Inf) leaf values — the failure classes a corrupt or
    foreign artifact produces *after* the archive itself parsed.
    """
    leaves, treedef = jax.tree.flatten(params)
    if template is not None:
        tdef, shapes = template
        if treedef != tdef:
            raise ValueError(
                f"{path}: param tree structure does not match the "
                f"ensemble's expert template — wrong architecture or a "
                f"partially-written checkpoint"
            )
        for leaf, shape in zip(leaves, shapes):
            if tuple(np.shape(leaf)) != tuple(shape):
                raise ValueError(
                    f"{path}: leaf shape mismatch {tuple(np.shape(leaf))} "
                    f"!= template {tuple(shape)}"
                )
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f" and not np.isfinite(arr).all():
            raise ValueError(
                f"{path}: non-finite leaf values (NaN/Inf) — corrupt "
                f"training artifact"
            )


@dataclasses.dataclass
class PendingRequest:
    """Handle returned by ``ServingEngine.submit``; resolved by ``flush``.

    ``state`` walks QUEUED → DONE, or to one of two terminal failure
    states: FAILED once the request's dispatch group exhausted its
    automatic re-queues, or DEADLINE_EXCEEDED once its
    ``deadline_s``/``max_steps`` lifetime bound expired — ``result()``
    then raises the named error (``RequestFailed`` / ``DeadlineExceeded``,
    both carrying the request id and requeue count) instead of hanging
    the caller.  On an elastic engine the request also snapshots the
    membership it was admitted under (store + coefficient tables +
    cluster map, all immutable), so later evictions/hot-adds cannot
    change its output.
    """

    key: jax.Array
    text_emb: jnp.ndarray | None
    batch_size: int
    _result: jnp.ndarray | None = None
    done: bool = False
    state: str = "QUEUED"
    error: BaseException | None = None
    requeues: int = 0
    _membership: tuple | None = None
    #: global submission order (engine-wide monotonic counter) — the
    #: deterministic FIFO key re-queues and the continuous scheduler
    #: order by.  -1 until assigned by ``submit`` (or the scheduler).
    seq: int = -1
    #: lifetime bounds (``repro.serving.resilience``): wall-clock
    #: seconds from submit, and scheduler ticks from submit.  None = no
    #: bound.  ``flush()`` enforces ``deadline_s`` only (it has no tick
    #: granularity); the resilient scheduler enforces both at tick
    #: boundaries.
    deadline_s: float | None = None
    max_steps: int | None = None
    submit_t: float | None = None

    def result(self, timeout: float | None = None) -> jnp.ndarray:
        """Resolved latents, or the request's named terminal error.

        ``timeout`` (seconds) bounds how long to wait for a concurrent
        driver (another thread ticking the scheduler / flushing the
        engine) to resolve this handle; expiry raises
        :class:`~repro.serving.resilience.RequestTimeout` instead of
        blocking forever on a lost request.  ``timeout=None`` keeps the
        classic non-blocking behavior (raise immediately if unresolved);
        ``timeout=0`` is an explicit instant poll.
        """
        if timeout is not None:
            give_up = time.monotonic() + timeout
            while not self.done and self.state not in (
                "FAILED", "DEADLINE_EXCEEDED"
            ):
                if time.monotonic() >= give_up:
                    raise RequestTimeout(
                        f"request seq={self.seq} still {self.state} "
                        f"after {timeout}s ({self.requeues} requeue(s))",
                        seq=self.seq, requeues=self.requeues,
                    )
                time.sleep(min(0.005, max(timeout, 1e-4)))
        if self.state == "DEADLINE_EXCEEDED":
            if isinstance(self.error, DeadlineExceeded):
                raise self.error
            raise DeadlineExceeded(
                f"request seq={self.seq} exceeded its deadline "
                f"({self.requeues} requeue(s))",
                seq=self.seq, requeues=self.requeues,
            )
        if self.state == "FAILED":
            raise RequestFailed(
                f"request seq={self.seq} failed after {self.requeues} "
                f"dispatch attempt(s): {self.error!r}",
                seq=self.seq, requeues=self.requeues,
            ) from self.error
        if not self.done:
            raise RuntimeError(
                "request not yet flushed — submit() only enqueues; call "
                "ServingEngine.flush() to execute the batched dispatch "
                "before reading result()"
            )
        return self._result


@dataclasses.dataclass
class ServingEngine:
    experts: list[ExpertSpec]
    expert_params: list
    router_fn: object | None
    latent_shape: tuple[int, int, int]
    sampler: SamplerConfig = dataclasses.field(default_factory=SamplerConfig)
    #: 'auto' | 'routed' | 'dense' | 'reference' (see core.sample_ensemble)
    engine: str = "auto"
    #: expert-parallel mesh placement (see module docstring "Topology").
    #: Defaults (1, None) keep the classic unsharded single-device path;
    #: setting either stands up an ("expert", "data") mesh — a forced 1×1
    #: mesh is the degenerate case and stays bit-identical.
    n_expert_shards: int = 1
    n_data_shards: int | None = None
    #: cross-request conditioning cache: max distinct text embeddings /
    #: cond pytrees kept resident, keyed by content hash and evicted LRU.
    #: The paper's intra-prompt-diversity workload re-submits the SAME
    #: prompt embedding across many requests (different seeds), so repeat
    #: ``submit()``/``generate()`` calls reuse one device buffer instead
    #: of re-transferring + re-retaining a copy per request.  Applies to
    #: HOST (numpy) inputs only — device-resident ``jax.Array``
    #: embeddings pass through unhashed (no forced device→host copy).
    #: 0 disables.
    cond_cache_size: int = 64
    #: elastic membership: when set, the stacked store pads to this many
    #: capacity slots with a traced ``(K_cap,)`` validity mask, and the
    #: engine gains ``add_expert``/``evict_expert``/``retire_expert``/
    #: ``quarantine_expert`` — membership changes reach the compiled
    #: sampler as new argument *values* (store, coefficient tables,
    #: cluster map), never a retrace.  None keeps the classic
    #: fixed-membership engine bit-identical.
    capacity: int | None = None
    #: automatic re-queues per request before a failing dispatch group
    #: marks its requests FAILED (carrying the exception) instead of
    #: re-poisoning every subsequent ``flush()`` forever.
    max_request_requeues: int = 1
    #: per-slot startup health (elastic): lets ``from_checkpoint_dir``
    #: mark quarantined-at-load slots; defaults to all-ACTIVE.
    initial_health: list | None = None
    #: opt-in dispatch-padding observability: wraps the shared expert
    #: forwards with a ``jax.debug.callback`` row counter so
    #: ``stats['padded_model_rows']`` tracks rows the backend *executed*
    #: (grouped: power-of-two bucket padding included; ragged: exactly
    #: the routed rows) against the ``routed_model_rows`` the plans
    #: asked for — read via :meth:`padding_stats`.  Off by default: the
    #: callback forces host sync points on the hot path.
    track_padding: bool = False

    def __post_init__(self) -> None:
        self._compiled: dict = {}
        self._queue: list[PendingRequest] = []
        self._seq = 0                      # global submission counter
        self._cond_cache: OrderedDict[tuple, jnp.ndarray] = OrderedDict()
        self.stats = {"traces": 0, "requests": 0,
                      "merged_batches": 0, "batched_requests": 0,
                      "cond_cache_hits": 0, "cond_cache_misses": 0,
                      "plan_refreshes": 0,
                      "experts_added": 0, "experts_evicted": 0,
                      "quarantined_checkpoints": 0, "degraded_steps": 0,
                      "request_requeues": 0, "failed_requests": 0,
                      "padded_model_rows": 0, "routed_model_rows": 0,
                      "model_steps": 0,
                      "deadline_exceeded": 0, "watchdog_trips": 0,
                      "breaker_trips": 0, "breaker_probes": 0,
                      "breaker_restores": 0, "journal_snapshots": 0}
        self.quarantine: list[dict] = []
        if self.track_padding:
            self._instrument_row_counting()
        self.elastic = self.capacity is not None
        self.homogeneous = len(self.experts) <= 1 or (
            all(e.apply_fn is self.experts[0].apply_fn for e in self.experts)
            and params_are_stackable(self.expert_params)
        )
        # Typed stacked-expert store (core.param_store): the routed
        # engine's dispatch substrate.  ``sampler.param_dtype`` selects
        # the storage — 'native' keeps checkpoint precision
        # (bit-identical), 'int8'/'fp8' quantize with per-expert scales
        # (~4x fewer resident expert-param bytes vs fp32).
        pd = self.sampler.param_dtype
        quantized = pd in ("int8", "fp8")
        if pd != "native":
            # The store only serves ROUTED execution: a dense/reference
            # engine (heterogeneous set, strategy='full', single expert,
            # engine override) runs from the per-expert params list at
            # native precision — accepting param_dtype there would either
            # lie about resident bytes (cast dtypes: unused store built
            # next to the fp32 list) or construct an engine whose every
            # generate() fails later (quantized dtypes drop that list).
            # Reject at construction, where strategy/engine are known.
            routed_capable = (
                self.homogeneous and len(self.experts) > 1
                and self.sampler.strategy in ("top1", "topk", "threshold")
                and self.engine in ("auto", "routed")
            )
            if not routed_capable:
                raise ValueError(
                    f"param_dtype={pd!r} changes the stacked expert "
                    f"store's storage, which only routed execution uses: "
                    f"it needs a homogeneous ensemble of ≥ 2 experts "
                    f"(shared apply_fn + stackable params), strategy in "
                    f"top1/topk/threshold, and engine auto/routed — got "
                    f"{len(self.experts)} expert(s), homogeneous="
                    f"{self.homogeneous}, strategy="
                    f"{self.sampler.strategy!r}, engine={self.engine!r}"
                )
        self.param_store = (
            make_store(D.stack_expert_params(self.expert_params), dtype=pd)
            if self.homogeneous and self.expert_params else None
        )
        # Slot template for integrity-validating incoming checkpoints
        # (captured before a quantized store drops the fp list).
        self._slot_template = None
        if self.expert_params:
            leaves, treedef = jax.tree.flatten(self.expert_params[0])
            self._slot_template = (
                treedef, [tuple(np.shape(leaf)) for leaf in leaves]
            )
        if quantized:
            # The quantized store IS the resident representation: drop
            # the full-precision per-expert list so the ~4x byte saving
            # is real, not an extra copy.  (The dense fallback and the
            # reference engine need that list; they raise clearly.)
            self.expert_params = None
        self.expert_health = ["ACTIVE"] * len(self.experts)
        self.membership_epoch = 0
        if self.elastic:
            self._init_elastic()
        self.mesh = None
        if self.n_expert_shards != 1 or self.n_data_shards is not None:
            if self.n_expert_shards > 1 and \
                    len(self.experts) % self.n_expert_shards != 0:
                # sanitize_spec would silently fall back to replicating
                # the expert axis — zero memory savings while reporting a
                # sharded mesh; make the misconfiguration loud instead.
                raise ValueError(
                    f"n_expert_shards={self.n_expert_shards} does not "
                    f"divide the {len(self.experts)}-expert ensemble; "
                    f"expert placement would silently replicate"
                )
            self.mesh = make_expert_mesh(self.n_expert_shards,
                                         self.n_data_shards)
            if self.param_store is not None:
                self.param_store = self._put_store(self.param_store)

    def _put_store(self, store):
        """Place a store on the expert mesh (no-op unsharded).

        Stores are registered pytrees: the quantized scales AND the
        elastic validity mask are ``(K,)`` leaves annotated with the same
        leading "expert" axis, so they shard with the leaves they
        rescale/gate.  Membership updates re-place the (functionally
        new) store through the same shardings.
        """
        if store is None or self.mesh is None:
            return store
        return jax.device_put(
            store,
            expert_param_shardings(
                store, self.mesh, logical_axes=store.logical_axes(),
            ),
        )

    # -- dispatch-padding observability -------------------------------------

    def _instrument_row_counting(self) -> None:
        """Wrap the shared expert forwards with runtime row counters.

        One wrapper per forward kind, shared by every spec — the
        homogeneity check (and ragged eligibility) compares functions by
        identity, so per-spec closures would silently force the dense
        engine.  ``jax.debug.callback`` fires only in branches that
        execute, which is the point: the grouped trace holds every
        power-of-two bucket branch, and trace-time counting would tally
        padding that never runs.
        """
        if not self.experts:
            return
        if any(e.apply_fn is not self.experts[0].apply_fn
               for e in self.experts):
            raise ValueError(
                "track_padding=True needs a homogeneous ensemble (one "
                "shared apply_fn): heterogeneous sets run the dense "
                "executor, which has no dispatch padding to observe"
            )

        def _bump(rows):
            self.stats["padded_model_rows"] += int(rows)

        base_apply = self.experts[0].apply_fn

        def counted_apply(params, x, t, **cond):
            jax.debug.callback(_bump, x.shape[0])
            return base_apply(params, x, t, **cond)

        base_ragged = getattr(self.experts[0], "ragged_apply_fn", None)
        counted_ragged = None
        if base_ragged is not None:
            def counted_ragged(view, x_p, t_p, cond, pe, g):
                jax.debug.callback(_bump, x_p.shape[0] * g)
                return base_ragged(view, x_p, t_p, cond, pe, g)

        self.experts = [
            dataclasses.replace(e, apply_fn=counted_apply,
                                ragged_apply_fn=counted_ragged)
            for e in self.experts
        ]

    def _count_routed_rows(self, batch_size: int, has_text: bool) -> None:
        """Deterministic per-dispatch routed-row demand: ``B·k·g·S`` —
        the rows the plans ask for, before any backend padding."""
        if not self.track_padding:
            return
        k_cap = max(len(self.experts), 1)
        k_slots = 1 if self.sampler.strategy in ("top1", "threshold") \
            else min(self.sampler.top_k, k_cap)
        g = 2 if (has_text and self.sampler.cfg_scale != 1.0) else 1
        steps = self.sampler.num_steps
        self.stats["routed_model_rows"] += batch_size * k_slots * g * steps
        self.stats["model_steps"] += steps

    def padding_stats(self) -> dict:
        """Flush pending row-count callbacks and derive per-step padding
        figures into ``stats`` (requires ``track_padding=True``).

        ``padded_rows_per_step`` is the runtime-executed row count per
        sampling step; ``padding_overhead`` is executed/routed − 1 (the
        grouped backend's bucket padding tax; 0.0 under ``ragged``).
        """
        if not self.track_padding:
            raise ValueError(
                "padding stats need ServingEngine(track_padding=True) — "
                "row counting instruments the expert forwards at "
                "construction time"
            )
        jax.effects_barrier()                  # callbacks may be in flight
        steps = max(self.stats["model_steps"], 1)
        routed = max(self.stats["routed_model_rows"], 1)
        self.stats["padded_rows_per_step"] = (
            self.stats["padded_model_rows"] / steps
        )
        self.stats["routed_rows_per_step"] = (
            self.stats["routed_model_rows"] / steps
        )
        self.stats["padding_overhead"] = (
            self.stats["padded_model_rows"] / routed - 1.0
        )
        return {
            k: self.stats[k]
            for k in ("padded_rows_per_step", "routed_rows_per_step",
                      "padding_overhead")
        }

    # -- elastic membership -------------------------------------------------

    def _init_elastic(self) -> None:
        k0 = len(self.experts)
        if self.param_store is None:
            raise ValueError(
                "elastic serving (capacity=...) needs a homogeneous "
                "ensemble with stackable params — the validity-masked "
                "capacity layout lives in the stacked ExpertParamStore"
            )
        if self.capacity < k0:
            raise ValueError(
                f"capacity={self.capacity} < {k0} loaded experts"
            )
        if self.sampler.strategy not in ("top1", "topk"):
            raise ValueError(
                f"elastic serving requires per-sample routing (strategy "
                f"'top1' or 'topk'); got {self.sampler.strategy!r}"
            )
        if self.engine not in ("auto", "routed"):
            raise ValueError(
                f"elastic serving requires the routed engine (engine "
                f"'auto' or 'routed'); got {self.engine!r}"
            )
        if self.router_fn is None:
            raise ValueError(
                "elastic serving routes per sample; a router_fn is "
                "required"
            )
        if self.sampler.ddpm_low_noise_only > 0.0:
            raise ValueError(
                "elastic serving is incompatible with ddpm_low_noise_only "
                "> 0: the §7.3 gate bakes each slot's objective into the "
                "trace, so a hot-added expert changing a slot's objective "
                "would silently bypass it"
            )
        # Own the membership lists: slots mutate on add/evict and must not
        # alias the caller's.
        self.experts = list(self.experts)
        health = (list(self.initial_health) if self.initial_health
                  else ["ACTIVE"] * k0)
        if len(health) != k0 or any(
            h not in EXPERT_HEALTH_STATES for h in health
        ):
            raise ValueError(
                f"initial_health must be {k0} states from "
                f"{EXPERT_HEALTH_STATES}; got {health}"
            )
        # Capacity padding: EMPTY slots carry zero params, a placeholder
        # spec (same apply_fn — objectives/schedules reach the sampler as
        # traced coefficient tables, so the placeholder values never
        # execute), and a dead validity bit.
        for i in range(k0, self.capacity):
            self.experts.append(dataclasses.replace(
                self.experts[0], name=f"<empty:{i}>", objective="fm",
                schedule="linear", cluster_id=0,
            ))
        self.expert_health = health + ["EMPTY"] * (self.capacity - k0)
        self.param_store = pad_to_capacity(self.param_store, self.capacity)
        mask = jnp.array([h == "ACTIVE" for h in self.expert_health])
        self.param_store = self.param_store.with_valid(mask)
        self._refresh_membership_arrays()

    def _refresh_membership_arrays(self) -> None:
        """Rebuild the traced membership side-cars from the slot specs.

        The ``(S, 5, K_cap)`` unified-coefficient tables and the
        ``(K_cap,)`` cluster map are jit *arguments* on elastic engines —
        a hot-added expert's objective/schedule/cluster lands as new
        values under the existing trace (``coeff_tables_cached`` makes
        the rebuild a process-wide cache hit for repeated memberships).
        """
        self._coeff_tables = coeff_tables_cached(
            tuple(e.objective for e in self.experts),
            tuple(e.schedule for e in self.experts),
            self.sampler.num_steps, self.sampler.conversion,
        )
        self._cluster_map = jnp.array(
            [max(e.cluster_id, 0) for e in self.experts], jnp.int32
        )

    def _membership(self) -> tuple | None:
        """Immutable admission-time snapshot (epoch, store, tables, map).

        Store/table/map updates are pure-functional, so holding the tuple
        pins a request's routing substrate bit-exactly whatever
        membership ops happen before its flush.
        """
        if not self.elastic:
            return None
        return (self.membership_epoch, self.param_store,
                self._coeff_tables, self._cluster_map)

    def _require_elastic(self, op: str) -> None:
        if not self.elastic:
            raise ValueError(
                f"{op} requires an elastic engine — construct the "
                f"ServingEngine with capacity=<K_cap> (or "
                f"from_checkpoint_dir(capacity=...))"
            )

    @property
    def num_live_experts(self) -> int:
        return sum(h == "ACTIVE" for h in self.expert_health)

    def add_expert(self, ckpt_path: str, *, slot: int | None = None) -> int:
        """Hot-add a contributor checkpoint into a free capacity slot.

        Pipeline: integrity-validate (named ``ValueError``s; failures are
        recorded in ``self.quarantine`` and counted before re-raising —
        the engine itself stays healthy) → quantize per
        ``sampler.param_dtype`` into the slot (``store.set_expert``) →
        incremental router-cluster refresh (coefficient tables + cluster
        map rebuilt from the slot specs) → flip the slot's validity bit.
        A reader can never observe a half-installed expert: the store
        update is functional and the mask flips last, in the same new
        store object.  Returns the slot index.
        """
        self._require_elastic("add_expert")
        if slot is None:
            free = [i for i, h in enumerate(self.expert_health)
                    if h in ("EMPTY", "EVICTED")]
            if not free:
                raise RuntimeError(
                    f"no free capacity slot (capacity={self.capacity}, "
                    f"health={self.expert_health}); evict or retire an "
                    f"expert first"
                )
            slot = free[0]
        elif self.expert_health[slot] in ("ACTIVE", "DRAINING"):
            raise ValueError(
                f"slot {slot} is {self.expert_health[slot]}; evict it "
                f"before overwriting"
            )
        try:
            params, meta = load_checkpoint(ckpt_path)
            for field in ("objective", "schedule"):
                if field not in meta:
                    raise ValueError(
                        f"{ckpt_path}: metadata missing {field!r} — not a "
                        f"self-describing expert checkpoint"
                    )
            _validate_expert_params(params, self._slot_template, ckpt_path)
        except (ValueError, FileNotFoundError) as e:
            self.quarantine.append(
                {"path": ckpt_path, "reason": str(e), "slot": None}
            )
            self.stats["quarantined_checkpoints"] += 1
            raise
        store = self.param_store.set_expert(slot, params)
        store = store.with_valid(store.valid_mask().at[slot].set(True))
        cid = int(meta.get("cluster_id", slot))
        self.experts[slot] = dataclasses.replace(
            self.experts[0],
            name=meta.get("name", os.path.basename(ckpt_path)),
            objective=meta["objective"], schedule=meta["schedule"],
            cluster_id=max(cid, 0),
        )
        self.expert_health[slot] = "ACTIVE"
        self.param_store = self._put_store(store)
        self._refresh_membership_arrays()
        self.membership_epoch += 1
        self.stats["experts_added"] += 1
        return slot

    def _mask_slot(self, e: int, state: str) -> int:
        if not (0 <= e < len(self.experts)):
            raise IndexError(
                f"expert slot {e} out of range [0, {len(self.experts)})"
            )
        if self.expert_health[e] not in ("ACTIVE", "DRAINING"):
            raise ValueError(
                f"slot {e} is {self.expert_health[e]}, not servable"
            )
        store = self.param_store.with_valid(
            self.param_store.valid_mask().at[e].set(False)
        )
        self.param_store = self._put_store(store)
        self.expert_health[e] = state
        self.membership_epoch += 1
        return e

    def evict_expert(self, e: int) -> int:
        """Mask slot ``e`` immediately (state ``EVICTED``).

        New ``generate``/``submit`` calls route over the survivors; any
        already-``submit()``ed request completes against its
        admission-time membership snapshot, bit-identical to a flush
        issued before the eviction.
        """
        self._require_elastic("evict_expert")
        self._mask_slot(e, "EVICTED")
        self.stats["experts_evicted"] += 1
        return e

    def retire_expert(self, e: int) -> int:
        """Graceful eviction: masked immediately, ``DRAINING`` until the
        next ``flush()`` completes the in-flight requests admitted under
        it, then ``EVICTED`` (and reusable by ``add_expert``)."""
        self._require_elastic("retire_expert")
        self._mask_slot(e, "DRAINING")
        self.stats["experts_evicted"] += 1
        return e

    def quarantine_expert(self, e: int, reason: str = "") -> int:
        """Mask slot ``e`` as ``QUARANTINED`` (suspect params at runtime,
        e.g. a health checker caught NaNs) and record it."""
        self._require_elastic("quarantine_expert")
        self._mask_slot(e, "QUARANTINED")
        self.quarantine.append(
            {"path": self.experts[e].name, "reason": reason or "runtime",
             "slot": e}
        )
        self.stats["quarantined_checkpoints"] += 1
        return e

    def trip_expert(self, e: int, reason: str = "") -> int:
        """Circuit-breaker trip: mask slot ``e`` as ``PROBATION``.

        Exactly the ``quarantine_expert`` masking path (validity-bit
        flip + epoch bump through ``_mask_slot`` — capacity-stable
        shapes, never a retrace), but the slot stays owned by the
        breaker: canary probes (``serving.resilience``) move it back to
        ``ACTIVE`` via :meth:`restore_expert` on a finite pass."""
        self._require_elastic("trip_expert")
        self._mask_slot(e, "PROBATION")
        self.quarantine.append(
            {"path": self.experts[e].name,
             "reason": reason or "breaker trip", "slot": e}
        )
        self.stats["breaker_trips"] += 1
        return e

    def restore_expert(self, e: int) -> int:
        """Un-mask a ``PROBATION``/``QUARANTINED`` slot back to
        ``ACTIVE`` (validity-bit flip + epoch bump — no retrace).  The
        breaker calls this after a passing canary probe; operators can
        call it directly after re-validating a quarantined slot."""
        self._require_elastic("restore_expert")
        if not (0 <= e < len(self.experts)):
            raise IndexError(
                f"expert slot {e} out of range [0, {len(self.experts)})"
            )
        if self.expert_health[e] not in ("PROBATION", "QUARANTINED"):
            raise ValueError(
                f"slot {e} is {self.expert_health[e]}; only PROBATION/"
                f"QUARANTINED slots can be restored"
            )
        store = self.param_store.with_valid(
            self.param_store.valid_mask().at[e].set(True)
        )
        self.param_store = self._put_store(store)
        self.expert_health[e] = "ACTIVE"
        self.membership_epoch += 1
        return e

    def _note_degraded(self, store, steps: int | None = None) -> None:
        """Count degraded-mode steps: serving with fewer live experts
        than the routing width wants (k slots renormalize over the
        survivors — correct, but quality-degraded; §3.1).

        ``steps`` overrides the per-dispatch step count: a lockstep
        dispatch runs ``num_steps`` Euler steps, a rolling-scheduler
        tick runs exactly one."""
        if not self.elastic:
            return
        n_live = int(np.asarray(store.valid_mask()).sum())
        k_slots = 1 if self.sampler.strategy == "top1" \
            else min(self.sampler.top_k, store.num_experts)
        if n_live < k_slots:
            self.stats["degraded_steps"] += (
                self.sampler.num_steps if steps is None else steps
            )

    def membership_line(self) -> str:
        """One-line membership/fault summary (the serve CLI prints it, and
        the quarantine counters round-trip through it — tested)."""
        s = self.stats
        cap = self.capacity if self.elastic else len(self.experts)
        probation = sum(h == "PROBATION" for h in self.expert_health)
        return (f"membership: live={self.num_live_experts}/{cap} "
                f"added={s['experts_added']} "
                f"evicted={s['experts_evicted']} "
                f"quarantined={s['quarantined_checkpoints']} "
                f"degraded_steps={s['degraded_steps']} "
                f"requeues={s['request_requeues']} "
                f"failed={s['failed_requests']} "
                f"probation={probation} "
                f"trips={s['breaker_trips']} "
                f"probes={s['breaker_probes']} "
                f"restores={s['breaker_restores']} "
                f"deadline_exceeded={s['deadline_exceeded']}")

    def restore(self, journal_dir: str, **kwargs):
        """Crash recovery: rebuild a resilient scheduler from a request
        journal written by a previous process and re-admit its in-flight
        requests at their last snapshot (bitwise-identical continuation —
        see ``repro.serving.resilience.ResilientScheduler.restore`` for
        the exact semantics and membership-verification rules).  The
        engine must be assembled from the same checkpoints/membership
        the journal was written under.  Returns the scheduler."""
        from repro.serving.resilience import ResilientScheduler

        return ResilientScheduler.restore(self, journal_dir, **kwargs)

    @property
    def stacked_params(self):
        """Back-compat view of the dispatch substrate.

        Dense stores expose their raw stacked pytree (the pre-store
        convention); quantized stores return the store itself — reading
        full-precision stacked leaves out of a quantized engine would
        defeat its resident-byte budget.
        """
        if isinstance(self.param_store, DenseStore):
            return self.param_store.stacked
        return self.param_store

    @classmethod
    def from_checkpoint_dir(
        cls, ckpt_dir: str, *, dit_cfg: DiTConfig,
        router_cfg: DiTConfig | None = None,
        sampler: SamplerConfig | None = None,
        engine: str = "auto",
        param_dtype: str | None = None,
        n_expert_shards: int = 1,
        n_data_shards: int | None = None,
        cond_cache_size: int = 64,
        capacity: int | None = None,
        on_bad_checkpoint: str = "raise",
        track_padding: bool = False,
    ) -> "ServingEngine":
        """Assemble an engine from a directory of expert checkpoints.

        Experts are ordered **numerically by cluster id** (from each
        checkpoint's metadata, falling back to the ``expert<N>.npz``
        filename index), never lexicographically — with ≥10 experts
        ``sorted(glob(...))`` would load ``expert10`` before ``expert2``
        and silently scramble the router's positional cluster→expert
        mapping.  Duplicate cluster ids always raise.

        ``on_bad_checkpoint`` controls what a corrupt/truncated/
        shape-mismatched artifact does: ``'raise'`` (default) propagates
        the named ``ValueError``; ``'skip'`` quarantines the file
        (recorded on ``engine.quarantine`` and in
        ``stats['quarantined_checkpoints']``) and serves the remaining
        experts, filling any cluster-id hole the bad file leaves with a
        masked EMPTY slot — which forces the elastic (capacity) path so
        the hole never routes.  ``capacity`` (> number of slots) reserves
        padded slots for :meth:`add_expert` hot-joins.

        ``param_dtype`` (overrides ``sampler.param_dtype`` when given)
        selects the stacked-store storage: ``'int8'``/``'fp8'`` quantize
        **on load** and drop the full-precision per-expert list, so an
        8-expert ensemble holds ~¼ the resident expert-param bytes of
        the fp32 checkpoints it was assembled from.
        """
        if on_bad_checkpoint not in ("raise", "skip"):
            raise ValueError(
                f"on_bad_checkpoint must be 'raise' or 'skip', "
                f"got {on_bad_checkpoint!r}"
            )
        apply_fn = D.make_expert_apply(dit_cfg)
        # One shared pair-major ragged forward per ensemble: publishing it
        # on every ExpertSpec makes dispatch='auto' pick the one-kernel
        # ragged grouped-GEMM backend (class-conditional configs keep the
        # grouped backend — the ragged forward is text/uncond only).
        ragged_fn = None
        if not dit_cfg.num_classes:
            ragged_fn = D.make_ragged_expert_apply(dit_cfg)
        paths = glob.glob(os.path.join(ckpt_dir, "expert*.npz"))
        if not paths:
            raise FileNotFoundError(f"no expert*.npz under {ckpt_dir}")
        loaded: list[tuple[int, str, object, dict]] = []
        quarantined: list[dict] = []
        template = None
        for path in sorted(paths):
            try:
                p, meta = load_checkpoint(path)
                for field in ("objective", "schedule"):
                    if field not in meta:
                        raise ValueError(
                            f"{path}: missing '{field}' metadata — not a "
                            f"self-describing expert checkpoint"
                        )
                cid = int(meta.get("cluster_id", -1))
                if cid < 0:
                    m = _EXPERT_IDX_RE.search(os.path.basename(path))
                    if m is None:
                        raise ValueError(
                            f"{path}: no cluster_id metadata and no numeric "
                            f"index in the filename — cannot place this "
                            f"expert"
                        )
                    cid = int(m.group(1))
                if template is None:
                    leaves, treedef = jax.tree_util.tree_flatten(p)
                    template = (treedef, [tuple(np.shape(x)) for x in leaves])
                else:
                    _validate_expert_params(p, template, path)
            except (ValueError, FileNotFoundError) as e:
                if on_bad_checkpoint == "raise":
                    raise
                quarantined.append({"path": path, "reason": str(e)})
                continue
            loaded.append((cid, path, p, meta))
        if not loaded:
            raise ValueError(
                f"every expert checkpoint under {ckpt_dir} was quarantined: "
                f"{[q['path'] for q in quarantined]}"
            )
        seen: dict[int, str] = {}
        for cid, path, _, _ in loaded:
            if cid in seen:
                raise ValueError(
                    f"duplicate cluster_id {cid}: {seen[cid]} and {path}"
                )
            seen[cid] = path
        n_slots = max(seen) + 1
        holes = sorted(set(range(n_slots)) - set(seen))
        if holes and on_bad_checkpoint == "raise":
            raise ValueError(
                f"expert checkpoints must cover cluster ids 0..{n_slots - 1} "
                f"exactly (the router posterior's columns are positional); "
                f"got {sorted(seen)} — missing {holes}"
            )
        loaded.sort(key=lambda item: item[0])
        by_cid = {cid: (path, p, meta) for cid, path, p, meta in loaded}
        experts, params, health = [], [], []
        for cid in range(n_slots):
            if cid in by_cid:
                path, p, meta = by_cid[cid]
                experts.append(ExpertSpec(
                    name=meta.get("name", os.path.basename(path)),
                    objective=meta["objective"],
                    schedule=meta["schedule"],
                    apply_fn=apply_fn,
                    cluster_id=cid,
                    ragged_apply_fn=ragged_fn,
                ))
                params.append(p)
                health.append("ACTIVE")
            else:
                # Masked placeholder for a quarantined slot: zero params,
                # valid=False — never routed, never gathered.
                experts.append(ExpertSpec(
                    name=f"<quarantined:{cid}>", objective="fm",
                    schedule="linear", apply_fn=apply_fn, cluster_id=cid,
                    ragged_apply_fn=ragged_fn,
                ))
                params.append(jax.tree.map(jnp.zeros_like, loaded[0][2]))
                health.append("EMPTY")
        if holes and capacity is None:
            capacity = n_slots                   # masking needs elastic mode
        router_fn = None
        router_path = os.path.join(ckpt_dir, "router.npz")
        if router_cfg is not None and os.path.exists(router_path):
            rp, _ = load_checkpoint(router_path)
            router_fn = D.make_router_fn(router_cfg, rp)
        sampler = sampler if sampler is not None else SamplerConfig()
        if param_dtype is not None:
            sampler = dataclasses.replace(sampler, param_dtype=param_dtype)
        eng = cls(
            experts=experts, expert_params=params, router_fn=router_fn,
            latent_shape=(dit_cfg.latent_size, dit_cfg.latent_size,
                          dit_cfg.latent_channels),
            sampler=sampler,
            engine=engine,
            n_expert_shards=n_expert_shards, n_data_shards=n_data_shards,
            cond_cache_size=cond_cache_size,
            capacity=capacity,
            initial_health=health if capacity is not None else None,
            track_padding=track_padding,
        )
        if quarantined:
            eng.quarantine.extend(quarantined)
            eng.stats["quarantined_checkpoints"] += len(quarantined)
        return eng

    # -- cross-request conditioning cache -----------------------------------

    def _cached_cond(self, text_emb):
        """Content-hash-keyed LRU over conditioning arrays.

        Requests carrying byte-identical embeddings (the common case for
        the paper's intra-prompt-diversity workload: one prompt, many
        seeds) resolve to ONE resident device buffer; distinct contents
        evict least-recently-used.  ``stats['cond_cache_hits'/'..misses']``
        expose the behavior.  Hashing happens on host bytes, off the
        compiled hot path — and therefore only for HOST inputs: an
        embedding already resident on device (``jax.Array``) passes
        through untouched, because hashing it would force a blocking
        device→host transfer per request just to dedupe a buffer the
        caller is already sharing.
        """
        if text_emb is None:
            return None
        if isinstance(text_emb, jax.Array) or self.cond_cache_size <= 0:
            return jnp.asarray(text_emb)
        arr = np.asarray(text_emb)
        key = (arr.shape, str(arr.dtype),
               hashlib.sha1(arr.tobytes()).hexdigest())
        cached = self._cond_cache.get(key)
        if cached is not None:
            self._cond_cache.move_to_end(key)
            self.stats["cond_cache_hits"] += 1
            return cached
        self.stats["cond_cache_misses"] += 1
        val = jnp.asarray(arr)
        self._cond_cache[key] = val
        while len(self._cond_cache) > self.cond_cache_size:
            self._cond_cache.popitem(last=False)
        return val

    def _count_plan_refreshes(self) -> None:
        """One sampler dispatch refreshes the plan ceil(S/R) times (the
        i % R == 0 steps of the scan) — deterministic, so counted exactly
        without a runtime callback on the hot path."""
        r = max(1, self.sampler.plan_refresh_every)
        self.stats["plan_refreshes"] += -(-self.sampler.num_steps // r)

    # -- retrace-free compiled-sampler cache --------------------------------

    def _get_compiled(self, batch_size: int, has_text: bool) -> Callable:
        """Jitted sampler keyed by everything that changes the trace.

        The initial-noise buffer is donated — XLA reuses it for the
        evolving latent state instead of allocating a fresh buffer per
        request.  On a sharded engine the noise/text inputs carry
        explicit "data"-axis shardings and the latent state is pinned to
        them throughout the scan.
        """
        cache_key = (batch_size, self.latent_shape, self.sampler,
                     self.engine, has_text)
        fn = self._compiled.get(cache_key)
        if fn is None:
            shape = (batch_size,) + self.latent_shape
            latent_sharding = None
            plan_sharding = None
            jit_kwargs: dict = {}
            if self.mesh is not None:
                lat_spec = serve_batch_spec(self.mesh, shape)
                latent_sharding = NamedSharding(self.mesh, lat_spec)
                plan_sharding = dispatch_plan_sharding(self.mesh)
                batch_sharded = len(lat_spec) > 0 and lat_spec[0] is not None
                text_spec = P("data") if (has_text and batch_sharded) else P()
                in_shardings = [
                    NamedSharding(self.mesh, P()),        # PRNG key
                    latent_sharding,                      # initial noise
                    NamedSharding(self.mesh, text_spec),  # text embeddings
                ]
                if self.elastic:
                    in_shardings += [
                        expert_param_shardings(
                            self.param_store, self.mesh,
                            logical_axes=self.param_store.logical_axes(),
                        ),                                # membership store
                        NamedSharding(self.mesh, P()),    # coeff tables
                        NamedSharding(self.mesh, P()),    # cluster map
                    ]
                jit_kwargs["in_shardings"] = tuple(in_shardings)

            if self.elastic:
                # Elastic engines take the membership substrate — store
                # (with its validity mask), coefficient tables, cluster
                # map — as jit ARGUMENTS: closing over them would bake
                # membership into the trace as constants, forcing a
                # recompile per add/evict.  Shapes are capacity-stable,
                # so every epoch hits the same compiled fn.
                def _sample(key, noise, text_emb, store, tables, cmap):
                    self.stats["traces"] += 1  # runs at trace time only
                    cond = {"text_emb": text_emb} if has_text else None
                    null = {"text_emb": None} if has_text else None
                    return sample_ensemble(
                        key, self.experts, self.expert_params,
                        self.router_fn,
                        shape, cond=cond, null_cond=null,
                        config=self.sampler,
                        engine=self.engine, init_noise=noise,
                        stacked_params=store,
                        latent_sharding=latent_sharding,
                        plan_sharding=plan_sharding,
                        coeff_tables=tables, cluster_map=cmap,
                    )
            else:
                def _sample(key, noise, text_emb):
                    self.stats["traces"] += 1  # runs at trace time only
                    cond = {"text_emb": text_emb} if has_text else None
                    null = {"text_emb": None} if has_text else None
                    return sample_ensemble(
                        key, self.experts, self.expert_params,
                        self.router_fn,
                        shape, cond=cond, null_cond=null,
                        config=self.sampler,
                        engine=self.engine, init_noise=noise,
                        stacked_params=self.param_store,
                        latent_sharding=latent_sharding,
                        plan_sharding=plan_sharding,
                    )

            # donation is a no-op (with a warning) on CPU; only request it
            # where XLA can actually alias the buffer.
            donate = () if jax.default_backend() == "cpu" else (1,)
            fn = jax.jit(_sample, donate_argnums=donate, **jit_kwargs)
            self._compiled[cache_key] = fn
        return fn

    def _run_compiled(self, fn, key, noise, text, membership=None):
        """Invoke a compiled sampler with the right membership arguments.

        ``membership`` is an admission-time snapshot tuple for queued
        requests; ``None`` means current membership (``generate``)."""
        if not self.elastic:
            return fn(key, noise, text)
        if membership is None:
            membership = self._membership()
        _, store, tables, cmap = membership
        self._note_degraded(store)
        return fn(key, noise, text, store, tables, cmap)

    def generate(
        self, key, batch_text_emb: jnp.ndarray | None, batch_size: int,
    ) -> jnp.ndarray:
        self.stats["requests"] += 1
        has_text = batch_text_emb is not None
        fn = self._get_compiled(batch_size, has_text)
        noise = jax.random.normal(
            key, (batch_size,) + self.latent_shape, dtype=jnp.float32
        )
        if has_text:
            batch_text_emb = self._cached_cond(batch_text_emb)
        else:
            batch_text_emb = jnp.zeros((0,), jnp.float32)   # static filler
        self._count_plan_refreshes()
        self._count_routed_rows(batch_size, has_text)
        return self._run_compiled(fn, key, noise, batch_text_emb)

    # -- cross-request batching queue ---------------------------------------

    def _next_seq(self) -> int:
        """Allocate the next global submission-order stamp (shared by
        ``submit`` and the continuous scheduler, so the two admission
        paths order against each other deterministically)."""
        seq = self._seq
        self._seq += 1
        return seq

    def submit(
        self, key, text_emb: jnp.ndarray | None = None,
        batch_size: int | None = None, *,
        deadline_s: float | None = None,
    ) -> PendingRequest:
        """Enqueue a request; returns a handle resolved by ``flush()``.

        Noise is derived from the request's own key at flush time, so a
        coalesced request produces the same samples it would have produced
        through ``generate`` with that key.  ``deadline_s`` bounds the
        request's wall-clock lifetime: a request still queued past it is
        moved to DEADLINE_EXCEEDED at the next ``flush()`` instead of
        dispatching stale work (``result()`` raises the named error).
        """
        if batch_size is None:
            batch_size = text_emb.shape[0] if text_emb is not None else 1
        if text_emb is not None and text_emb.shape[0] != batch_size:
            raise ValueError(
                f"text_emb batch {text_emb.shape[0]} != batch_size "
                f"{batch_size}"
            )
        req = PendingRequest(key=key, text_emb=self._cached_cond(text_emb),
                             batch_size=batch_size,
                             _membership=self._membership(),
                             seq=self._next_seq(),
                             deadline_s=deadline_s,
                             submit_t=time.monotonic())
        self._queue.append(req)
        self.stats["requests"] += 1
        return req

    def flush(self) -> int:
        """Run all queued requests, coalescing compatible ones.

        Latent shape and sampler config are engine invariants, so within
        one engine compatibility reduces to the conditioning signature
        (text present + trailing text shape) — plus, on an elastic
        engine, the membership epoch the request was admitted under, so
        every request executes against its own snapshot.  Each group
        becomes ONE batched sampler dispatch; the merged batch is padded
        up to a power-of-two bucket (bounding compile count under varying
        request mixes) that is also a multiple of the mesh "data" axis on
        a sharded engine (so the batch dim always shards cleanly), and
        per-request slices (padding dropped) are written back to the
        handles.

        Failures are isolated **per group**: a failing dispatch (compile
        error, OOM on a new bucket size, a poison request) re-queues only
        its own group's requests — every other group still dispatches —
        and each request is automatically re-queued at most
        ``max_request_requeues`` times before being marked FAILED with
        the exception on its handle (``result()`` re-raises it), so a
        persistently-bad group can't re-poison every subsequent flush.
        Returns the number of successfully merged dispatches.
        """
        if not self._queue:
            return 0
        now = time.monotonic()
        live = []
        for req in self._queue:
            if (req.deadline_s is not None and req.submit_t is not None
                    and now - req.submit_t >= req.deadline_s):
                req.state = "DEADLINE_EXCEEDED"
                req.error = DeadlineExceeded(
                    f"request seq={req.seq} exceeded deadline_s="
                    f"{req.deadline_s} before dispatch "
                    f"({req.requeues} requeue(s))",
                    seq=req.seq, requeues=req.requeues,
                )
                self.stats["deadline_exceeded"] += 1
            else:
                live.append(req)
        self._queue = live
        groups: dict[tuple, list[PendingRequest]] = {}
        for req in self._queue:
            sig = (req.text_emb is not None,
                   tuple(req.text_emb.shape[1:])
                   if req.text_emb is not None else (),
                   req._membership[0] if req._membership is not None
                   else -1)
            groups.setdefault(sig, []).append(req)
        self._queue = []
        ok = 0
        for (has_text, text_tail, _epoch), reqs in groups.items():
            try:
                self._dispatch_group(has_text, text_tail, reqs)
                ok += 1
            except Exception as e:
                for r in reqs:
                    r.requeues += 1
                    if r.requeues > self.max_request_requeues:
                        r.state = "FAILED"
                        r.error = e
                        self.stats["failed_requests"] += 1
                    else:
                        self.stats["request_requeues"] += 1
                        self._queue.append(r)
        # Re-queues above appended in GROUP iteration order; restore the
        # global submission order so a partially-failed flush retries
        # requests deterministically FIFO (interleaved groups would
        # otherwise leapfrog earlier failed requests — regression-tested
        # in tests/test_continuous.py).
        self._queue.sort(key=lambda r: r.seq)
        if self.elastic:
            # DRAINING slots held for their in-flight snapshots are done
            # (dispatched or failed/re-queued with the snapshot intact).
            for i, h in enumerate(self.expert_health):
                if h == "DRAINING":
                    self.expert_health[i] = "EVICTED"
        return ok

    def _dispatch_group(
        self, has_text: bool, text_tail: tuple, reqs: list[PendingRequest],
    ) -> None:
        total = sum(r.batch_size for r in reqs)
        # Bucket the merged batch to the next power of two (and a
        # "data"-axis multiple on a sharded engine): varying request
        # mixes then land on O(log max_batch) compiled sizes instead
        # of one compile per distinct total, keeping the engine
        # retrace-free under real traffic.
        bucket = 1 << (total - 1).bit_length()
        if self.mesh is not None:
            nd = self.mesh.shape["data"]
            bucket += (-bucket) % nd
        pad = bucket - total
        noise = [
            jax.random.normal(
                r.key, (r.batch_size,) + self.latent_shape, jnp.float32
            )
            for r in reqs
        ]
        if pad:
            noise.append(jnp.zeros((pad,) + self.latent_shape, jnp.float32))
        noise = jnp.concatenate(noise, axis=0)
        if has_text:
            text = [jnp.asarray(r.text_emb) for r in reqs]
            if pad:
                text.append(jnp.zeros((pad,) + text_tail, text[0].dtype))
            text = jnp.concatenate(text, axis=0)
        else:
            text = jnp.zeros((0,), jnp.float32)             # static filler
        fn = self._get_compiled(total + pad, has_text)
        self._count_plan_refreshes()
        self._count_routed_rows(total + pad, has_text)
        out = self._run_compiled(fn, reqs[0].key, noise, text,
                                 membership=reqs[0]._membership)
        self.stats["merged_batches"] += 1
        self.stats["batched_requests"] += len(reqs)
        off = 0
        for r in reqs:
            r._result = out[off:off + r.batch_size]
            r.state = "DONE"
            r.done = True
            off += r.batch_size


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog="shards > 1 need that many visible devices — on a CPU host "
               "set XLA_FLAGS=--xla_force_host_platform_device_count=N "
               "before launching (as launch/dryrun.py does)."
    )
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--cfg-scale", type=float, default=7.5)
    ap.add_argument("--strategy", default="topk",
                    choices=("top1", "topk", "full", "threshold"))
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "routed", "dense", "reference"))
    ap.add_argument("--dispatch", default="auto",
                    choices=("auto", "gathered", "grouped", "ragged",
                             "dense"),
                    help="expert-dispatch executor backend "
                         "(core.dispatch): per-sample gather+vmap, "
                         "sort-based grouped segment execution, or the "
                         "one-kernel ragged grouped GEMM (pair-major, "
                         "zero bucket padding)")
    ap.add_argument("--param-dtype", default="native",
                    choices=("native", "fp32", "bf16", "int8", "fp8"),
                    help="stacked expert-param storage "
                         "(core.param_store): int8/fp8 quantize on load "
                         "with per-expert scales and dequantize routed "
                         "slices through the fused Pallas kernel "
                         "(~4x fewer resident expert-param bytes)")
    ap.add_argument("--plan-refresh", type=int, default=1,
                    help="recompute the router posterior + DispatchPlan "
                         "only every R-th Euler step, carrying the plan "
                         "through the scan in between (R=1 = per-step "
                         "routing, bit-identical to the classic path; "
                         "R>1 trades bounded drift for skipping the "
                         "router forward on the other steps)")
    ap.add_argument("--no-step-fuse", action="store_true",
                    help="disable the step-fused kernel (CFG combine + "
                         "Euler update folded into convert-and-fuse) and "
                         "run the unfused three-op chain instead")
    ap.add_argument("--cond-cache", type=int, default=64,
                    help="cross-request conditioning LRU capacity "
                         "(content-hash-keyed text-embedding reuse "
                         "across submit()/generate() calls; 0 disables)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--latent-size", type=int, default=8)
    ap.add_argument("--expert-shards", type=int, default=1)
    ap.add_argument("--data-shards", type=int, default=None)
    ap.add_argument("--coalesce", action="store_true",
                    help="drive requests through submit()/flush() instead "
                         "of per-request generate()")
    ap.add_argument("--continuous", action="store_true",
                    help="drive requests through the rolling "
                         "mixed-timestep scheduler (repro.serving): "
                         "requests join/leave the always-full batch at "
                         "step boundaries instead of lockstep flushing")
    ap.add_argument("--max-resident", type=int, default=8,
                    help="rolling-batch capacity per shape bucket "
                         "(continuous mode)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="scheduler queue-depth bound before submit() "
                         "raises QueueBackpressure (continuous mode)")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="continuous mode: submit one request every N "
                         "scheduler ticks (staggered open-loop arrivals)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline in seconds "
                         "(expired requests land in DEADLINE_EXCEEDED "
                         "and result() raises the named error)")
    ap.add_argument("--tick-budget", type=float, default=None,
                    help="continuous mode: wall-clock watchdog budget "
                         "per bucket launch; a slower tick fails only "
                         "that bucket with bounded-backoff retry")
    ap.add_argument("--journal-dir", default=None,
                    help="continuous mode: write the crash-recovery "
                         "request journal (submit/admit/tick/resolve "
                         "records + row-state snapshots) here; recover "
                         "with ServingEngine.restore(journal_dir)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="expert-slot capacity (>= checkpoint count): pads "
                         "the store with masked EMPTY slots and enables "
                         "elastic membership (hot add_expert/evict_expert "
                         "without recompiling)")
    ap.add_argument("--on-bad-checkpoint", default="raise",
                    choices=("raise", "skip"),
                    help="'skip' quarantines corrupt/truncated/mismatched "
                         "expert checkpoints and serves the rest in "
                         "degraded mode instead of refusing to start")
    ap.add_argument("--track-padding", action="store_true",
                    help="instrument the expert forwards with a runtime "
                         "row counter and print padded vs routed rows "
                         "per step after serving (grouped bucket-padding "
                         "tax; 0.0 under --dispatch ragged)")
    args = ap.parse_args()

    dit_cfg = dit_b2()
    rcfg = router_b2()
    if args.reduced:
        dit_cfg = dit_cfg.reduced(latent_size=args.latent_size)
        rcfg = rcfg.reduced(latent_size=args.latent_size)
    engine = ServingEngine.from_checkpoint_dir(
        args.ckpt_dir, dit_cfg=dit_cfg, router_cfg=rcfg,
        sampler=SamplerConfig(
            num_steps=args.steps, cfg_scale=args.cfg_scale,
            strategy=args.strategy, top_k=args.top_k,
            dispatch=args.dispatch, param_dtype=args.param_dtype,
            step_fused=not args.no_step_fuse,
            plan_refresh_every=args.plan_refresh,
        ),
        engine=args.engine,
        n_expert_shards=args.expert_shards, n_data_shards=args.data_shards,
        cond_cache_size=args.cond_cache,
        capacity=args.capacity,
        on_bad_checkpoint=args.on_bad_checkpoint,
        track_padding=args.track_padding,
    )
    print(f"loaded {len(engine.experts)} experts "
          f"({[e.objective for e in engine.experts]}) "
          f"homogeneous={engine.homogeneous} "
          f"mesh={dict(engine.mesh.shape) if engine.mesh else None}")
    if engine.elastic:
        print(engine.membership_line())
    if args.continuous:
        from repro.serving import (
            ContinuousScheduler, ResiliencePolicy, ResilientScheduler,
        )

        resilient = (args.deadline_s is not None
                     or args.tick_budget is not None
                     or args.journal_dir is not None)
        if resilient:
            sched = ResilientScheduler(
                engine, max_resident=args.max_resident,
                max_queue_depth=args.max_queue,
                policy=ResiliencePolicy(tick_budget_s=args.tick_budget),
                journal_dir=args.journal_dir,
            )
        else:
            sched = ContinuousScheduler(
                engine, max_resident=args.max_resident,
                max_queue_depth=args.max_queue,
            )
        t0 = time.time()
        handles = []
        for r in range(args.requests):
            key = jax.random.PRNGKey(r)
            text = np.asarray(jax.random.normal(
                key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
            ))
            if resilient:
                handles.append(
                    sched.submit(key, text, deadline_s=args.deadline_s)
                )
            else:
                handles.append(sched.submit(key, text))
            for _ in range(max(args.arrival_every, 0)):
                sched.step()
        sched.run_until_idle()
        outs = [jax.block_until_ready(h.result()) for h in handles]
        dt = time.time() - t0
        n = sum(o.shape[0] for o in outs)
        print(f"continuous {len(handles)} requests in "
              f"{sched.step_count} ticks: {n} imgs in {dt:.2f}s "
              f"({n / dt:.1f} img/s) traces={engine.stats['traces']}")
        print(sched.line())
        if engine.elastic:
            print(engine.membership_line())
        return
    if args.coalesce:
        t0 = time.time()
        handles = []
        for r in range(args.requests):
            key = jax.random.PRNGKey(r)
            # host-side ndarray, as a remote text encoder would deliver —
            # the form the conditioning cache hashes and dedupes
            text = np.asarray(jax.random.normal(
                key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
            ))
            handles.append(engine.submit(key, text))
        engine.flush()
        outs = [jax.block_until_ready(h.result()) for h in handles]
        dt = time.time() - t0
        n = sum(o.shape[0] for o in outs)
        print(f"coalesced {len(handles)} requests -> "
              f"{engine.stats['merged_batches']} dispatch(es): "
              f"{n} imgs in {dt:.2f}s ({n / dt:.1f} img/s) "
              f"traces={engine.stats['traces']}")
        print(f"cache: cond_hits={engine.stats['cond_cache_hits']} "
              f"cond_misses={engine.stats['cond_cache_misses']} "
              f"plan_refreshes={engine.stats['plan_refreshes']} "
              f"(R={args.plan_refresh}, {args.steps} steps/dispatch)")
        if engine.elastic:
            print(engine.membership_line())
        return
    for r in range(args.requests):
        key = jax.random.PRNGKey(r)
        t0 = time.time()
        # host-side ndarray, as a remote text encoder would deliver
        text = np.asarray(jax.random.normal(
            key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
        ))
        out = engine.generate(key, text, args.batch)
        out = jax.block_until_ready(out)
        dt = time.time() - t0
        print(f"request {r}: {out.shape} in {dt:.2f}s "
              f"({args.batch / dt:.1f} img/s) "
              f"traces={engine.stats['traces']} "
              f"finite={bool(np.isfinite(np.asarray(out)).all())}")
    print(f"cache: cond_hits={engine.stats['cond_cache_hits']} "
          f"cond_misses={engine.stats['cond_cache_misses']} "
          f"plan_refreshes={engine.stats['plan_refreshes']} "
          f"(R={args.plan_refresh}, {args.steps} steps/request)")
    if args.track_padding:
        ps = engine.padding_stats()
        print(f"padding: padded_rows/step={ps['padded_rows_per_step']:.2f} "
              f"routed_rows/step={ps['routed_rows_per_step']:.2f} "
              f"overhead={ps['padding_overhead']:.3f}")
    if engine.elastic:
        print(engine.membership_line())


if __name__ == "__main__":
    main()
