"""Serving engine for heterogeneous decentralized diffusion.

Loads a directory of self-describing expert checkpoints (each carries its
objective / schedule / cluster metadata — §5 limitation iv) plus a router
checkpoint, and serves batched text-to-image requests with the paper's
Fig. 2 pipeline: router posterior → Top-K expert selection → native expert
predictions → schedule-aware ε→v conversion → fused velocity → Euler step.

Also exposes ``ServingEngine`` programmatically (used by examples/ and the
benchmark harness).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ConversionConfig,
    ExpertSpec,
    SamplerConfig,
    sample_ensemble,
)
from repro.models import dit as D
from repro.models.config import DiTConfig, dit_b2, router_b2
from repro.training import load_checkpoint


@dataclasses.dataclass
class ServingEngine:
    experts: list[ExpertSpec]
    expert_params: list
    router_fn: object | None
    latent_shape: tuple[int, int, int]
    sampler: SamplerConfig = SamplerConfig()

    @classmethod
    def from_checkpoint_dir(
        cls, ckpt_dir: str, *, dit_cfg: DiTConfig,
        router_cfg: DiTConfig | None = None,
        sampler: SamplerConfig = SamplerConfig(),
    ) -> "ServingEngine":
        experts, params = [], []
        apply_fn = D.make_expert_apply(dit_cfg)
        for path in sorted(glob.glob(os.path.join(ckpt_dir, "expert*.npz"))):
            p, meta = load_checkpoint(path)
            experts.append(ExpertSpec(
                name=meta.get("name", os.path.basename(path)),
                objective=meta["objective"],
                schedule=meta["schedule"],
                apply_fn=apply_fn,
                cluster_id=int(meta.get("cluster_id", -1)),
            ))
            params.append(p)
        if not experts:
            raise FileNotFoundError(f"no expert*.npz under {ckpt_dir}")
        router_fn = None
        router_path = os.path.join(ckpt_dir, "router.npz")
        if router_cfg is not None and os.path.exists(router_path):
            rp, _ = load_checkpoint(router_path)
            router_fn = D.make_router_fn(router_cfg, rp)
        return cls(
            experts=experts, expert_params=params, router_fn=router_fn,
            latent_shape=(dit_cfg.latent_size, dit_cfg.latent_size,
                          dit_cfg.latent_channels),
            sampler=sampler,
        )

    def generate(
        self, key, batch_text_emb: jnp.ndarray | None, batch_size: int,
        *, null_text_emb: jnp.ndarray | None = None,
    ) -> jnp.ndarray:
        cond = {"text_emb": batch_text_emb} if batch_text_emb is not None \
            else None
        null = {"text_emb": None}
        return sample_ensemble(
            key, self.experts, self.expert_params, self.router_fn,
            (batch_size,) + self.latent_shape,
            cond=cond, null_cond=null if batch_text_emb is not None else None,
            config=self.sampler,
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--cfg-scale", type=float, default=7.5)
    ap.add_argument("--strategy", default="topk",
                    choices=("top1", "topk", "full", "threshold"))
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--latent-size", type=int, default=8)
    args = ap.parse_args()

    dit_cfg = dit_b2()
    rcfg = router_b2()
    if args.reduced:
        dit_cfg = dit_cfg.reduced(latent_size=args.latent_size)
        rcfg = rcfg.reduced(latent_size=args.latent_size)
    engine = ServingEngine.from_checkpoint_dir(
        args.ckpt_dir, dit_cfg=dit_cfg, router_cfg=rcfg,
        sampler=SamplerConfig(
            num_steps=args.steps, cfg_scale=args.cfg_scale,
            strategy=args.strategy, top_k=args.top_k,
        ),
    )
    print(f"loaded {len(engine.experts)} experts "
          f"({[e.objective for e in engine.experts]})")
    for r in range(args.requests):
        key = jax.random.PRNGKey(r)
        t0 = time.time()
        text = jax.random.normal(
            key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
        )
        out = engine.generate(key, text, args.batch)
        dt = time.time() - t0
        print(f"request {r}: {out.shape} in {dt:.2f}s "
              f"({args.batch / dt:.1f} img/s) "
              f"finite={bool(np.isfinite(np.asarray(out)).all())}")


if __name__ == "__main__":
    main()
