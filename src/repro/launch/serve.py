"""Serving engine for heterogeneous decentralized diffusion.

Loads a directory of self-describing expert checkpoints (each carries its
objective / schedule / cluster metadata — §5 limitation iv) plus a router
checkpoint, and serves batched text-to-image requests with the paper's
Fig. 2 pipeline on the compute-sparse hot path: router posterior → Top-K
expert selection → **routed-expert-only** native predictions (stacked
params + gather dispatch; CFG batched along the batch axis) → one fused
schedule-aware ε→v-and-combine kernel per Euler step.

Serving properties:

* **compute-sparse** — only the routed experts run each step (k forwards
  instead of K; 1 forward with batched CFG instead of 2), matching the
  paper's claim that Top-K routing pays single-model cost at ensemble
  quality.  Heterogeneous-architecture expert sets fall back to the dense
  fused path automatically.
* **retrace-free** — ``ServingEngine`` caches a jitted sampling function
  per (batch size, latent shape, sampler config, conditioning signature)
  with the noise buffer donated, so repeated requests with the same shape
  never recompile; ``engine.stats['traces']`` exposes the compile count.

Also exposes ``ServingEngine`` programmatically (used by examples/ and the
benchmark harness).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ConversionConfig,
    ExpertSpec,
    SamplerConfig,
    params_are_stackable,
    sample_ensemble,
)
from repro.models import dit as D
from repro.models.config import DiTConfig, dit_b2, router_b2
from repro.training import load_checkpoint


@dataclasses.dataclass
class ServingEngine:
    experts: list[ExpertSpec]
    expert_params: list
    router_fn: object | None
    latent_shape: tuple[int, int, int]
    sampler: SamplerConfig = SamplerConfig()
    #: 'auto' | 'routed' | 'dense' | 'reference' (see core.sample_ensemble)
    engine: str = "auto"

    def __post_init__(self) -> None:
        self._compiled: dict = {}
        self.stats = {"traces": 0, "requests": 0}
        self.homogeneous = len(self.experts) <= 1 or (
            all(e.apply_fn is self.experts[0].apply_fn for e in self.experts)
            and params_are_stackable(self.expert_params)
        )
        # Stacked single-pytree expert params: the routed engine's dispatch
        # substrate (kept alongside the per-expert list for the fallback).
        self.stacked_params = (
            D.stack_expert_params(self.expert_params)
            if self.homogeneous and self.expert_params else None
        )

    @classmethod
    def from_checkpoint_dir(
        cls, ckpt_dir: str, *, dit_cfg: DiTConfig,
        router_cfg: DiTConfig | None = None,
        sampler: SamplerConfig = SamplerConfig(),
        engine: str = "auto",
    ) -> "ServingEngine":
        experts, params = [], []
        apply_fn = D.make_expert_apply(dit_cfg)
        for path in sorted(glob.glob(os.path.join(ckpt_dir, "expert*.npz"))):
            p, meta = load_checkpoint(path)
            experts.append(ExpertSpec(
                name=meta.get("name", os.path.basename(path)),
                objective=meta["objective"],
                schedule=meta["schedule"],
                apply_fn=apply_fn,
                cluster_id=int(meta.get("cluster_id", -1)),
            ))
            params.append(p)
        if not experts:
            raise FileNotFoundError(f"no expert*.npz under {ckpt_dir}")
        router_fn = None
        router_path = os.path.join(ckpt_dir, "router.npz")
        if router_cfg is not None and os.path.exists(router_path):
            rp, _ = load_checkpoint(router_path)
            router_fn = D.make_router_fn(router_cfg, rp)
        return cls(
            experts=experts, expert_params=params, router_fn=router_fn,
            latent_shape=(dit_cfg.latent_size, dit_cfg.latent_size,
                          dit_cfg.latent_channels),
            sampler=sampler, engine=engine,
        )

    # -- retrace-free compiled-sampler cache --------------------------------

    def _get_compiled(self, batch_size: int, has_text: bool) -> Callable:
        """Jitted sampler keyed by everything that changes the trace.

        The initial-noise buffer is donated — XLA reuses it for the
        evolving latent state instead of allocating a fresh buffer per
        request.
        """
        cache_key = (batch_size, self.latent_shape, self.sampler,
                     self.engine, has_text)
        fn = self._compiled.get(cache_key)
        if fn is None:
            shape = (batch_size,) + self.latent_shape

            def _sample(key, noise, text_emb):
                self.stats["traces"] += 1      # runs at trace time only
                cond = {"text_emb": text_emb} if has_text else None
                null = {"text_emb": None} if has_text else None
                return sample_ensemble(
                    key, self.experts, self.expert_params, self.router_fn,
                    shape, cond=cond, null_cond=null, config=self.sampler,
                    engine=self.engine, init_noise=noise,
                    stacked_params=self.stacked_params,
                )

            # donation is a no-op (with a warning) on CPU; only request it
            # where XLA can actually alias the buffer.
            donate = () if jax.default_backend() == "cpu" else (1,)
            fn = jax.jit(_sample, donate_argnums=donate)
            self._compiled[cache_key] = fn
        return fn

    def generate(
        self, key, batch_text_emb: jnp.ndarray | None, batch_size: int,
        *, null_text_emb: jnp.ndarray | None = None,
    ) -> jnp.ndarray:
        self.stats["requests"] += 1
        has_text = batch_text_emb is not None
        fn = self._get_compiled(batch_size, has_text)
        noise = jax.random.normal(
            key, (batch_size,) + self.latent_shape, dtype=jnp.float32
        )
        if not has_text:
            batch_text_emb = jnp.zeros((0,), jnp.float32)   # static filler
        return fn(key, noise, batch_text_emb)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--cfg-scale", type=float, default=7.5)
    ap.add_argument("--strategy", default="topk",
                    choices=("top1", "topk", "full", "threshold"))
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "routed", "dense", "reference"))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--latent-size", type=int, default=8)
    args = ap.parse_args()

    dit_cfg = dit_b2()
    rcfg = router_b2()
    if args.reduced:
        dit_cfg = dit_cfg.reduced(latent_size=args.latent_size)
        rcfg = rcfg.reduced(latent_size=args.latent_size)
    engine = ServingEngine.from_checkpoint_dir(
        args.ckpt_dir, dit_cfg=dit_cfg, router_cfg=rcfg,
        sampler=SamplerConfig(
            num_steps=args.steps, cfg_scale=args.cfg_scale,
            strategy=args.strategy, top_k=args.top_k,
        ),
        engine=args.engine,
    )
    print(f"loaded {len(engine.experts)} experts "
          f"({[e.objective for e in engine.experts]}) "
          f"homogeneous={engine.homogeneous}")
    for r in range(args.requests):
        key = jax.random.PRNGKey(r)
        t0 = time.time()
        text = jax.random.normal(
            key, (args.batch, dit_cfg.text_len, dit_cfg.text_dim)
        )
        out = engine.generate(key, text, args.batch)
        out = jax.block_until_ready(out)
        dt = time.time() - t0
        print(f"request {r}: {out.shape} in {dt:.2f}s "
              f"({args.batch / dt:.1f} img/s) "
              f"traces={engine.stats['traces']} "
              f"finite={bool(np.isfinite(np.asarray(out)).all())}")


if __name__ == "__main__":
    main()
