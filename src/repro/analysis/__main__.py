"""``python -m repro.analysis`` — the lint + contract CLI (the CI gate).

Exit status: 0 when clean (or every finding is baselined), 1 when any
finding survives, 2 on usage errors.

Examples::

    python -m repro.analysis --check src/          # lint + contracts
    python -m repro.analysis --explain JX101       # rule documentation
    python -m repro.analysis --list-rules
    python -m repro.analysis --check src/ --baseline   # adopt findings
    python -m repro.analysis --check src/ --report lint-report.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.astlint import (
    Finding,
    apply_baseline,
    lint_paths,
    load_baseline,
    write_baseline,
)
from repro.analysis.kernel_contracts import check_kernel_contracts
from repro.analysis.rules import default_rules, find_rule, rule_classes


def _find_kernels_dir(paths: list[str]) -> str | None:
    """Locate the kernels package under the checked paths (the directory
    holding ``ref.py`` next to kernel modules)."""
    for path in paths:
        if os.path.isfile(path):
            path = os.path.dirname(path)
        for root, dirs, files in os.walk(path):
            dirs[:] = [d for d in dirs
                       if not d.startswith(".") and d != "__pycache__"]
            if os.path.basename(root) == "kernels" and "ref.py" in files:
                return root
    return None


def _find_tests_dir(paths: list[str]) -> str | None:
    """tests/ sibling of the checked tree (for KC204 coverage checks)."""
    for path in paths:
        cur = os.path.abspath(path)
        if os.path.isfile(cur):
            cur = os.path.dirname(cur)
        for _ in range(4):
            cand = os.path.join(cur, "tests")
            if os.path.isdir(cand):
                return cand
            cur = os.path.dirname(cur)
    return None


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas hazard linter + kernel-contract checker",
    )
    ap.add_argument("--check", nargs="+", metavar="PATH",
                    help="lint these files/directories")
    ap.add_argument("--explain", metavar="RULE",
                    help="print one rule's documentation (id or slug)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list every rule id, slug, and title")
    ap.add_argument("--baseline", action="store_true",
                    help="with --check: write current findings to the "
                         "baseline file instead of failing on them")
    ap.add_argument("--baseline-file", default=".analysis-baseline.json",
                    help="baseline fingerprint file "
                         "(default: %(default)s)")
    ap.add_argument("--report", metavar="FILE",
                    help="also write findings as JSON (CI artifact)")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the kernel-contract checks (AST lint only)")
    args = ap.parse_args(argv)

    if args.explain:
        cls = find_rule(args.explain)
        if cls is None:
            known = ", ".join(c.id for c in rule_classes())
            print(f"unknown rule {args.explain!r}; known: {known}",
                  file=sys.stderr)
            return 2
        print(cls.explain())
        return 0

    if args.list_rules:
        for cls in rule_classes():
            print(f"{cls.id:7s} [{cls.slug}] {cls.title}")
        return 0

    if not args.check:
        ap.print_usage(sys.stderr)
        print("error: one of --check/--explain/--list-rules is required",
              file=sys.stderr)
        return 2

    findings: list[Finding] = lint_paths(args.check, default_rules())
    if not args.no_contracts:
        kernels_dir = _find_kernels_dir(args.check)
        if kernels_dir is not None:
            findings.extend(check_kernel_contracts(
                kernels_dir, tests_dir=_find_tests_dir(args.check)))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    if args.baseline:
        n = write_baseline(findings, args.baseline_file)
        print(f"baseline: {n} fingerprint(s) -> {args.baseline_file}")
        return 0

    baseline = load_baseline(args.baseline_file)
    fresh = apply_baseline(findings, baseline)

    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump({
                "checked": args.check,
                "findings": [f.as_dict() for f in fresh],
                "baselined": len(findings) - len(fresh),
            }, fh, indent=2)
            fh.write("\n")

    for f in fresh:
        print(f.format())
    n_base = len(findings) - len(fresh)
    tail = f" ({n_base} baselined)" if n_base else ""
    if fresh:
        print(f"\n{len(fresh)} finding(s){tail} — "
              f"`python -m repro.analysis --explain <RULE>` for details")
        return 1
    print(f"clean: 0 findings{tail}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
