"""AST lint engine for repo-specific JAX/Pallas hazard rules.

Six PRs of hot-path work made correctness depend on invariants no
compiler enforces: jit cache keys must stay hashable, traced values must
never hit Python control flow or host syncs, pytree dataclasses must be
registered before entering ``lax.scan`` carries, and every Pallas kernel
must keep a signature-matched oracle.  This module is the enforcement
layer: a small, dependency-free engine that parses each source file once
and runs a registry of :class:`Rule` objects over it.

Design notes
------------
* **Pure AST** — nothing is imported or executed; the linter is safe to
  run on a broken tree and costs milliseconds in CI.
* **Traced-reachability** (:class:`TracedAnalysis`) — rules that only
  make sense under a ``jax.jit``/``lax.scan`` trace (host syncs, Python
  branches on tracers) fire only inside functions that are statically
  reachable from a trace entry point *within the module*: functions
  decorated with ``jax.jit``, functions passed (directly or through
  ``functools.partial``/local aliases) to ``jit``/``scan``/``cond``/
  ``while_loop``/``switch``/``pallas_call``/``vmap``/…, functions they
  transitively call by name, and functions nested inside any of those.
  Cross-module reachability is intentionally out of scope: each module
  is analyzed standalone, so moving a helper never silently changes
  another file's lint results.
* **Pragmas** — every finding can be suppressed at the line that raised
  it (or a pure-comment line directly above) with
  ``# lint: allow-<slug>`` (e.g. ``# lint: allow-host-sync``),
  ``# lint: allow-<RULE-ID>``, or ``# lint: disable`` (all rules).
  ``# lint: skip-file`` in the first ten lines skips the whole file.
  An intentional host sync at an explicit device→host boundary is
  *supposed* to carry the pragma — it documents the sync for reviewers.
* **Baselines** — ``write_baseline``/``load_baseline`` store content
  fingerprints (rule id + file basename + stripped source line), so a
  baseline survives unrelated edits and line renumbering but expires
  when the offending line itself changes.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
from typing import Iterable, Iterator

#: modules whose attribute chains mark an expression as "device-valued":
#: ``float(jnp.mean(x))`` forces a blocking device→host transfer.
JAX_ROOTS = frozenset({"jnp", "jax", "lax", "pl", "pltpu"})

#: call tails that wrap a function into a traced context.
TRACE_WRAPPERS = frozenset({
    "jit", "pallas_call", "vmap", "pmap", "grad", "value_and_grad",
    "checkpoint", "remat", "shard_map", "eval_shape", "make_jaxpr",
})

#: structured-control-flow HOFs whose callables run under the trace.
TRACE_HOFS = frozenset({
    "scan", "cond", "while_loop", "switch", "fori_loop",
    "associative_scan", "map", "custom_root",
})

_PRAGMA_RE = re.compile(r"#\s*lint:\s*([a-zA-Z][\w,-]*)")


# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str          # rule id, e.g. "JX102"
    slug: str          # pragma name, e.g. "host-sync"
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""

    def format(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col}"
        out = f"{loc}: {self.rule} [{self.slug}] {self.message}"
        if self.snippet:
            out += f"\n    {self.snippet.strip()}"
        return out

    def fingerprint(self) -> str:
        """Content fingerprint for baselines: stable under line moves,
        invalidated when the offending line's text changes."""
        key = f"{self.rule}|{os.path.basename(self.path)}|" \
              f"{self.snippet.strip()}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self) | {"fingerprint": self.fingerprint()}


# ---------------------------------------------------------------------------
# Expression helpers shared by rules
# ---------------------------------------------------------------------------


def attr_root(node: ast.AST) -> str | None:
    """Leftmost name of an attribute chain: ``jnp.exp(x).T`` → ``jnp``."""
    while isinstance(node, (ast.Attribute, ast.Call, ast.Subscript)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def call_tail(call: ast.Call) -> str | None:
    """Rightmost name of a call's callee: ``jax.lax.scan(...)`` → ``scan``."""
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def dotted_name(node: ast.AST) -> str | None:
    """``np.ndarray`` → ``"np.ndarray"``; bare names pass through."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_jax_rooted(expr: ast.AST) -> bool:
    """True if the expression contains an attribute chain rooted at a jax
    namespace — the static proxy for "this value lives on device"."""
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and attr_root(n) in JAX_ROOTS:
            return True
    return False


def referenced_names(node: ast.AST) -> set[str]:
    """Bare names + attribute tails referenced anywhere inside ``node``
    (used to seed traced-reachability conservatively)."""
    out: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


# ---------------------------------------------------------------------------
# Traced-reachability analysis
# ---------------------------------------------------------------------------

_FnDef = (ast.FunctionDef, ast.AsyncFunctionDef)


class TracedAnalysis:
    """Which functions of a module execute under a JAX trace?

    Name-level and conservative: seeds are decorator matches and names
    referenced inside trace-entry calls (expanded through simple local
    aliases like ``kernel = functools.partial(_ssd_kernel, ...)``), then
    reachability propagates through same-module calls-by-name and into
    nested function definitions.
    """

    def __init__(self, tree: ast.Module) -> None:
        self._defs: dict[str, list[ast.AST]] = {}
        self._parent: dict[ast.AST, ast.AST | None] = {}
        self._calls: dict[ast.AST, set[str]] = {}
        self._aliases: dict[str, set[str]] = {}
        seeds: set[str] = set()
        decorated: set[ast.AST] = set()

        stack: list[ast.AST] = []

        def visit(node: ast.AST) -> None:
            if isinstance(node, _FnDef):
                self._defs.setdefault(node.name, []).append(node)
                self._parent[node] = stack[-1] if stack else None
                self._calls[node] = set()
                for dec in node.decorator_list:
                    names = referenced_names(dec)
                    if names & (TRACE_WRAPPERS | TRACE_HOFS):
                        decorated.add(node)
                stack.append(node)
                for child in ast.iter_child_nodes(node):
                    visit(child)
                stack.pop()
                return
            if isinstance(node, ast.Call):
                tail = call_tail(node)
                if stack and tail is not None:
                    self._calls[stack[-1]].add(tail)
                if tail in TRACE_WRAPPERS or tail in TRACE_HOFS:
                    for arg in list(node.args) + [
                        kw.value for kw in node.keywords
                    ]:
                        seeds.update(referenced_names(arg))
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self._aliases.setdefault(
                    node.targets[0].id, set()
                ).update(referenced_names(node.value))
            for child in ast.iter_child_nodes(node):
                visit(child)

        visit(tree)

        # Expand seeds through assignment aliases to a fixpoint:
        # pallas_call(kernel) + kernel = partial(_ssd_kernel) → _ssd_kernel.
        changed = True
        while changed:
            changed = False
            for name in list(seeds):
                extra = self._aliases.get(name, set()) - seeds
                if extra:
                    seeds |= extra
                    changed = True

        # Traced fixpoint over the call graph + nesting.
        traced: set[ast.AST] = set(decorated)
        traced |= {
            fn for name in seeds for fn in self._defs.get(name, [])
        }
        changed = True
        while changed:
            changed = False
            for fn, calls in self._calls.items():
                if fn in traced:
                    for name in calls:
                        for callee in self._defs.get(name, []):
                            if callee not in traced:
                                traced.add(callee)
                                changed = True
                elif self._parent.get(fn) in traced:
                    traced.add(fn)
                    changed = True
        self.traced = traced

    def is_traced(self, fn: ast.AST) -> bool:
        return fn in self.traced


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


class Rule:
    """One hazard class.  Subclasses set the metadata class attributes
    and implement :meth:`check`."""

    id: str = "JX000"
    slug: str = "generic"
    title: str = ""
    hazard: str = ""
    bad: str = ""
    good: str = ""

    def check(self, ctx: "ModuleContext") -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "ModuleContext", node: ast.AST,
                message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        snippet = ctx.lines[line - 1] if 0 < line <= len(ctx.lines) else ""
        return Finding(
            rule=self.id, slug=self.slug, path=ctx.path, line=line,
            col=getattr(node, "col_offset", 0), message=message,
            snippet=snippet,
        )

    @classmethod
    def explain(cls) -> str:
        parts = [f"{cls.id} [{cls.slug}] — {cls.title}", "", cls.hazard]
        if cls.bad:
            parts += ["", "Bad:", "    " + cls.bad.replace("\n", "\n    ")]
        if cls.good:
            parts += ["", "Good:", "    " + cls.good.replace("\n", "\n    ")]
        parts += ["", f"Suppress with: # lint: allow-{cls.slug}"]
        return "\n".join(parts)


class ModuleContext:
    """Everything a rule needs about one parsed source file."""

    def __init__(self, path: str, src: str, tree: ast.Module) -> None:
        self.path = path
        self.src = src
        self.lines = src.splitlines()
        self.tree = tree
        self.traced = TracedAnalysis(tree)
        # parent links for enclosing-function lookups
        self._parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node

    def enclosing_function(self, node: ast.AST) -> ast.AST | None:
        cur = self._parents.get(node)
        while cur is not None and not isinstance(cur, _FnDef):
            cur = self._parents.get(cur)
        return cur

    def in_traced(self, node: ast.AST) -> bool:
        fn = node if isinstance(node, _FnDef) \
            else self.enclosing_function(node)
        return fn is not None and self.traced.is_traced(fn)

    def traced_functions(self) -> list[ast.AST]:
        return [fn for fn in self.traced.traced]


# ---------------------------------------------------------------------------
# Pragma suppression
# ---------------------------------------------------------------------------


def _pragmas_on(line_text: str) -> set[str]:
    out: set[str] = set()
    for m in _PRAGMA_RE.finditer(line_text):
        tok = m.group(1)
        if tok in ("disable", "skip-file"):
            out.add(tok)
        elif tok.startswith("allow-"):
            out.update(t.strip() for t in tok[len("allow-"):].split(","))
    return out


def file_skipped(src: str) -> bool:
    head = src.splitlines()[:10]
    return any("skip-file" in _pragmas_on(ln) for ln in head)


def suppressed(finding: Finding, lines: list[str]) -> bool:
    """A finding is suppressed by a pragma on its own line or on a
    pure-comment line directly above it."""
    cand: list[str] = []
    if 0 < finding.line <= len(lines):
        cand.append(lines[finding.line - 1])
        if finding.line >= 2 and lines[finding.line - 2].lstrip().startswith("#"):
            cand.append(lines[finding.line - 2])
    for text in cand:
        tokens = _pragmas_on(text)
        if "disable" in tokens or finding.slug in tokens \
                or finding.rule in tokens:
            return True
    return False


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(
                d for d in dirs
                if not d.startswith(".") and d != "__pycache__"
            )
            for name in sorted(files):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def lint_source(path: str, src: str, rules: Iterable[Rule]) -> list[Finding]:
    """Run ``rules`` over one in-memory source file (pragmas applied)."""
    if file_skipped(src):
        return []
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(
            rule="JX000", slug="parse", path=path, line=e.lineno or 1,
            col=e.offset or 0, message=f"syntax error: {e.msg}",
        )]
    ctx = ModuleContext(path, src, tree)
    lines = ctx.lines
    out: list[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            if not suppressed(f, lines):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def lint_paths(paths: Iterable[str], rules: Iterable[Rule]) -> list[Finding]:
    rules = list(rules)
    out: list[Finding] = []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        out.extend(lint_source(path, src, rules))
    return out


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def write_baseline(findings: Iterable[Finding], path: str) -> int:
    fps = sorted({f.fingerprint() for f in findings})
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "fingerprints": fps}, fh, indent=2)
        fh.write("\n")
    return len(fps)


def load_baseline(path: str) -> set[str]:
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return set(data.get("fingerprints", ()))


def apply_baseline(findings: Iterable[Finding],
                   baseline: set[str]) -> list[Finding]:
    return [f for f in findings if f.fingerprint() not in baseline]
