"""Runtime sanitizer for ``ServingEngine`` (RT301–RT304).

The static rules catch hazards the AST can prove; these serving
invariants only manifest at runtime and get a cheap wrapper instead:

RT301  **trace budget** — the engine promises retrace-free serving
       (compiled-sampler cache; elastic membership passes the store /
       coefficient tables / cluster map as jit *arguments*).  A
       regression here is silent: everything still returns the right
       numbers, just recompiling per request.  The sanitizer watches
       ``engine.stats['traces']`` and raises when a checked operation
       (or the whole wrapped lifetime) exceeds its budget — membership
       ops (``add_expert``/``evict_expert``/…) get a hard budget of 0.
RT302  **numerical hazard** — NaN/Inf escaping the fused kernel outputs
       corrupts one expert's slot without failing any test; the wrapper
       blocks on each checked result and raises naming the operation.
RT303  **sharding mismatch** — store leaves must actually lie on the
       placements ``launch.sharding.expert_param_shardings`` derives
       from the store's declared logical axes; a silently-replicated
       leaf costs the whole memory saving of expert placement.
RT304  **scheduler starvation** — the continuous scheduler
       (``repro.serving``) promises FIFO admission with per-bucket
       head-of-line blocking; a policy regression leaves the queue head
       waiting unboundedly while throughput still looks healthy.
       ``check_scheduler_liveness`` (or
       ``EngineSanitizer.check_scheduler``) bounds the oldest queued
       request's wait in scheduler ticks.

Use as a drop-in wrapper in tests/benches/examples::

    eng = EngineSanitizer(engine, trace_budget=1)
    out = eng.generate(key, text, batch)      # checked
    with assert_no_retrace(engine):
        engine.add_expert(path)               # membership must not trace
"""

from __future__ import annotations

import contextlib
from typing import Iterator

import jax
import numpy as np

from repro.analysis.astlint import Rule


class SanitizerError(RuntimeError):
    """Base class for runtime sanitizer violations."""


class TraceBudgetExceeded(SanitizerError):
    rule = "RT301"


class NumericalHazard(SanitizerError):
    rule = "RT302"


class ShardingMismatch(SanitizerError):
    rule = "RT303"


class StarvationHazard(SanitizerError):
    rule = "RT304"


# --- rule metadata (for `python -m repro.analysis --explain RT30x`) ---------


class TraceBudgetRule(Rule):
    id = "RT301"
    slug = "trace-budget"
    title = "ServingEngine retraced past its budget"
    hazard = (
        "The engine caches one compiled sampler per (batch, latent "
        "shape, sampler config, conditioning signature); elastic "
        "membership changes arrive as jit-argument VALUES.  Any code "
        "path that bakes membership (or an unhashable config) into the "
        "trace recompiles per request — numerically correct, "
        "catastrophically slow, and invisible to assert-based tests.  "
        "EngineSanitizer(engine, trace_budget=N) raises "
        "TraceBudgetExceeded the moment stats['traces'] passes N, and "
        "assert_no_retrace(engine) pins membership ops to zero traces."
    )
    bad = "engine.add_expert(p)   # retraces: membership closed over"
    good = ("with assert_no_retrace(engine):\n"
            "    engine.add_expert(p)   # store arrives as an argument")


class NumericalHazardRule(Rule):
    id = "RT302"
    slug = "numerical-hazard"
    title = "NaN/Inf escaped a checked engine output"
    hazard = (
        "One contributor checkpoint with a bad leaf (or a dequant-scale "
        "regression) poisons only the samples routed through its slot — "
        "aggregate tests keep passing while a fraction of served images "
        "are garbage.  The sanitizer blocks on each checked result and "
        "raises NumericalHazard naming the operation that produced the "
        "non-finite values."
    )
    bad = "out = engine.generate(key, text, 8)   # silently NaN"
    good = "out = EngineSanitizer(engine).generate(key, text, 8)"


class ShardingMismatchRule(Rule):
    id = "RT303"
    slug = "sharding-mismatch"
    title = "store leaf placement drifted from its declared logical axes"
    hazard = (
        "expert_param_shardings maps the store's logical axes "
        "('expert' on the leading K dim) to mesh placements.  If a "
        "membership update or a load path re-places a leaf with a "
        "different spec (e.g. fully replicated), GSPMD still computes "
        "correct results — while quietly holding K/n_shards times the "
        "intended bytes per device.  check_store_sharding compares every "
        "leaf's actual sharding spec against the declared one."
    )
    bad = "store = jax.device_put(store, NamedSharding(mesh, P()))"
    good = ("store = jax.device_put(store, expert_param_shardings(\n"
            "    store, mesh, logical_axes=store.logical_axes()))")


class SchedulerLivenessRule(Rule):
    id = "RT304"
    slug = "scheduler-starvation"
    title = "continuous scheduler starved a queued request"
    hazard = (
        "The rolling scheduler admits FIFO with per-bucket head-of-line "
        "blocking; a policy regression (skipping the queue head, a "
        "bucket that never frees rows, a request wider than any bucket "
        "slipping past submit-time rejection) leaves requests QUEUED "
        "forever while throughput metrics still look healthy.  "
        "check_scheduler_liveness bounds the oldest queued request's "
        "wait: with max_resident >= the widest queued request, the head "
        "must admit within about num_steps ticks (one full drain of the "
        "batch it is waiting on), so a wait past the bound is a "
        "liveness bug, not load."
    )
    bad = "while True: sched.step()   # head waits unboundedly, unnoticed"
    good = ("EngineSanitizer(engine, starvation_bound=2 * S)"
            ".check_scheduler(sched)   # raises StarvationHazard")


SANITIZER_RULES: list[type[Rule]] = [
    TraceBudgetRule, NumericalHazardRule, ShardingMismatchRule,
    SchedulerLivenessRule,
]


# --- trace budget ----------------------------------------------------------


@contextlib.contextmanager
def assert_no_retrace(engine, budget: int = 0) -> Iterator[None]:
    """Fail if the wrapped block compiles more than ``budget`` traces.

    Membership operations and repeat same-shape requests promise zero;
    a first-contact request legitimately compiles once (budget=1).
    """
    before = engine.stats["traces"]
    yield
    traced = engine.stats["traces"] - before
    if traced > budget:
        raise TraceBudgetExceeded(
            f"RT301: {traced} trace(s) inside a block budgeted for "
            f"{budget} — the compiled-sampler cache was bypassed "
            f"(unhashable cache key, membership closed over, or a "
            f"shape/config drifting per call)"
        )


# --- numerics --------------------------------------------------------------


def nonfinite_leaves(tree, prefix: str = "out") -> list[str]:
    """Paths of floating leaves containing NaN/Inf (blocks on device)."""
    bad: list[str] = []
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    for path, leaf in leaves:
        arr = np.asarray(leaf)  # lint: allow-host-sync — sanitizer boundary
        if arr.dtype.kind == "f" and not np.isfinite(arr).all():
            n = int((~np.isfinite(arr)).sum())
            bad.append(f"{prefix}{jax.tree_util.keystr(path)}: "
                       f"{n}/{arr.size} non-finite")
    return bad


def check_finite(value, op: str) -> None:
    bad = nonfinite_leaves(value)
    if bad:
        raise NumericalHazard(
            f"RT302: non-finite values escaped {op}: " + "; ".join(bad)
        )


# --- sharding --------------------------------------------------------------


def _norm_spec(spec) -> tuple:
    """PartitionSpec → comparable tuple with trailing Nones stripped
    (P('expert') and P('expert', None) are the same placement)."""
    t = tuple(spec) if spec is not None else ()
    while t and t[-1] is None:
        t = t[:-1]
    return t


def check_store_sharding(engine) -> list[str]:
    """Compare each store leaf's actual sharding against the placement
    declared by its logical axes.  Returns mismatch descriptions
    (empty = clean); no-op on unsharded engines."""
    store = getattr(engine, "param_store", None)
    mesh = getattr(engine, "mesh", None)
    if store is None or mesh is None:
        return []
    from repro.launch.sharding import expert_param_shardings

    declared = expert_param_shardings(
        store, mesh, logical_axes=store.logical_axes()
    )
    leaves = jax.tree_util.tree_leaves_with_path(store)
    decl_leaves = jax.tree_util.tree_leaves(declared)
    out: list[str] = []
    for (path, leaf), want in zip(leaves, decl_leaves):
        if not isinstance(leaf, jax.Array):
            continue
        got_spec = getattr(leaf.sharding, "spec", None)
        want_spec = getattr(want, "spec", None)
        if _norm_spec(got_spec) != _norm_spec(want_spec):
            out.append(
                f"store{jax.tree_util.keystr(path)}: placed as "
                f"{_norm_spec(got_spec) or '(replicated)'} but logical "
                f"axes declare {_norm_spec(want_spec) or '(replicated)'}"
            )
    return out


def assert_store_sharding(engine) -> None:
    bad = check_store_sharding(engine)
    if bad:
        raise ShardingMismatch(
            "RT303: store placement drifted from declared logical axes: "
            + "; ".join(bad)
        )


# --- scheduler liveness ----------------------------------------------------


def check_scheduler_liveness(scheduler, bound: int) -> None:
    """RT304: fail if any queued request has waited > ``bound`` ticks.

    ``scheduler`` is a ``repro.serving.ContinuousScheduler`` (duck-typed
    on ``max_pending_wait_steps``).  Pick the bound from the workload:
    the queue head admits as soon as its bucket frees ``batch_size``
    rows, so with sane admission ``num_steps`` ticks (one full drain) is
    the worst case and ``2 * num_steps`` a comfortable bound; any wait
    beyond that means the FIFO policy regressed or a bucket leaks rows.
    """
    wait = scheduler.max_pending_wait_steps()
    if wait > bound:
        raise StarvationHazard(
            f"RT304: a queued request has waited {wait} scheduler "
            f"tick(s) > bound {bound} — queued={scheduler.queue_depth} "
            f"resident={scheduler.num_resident}; the admission policy "
            f"is starving the queue head (or a rolling bucket never "
            f"frees rows)"
        )


# --- engine wrapper --------------------------------------------------------


class EngineSanitizer:
    """Checked facade over a ``ServingEngine``.

    ``generate``/``flush`` run under the trace budget and (optionally)
    finiteness + sharding checks; membership mutators run under a hard
    zero-trace budget.  Everything else forwards to the engine
    untouched, so the wrapper is a drop-in for tests and benches.

    ``trace_budget`` is a LIFETIME cap on ``stats['traces']`` growth
    from the moment of wrapping: budget=1 means "one compile, ever" —
    exactly the retrace-free serving contract for a fixed-shape
    workload.  ``None`` disables the budget (numerics/sharding only).
    """

    _CHECKED = ("generate", "flush")
    _MEMBERSHIP = ("add_expert", "evict_expert", "retire_expert",
                   "quarantine_expert", "trip_expert", "restore_expert")

    def __init__(self, engine, *, trace_budget: int | None = None,
                 check_numerics: bool = True,
                 check_sharding: bool = True,
                 starvation_bound: int | None = None) -> None:
        self.engine = engine
        self.trace_budget = trace_budget
        self.check_numerics = check_numerics
        self.check_sharding = check_sharding
        #: RT304 wait bound for ``check_scheduler``; defaults (None) to
        #: 2 * num_steps — one full drain of the batch the queue head
        #: waits on, doubled for slack.
        self.starvation_bound = starvation_bound
        self._traces_at_wrap = engine.stats["traces"]
        self.events: list[str] = []

    # -- scheduler liveness (RT304) --

    def check_scheduler(self, scheduler) -> None:
        """Audit a ``ContinuousScheduler`` tick loop for starvation —
        call per tick (cheap: one host-side max over the queue)."""
        bound = self.starvation_bound
        if bound is None:
            bound = 2 * self.engine.sampler.num_steps
        check_scheduler_liveness(scheduler, bound)
        self.events.append(
            f"check_scheduler: wait={scheduler.max_pending_wait_steps()}"
            f"/{bound}"
        )

    # -- checked operations --

    def generate(self, key, batch_text_emb, batch_size):
        out = self.engine.generate(key, batch_text_emb, batch_size)
        self._post_op(f"generate(batch={batch_size})")
        if self.check_numerics:
            check_finite(out, f"generate(batch={batch_size})")
        return out

    def submit(self, key, text_emb=None, batch_size=None):
        return self.engine.submit(key, text_emb=text_emb,
                                  batch_size=batch_size)

    def flush(self) -> int:
        n = self.engine.flush()
        self._post_op(f"flush() -> {n} dispatch(es)")
        return n

    def __getattr__(self, name: str):
        attr = getattr(self.engine, name)
        if name in self._MEMBERSHIP and callable(attr):
            def checked(*args, **kwargs):
                with assert_no_retrace(self.engine, budget=0):
                    result = attr(*args, **kwargs)
                self._post_op(f"{name}()")
                return result
            return checked
        return attr

    # -- internals --

    def _post_op(self, op: str) -> None:
        traced = self.engine.stats["traces"] - self._traces_at_wrap
        self.events.append(f"{op}: traces={traced}")
        if self.trace_budget is not None and traced > self.trace_budget:
            raise TraceBudgetExceeded(
                f"RT301: {op} pushed the engine to {traced} trace(s), "
                f"budget is {self.trace_budget} — retrace-free serving "
                f"contract violated"
            )
        if self.check_sharding:
            assert_store_sharding(self.engine)
