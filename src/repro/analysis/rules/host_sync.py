"""Host-synchronization hazard rules (JX101, JX102).

A ``float()``/``.item()``/``np.asarray()`` on a device value forces a
blocking device→host transfer; inside a traced function it is worse —
the call either crashes at trace time (``TracerConversionError``) or,
when it happens to run on a concrete value, silently bakes that value
into the compiled program as a constant, so the next call with different
data serves stale numbers without any error.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astlint import (
    Finding,
    ModuleContext,
    Rule,
    attr_root,
    call_tail,
    is_jax_rooted,
)

#: builtins that coerce a device scalar to a host scalar.
_COERCIONS = frozenset({"float", "int", "bool", "complex"})

#: method calls that always force a device→host sync.
_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})

#: numpy entry points that pull a traced/device value to host.
_NP_SINKS = frozenset({"asarray", "array", "copy", "save", "savez"})


def _sync_call_kind(node: ast.Call) -> str | None:
    """Classify a call as a host sync, or None."""
    tail = call_tail(node)
    fn = node.func
    if isinstance(fn, ast.Name) and fn.id in _COERCIONS and node.args:
        if is_jax_rooted(node.args[0]):
            return f"{fn.id}() on a device expression"
    if isinstance(fn, ast.Attribute) and tail in _SYNC_METHODS:
        return f".{tail}()"
    if isinstance(fn, ast.Attribute) and tail == "device_get" \
            and attr_root(fn) == "jax":
        return "jax.device_get()"
    return None


class HostSyncInTraced(Rule):
    id = "JX101"
    slug = "host-sync"
    title = "host sync reachable from jitted/scanned code"
    hazard = (
        "Inside a function that executes under jax.jit / lax.scan / "
        "pallas_call, any device→host conversion (.item(), float(jnp...), "
        "np.asarray on a traced value, jax.device_get) either raises a "
        "TracerConversionError at trace time or freezes the value into "
        "the compiled program as a constant — the served result silently "
        "stops depending on that input."
    )
    bad = ("@jax.jit\n"
           "def step(x):\n"
           "    if float(jnp.mean(x)) > 0:   # trace-time sync\n"
           "        ...")
    good = ("@jax.jit\n"
            "def step(x):\n"
            "    return jnp.where(jnp.mean(x) > 0, ..., ...)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or not ctx.in_traced(node):
                continue
            kind = _sync_call_kind(node)
            if kind is None and isinstance(node.func, ast.Attribute) \
                    and call_tail(node) in _NP_SINKS \
                    and attr_root(node.func) == "np" and node.args:
                kind = f"np.{call_tail(node)}() on a traced value"
            if kind is not None:
                yield self.finding(
                    ctx, node,
                    f"{kind} inside traced code — moves a traced value to "
                    f"host (trace-time crash or silently baked constant)",
                )


class ImplicitHostSync(Rule):
    id = "JX102"
    slug = "host-sync"
    title = "implicit device→host sync outside an explicit boundary"
    hazard = (
        "float(jnp...), int(jnp...), and .item() block the caller until "
        "the device finishes every queued computation — a hidden "
        "synchronization point that serializes the pipeline.  Device→host "
        "conversions belong at one explicit boundary, marked with "
        "'# lint: allow-host-sync' so the sync is visible in review."
    )
    bad = "ppl = float(jnp.exp(-jnp.mean(picked)))"
    good = ("def _host_scalar(x):\n"
            "    return jnp.asarray(x).item()  # lint: allow-host-sync\n"
            "ppl = _host_scalar(jnp.exp(-jnp.mean(picked)))")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or ctx.in_traced(node):
                continue  # traced contexts are JX101's jurisdiction
            kind = _sync_call_kind(node)
            if kind is not None and "device_get" not in kind \
                    and "block_until_ready" not in kind:
                yield self.finding(
                    ctx, node,
                    f"implicit host sync: {kind} — move the device→host "
                    f"conversion to an explicit boundary and mark it with "
                    f"'# lint: allow-host-sync'",
                )
