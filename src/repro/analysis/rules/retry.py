"""Unbounded-retry rule (RT305).

The serving stack's failure handling is built on *bounded* retries:
failed dispatch groups re-queue at most ``max_request_requeues`` times,
failed buckets re-admit after an exponential-backoff window, canary
probes back off between attempts.  A retry loop WITHOUT a bound or a
backoff turns one persistent fault into a livelock — the scheduler
looks busy (throughput counters move) while the same poisoned work
re-dispatches forever.  This rule makes that shape un-mergeable:

* a constant-condition ``while`` (``while True:`` / ``while 1:``)
  whose body calls into the dispatch/flush/step surface and never
  references a bound-ish identifier (cap / budget / backoff / deadline
  / attempt / retries / …);
* a ``<handle>.requeues += 1`` bump inside a function that never
  *compares* a requeue count against anything (the cap consult that
  turns a re-queue into a terminal FAILED).

Runs in the same CI gate as the other AST rules (RT301–RT304 are
runtime sanitizers; RT305 is their static sibling and shares the RT3xx
"runtime serving contract" range).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astlint import (
    Finding,
    ModuleContext,
    Rule,
    call_tail,
    referenced_names,
)

#: call tails that dispatch serving work — retrying these needs a bound.
_DISPATCH_TAILS = frozenset({
    "flush", "dispatch", "_dispatch_group", "generate", "step",
    "advance", "submit", "probe", "retry", "launch", "send",
})

#: identifier fragments that signal SOME bound/backoff is consulted.
_BOUND_HINTS = (
    "max", "cap", "budget", "bound", "backoff", "deadline", "attempt",
    "retries", "requeue", "limit", "timeout", "expire",
)


def _is_constant_true(test: ast.AST) -> bool:
    return isinstance(test, ast.Constant) and bool(test.value)


def _names_hint_bound(names: set[str]) -> bool:
    return any(h in n.lower() for n in names for h in _BOUND_HINTS)


class UnboundedRetryLoop(Rule):
    id = "RT305"
    slug = "unbounded-retry"
    title = "retry loop without a bound or backoff"
    hazard = (
        "Re-dispatching failed work without a cap or a backoff window "
        "turns one persistent fault into a livelock: the loop burns "
        "compute re-running the same poisoned dispatch while liveness "
        "metrics look healthy.  Every retry path must either consult a "
        "bound (max_request_requeues, an attempt cap, a deadline) or "
        "wait out a growing backoff before re-admission — the serving "
        "stack's _fail_bucket/flush re-queue machinery does both; new "
        "code should route failures through it rather than hand-rolling "
        "a while-True around the dispatch surface."
    )
    bad = ("while True:\n"
           "    try:\n"
           "        engine.flush()      # retries forever on poison\n"
           "    except Exception:\n"
           "        continue")
    good = ("for attempt in range(max_attempts):   # bounded\n"
            "    try:\n"
            "        engine.flush()\n"
            "        break\n"
            "    except Exception:\n"
            "        time.sleep(backoff * 2 ** attempt)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        yield from self._unbounded_whiles(ctx)
        yield from self._uncapped_requeues(ctx)

    def _unbounded_whiles(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.While) \
                    or not _is_constant_true(node.test):
                continue
            tails = {
                call_tail(n) for n in ast.walk(node)
                if isinstance(n, ast.Call)
            }
            dispatching = tails & _DISPATCH_TAILS
            if not dispatching:
                continue
            if _names_hint_bound(referenced_names(node)):
                continue
            yield self.finding(
                ctx, node,
                f"`while True` around "
                f"{'/'.join(sorted(dispatching))}(...) with no bound or "
                f"backoff in the loop — a persistent fault livelocks "
                f"here; cap the attempts or consult a backoff window",
            )

    def _uncapped_requeues(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            bumps = [
                n for n in ast.walk(fn)
                if isinstance(n, ast.AugAssign)
                and isinstance(n.target, ast.Attribute)
                and n.target.attr == "requeues"
            ]
            if not bumps:
                continue
            compares_cap = any(
                isinstance(n, ast.Compare)
                and "requeues" in " ".join(referenced_names(n)).lower()
                for n in ast.walk(fn)
            )
            if compares_cap:
                continue
            for bump in bumps:
                yield self.finding(
                    ctx, bump,
                    f"`{ast.unparse(bump.target)} += ...` in "
                    f"{fn.name}() without comparing the requeue count "
                    f"against a cap — the request re-queues forever "
                    f"instead of going terminal FAILED",
                )
