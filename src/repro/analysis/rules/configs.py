"""Config-hashability and pytree-registration rules (JX104, JX105).

The serving engine keys its compiled-sampler cache on frozen config
dataclasses (``SamplerConfig`` and friends): one unhashable or
mutable-default field turns every ``generate()`` into either a
``TypeError`` or — worse, with hash-by-id objects — a silent recompile
per request.  Separately, a dataclass carrying arrays through a
``lax.scan``/``lax.cond`` carry must be registered as a pytree first,
or JAX treats the whole instance as a static leaf and leaks tracers.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astlint import (
    Finding,
    ModuleContext,
    Rule,
    call_tail,
    dotted_name,
)

_MUTABLE_CONTAINERS = frozenset({
    "list", "dict", "set", "List", "Dict", "Set", "MutableMapping",
    "bytearray", "defaultdict", "OrderedDict",
})

_ARRAY_TYPES = frozenset({
    "np.ndarray", "numpy.ndarray", "jnp.ndarray", "jax.Array", "Array",
    "ndarray", "chex.Array", "ArrayLike", "jax.numpy.ndarray",
})

_REGISTRATIONS = frozenset({
    "register_dataclass", "register_pytree_node",
    "register_pytree_node_class", "register_pytree_with_keys",
    "register_pytree_with_keys_class", "register_static",
})

_HOF_TRIGGERS = frozenset({"scan", "cond", "while_loop", "switch",
                           "fori_loop"})


def _dataclass_decorator(cls: ast.ClassDef) -> tuple[bool, bool]:
    """(is_dataclass, is_frozen) from the decorator list."""
    for dec in cls.decorator_list:
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name is None or name.split(".")[-1] != "dataclass":
            continue
        frozen = False
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                    frozen = bool(kw.value.value)
        return True, frozen
    return False, False


def _annotation_bases(ann: ast.AST) -> set[str]:
    """Top-level type names of an annotation, unwrapping Optional/unions
    and string annotations — but NOT descending into subscripts, so
    ``Callable[..., Array]`` resolves to ``Callable``, not ``Array``."""
    out: set[str] = set()

    def walk(node: ast.AST) -> None:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            try:
                walk(ast.parse(node.value, mode="eval").body)
            except SyntaxError:
                pass
            return
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
            walk(node.left)
            walk(node.right)
            return
        if isinstance(node, ast.Subscript):
            base = dotted_name(node.value)
            if base is not None and base.split(".")[-1] in (
                "Optional", "Union", "Annotated", "Final", "ClassVar",
            ):
                elts = node.slice.elts if isinstance(
                    node.slice, ast.Tuple) else [node.slice]
                for e in elts:
                    walk(e)
            else:
                walk(node.value)
            return
        name = dotted_name(node)
        if name is not None:
            out.add(name)

    walk(ann)
    return out


def _registered_classes(tree: ast.Module) -> set[str]:
    """Class names pytree-registered anywhere in the module (call form
    ``register_dataclass(Cls)``/``register_pytree_node(Cls, ...)``,
    decorator form, or ``functools.partial(register_dataclass, ...)``
    used as a decorator)."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_tail(node) in _REGISTRATIONS:
            for arg in node.args:
                name = dotted_name(arg)
                if name is not None:
                    out.add(name.split(".")[-1])
        if isinstance(node, ast.ClassDef):
            for dec in node.decorator_list:
                tails = {
                    call_tail(n) if isinstance(n, ast.Call) else None
                    for n in ast.walk(dec) if isinstance(n, ast.Call)
                }
                name = dotted_name(dec if not isinstance(dec, ast.Call)
                                   else dec.func)
                tails.add(None if name is None else name.split(".")[-1])
                for n in ast.walk(dec):
                    if isinstance(n, (ast.Name, ast.Attribute)):
                        dn = dotted_name(n)
                        if dn is not None:
                            tails.add(dn.split(".")[-1])
                if tails & _REGISTRATIONS:
                    out.add(node.name)
    return out


def _field_findings(cls: ast.ClassDef):
    """Yield (stmt, kind, detail) for hazardous fields of a dataclass."""
    for stmt in cls.body:
        if not isinstance(stmt, ast.AnnAssign) or not isinstance(
                stmt.target, ast.Name):
            continue
        bases = _annotation_bases(stmt.annotation)
        short = {b.split(".")[-1] for b in bases}
        if short & _MUTABLE_CONTAINERS:
            yield stmt, "container", sorted(short & _MUTABLE_CONTAINERS)[0]
        elif bases & _ARRAY_TYPES or short & {"ndarray"}:
            yield stmt, "array", sorted(bases)[0]
        if stmt.value is not None:
            for n in ast.walk(stmt.value):
                if isinstance(n, ast.Call) \
                        and call_tail(n) == "field":
                    for kw in n.keywords:
                        if kw.arg == "default_factory" and dotted_name(
                                kw.value) in ("list", "dict", "set"):
                            yield stmt, "default", dotted_name(kw.value)
                elif isinstance(n, (ast.List, ast.Dict, ast.Set)) \
                        and n is stmt.value:
                    yield stmt, "default", type(n).__name__.lower()


class UnhashableConfigField(Rule):
    id = "JX104"
    slug = "mutable-config"
    title = "unhashable or mutable-default field on a frozen config"
    hazard = (
        "Frozen config dataclasses are jit-cache keys (the ServingEngine "
        "keys compiled samplers on SamplerConfig).  A list/dict/set "
        "field, a mutable default_factory, or a bare ndarray field makes "
        "hash() raise — or, for hash-by-id values, makes every request "
        "miss the compile cache and silently retrace.  Use tuples, "
        "frozen sub-configs via default_factory, or move array state out "
        "of the config."
    )
    bad = ("@dataclasses.dataclass(frozen=True)\n"
           "class Config:\n"
           "    steps: list = dataclasses.field(default_factory=list)")
    good = ("@dataclasses.dataclass(frozen=True)\n"
            "class Config:\n"
            "    steps: tuple = ()")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        registered = _registered_classes(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            is_dc, frozen = _dataclass_decorator(node)
            if not is_dc or not frozen:
                continue
            if node.name in registered:
                continue  # registered pytrees are traced data, not keys
            for stmt, kind, detail in _field_findings(node):
                if kind == "container":
                    msg = (f"frozen dataclass {node.name}: field typed "
                           f"'{detail}' is unhashable — breaks jit cache "
                           f"keys; use a tuple/frozen sub-config")
                elif kind == "array":
                    msg = (f"frozen dataclass {node.name}: ndarray-typed "
                           f"field ('{detail}') makes hash() raise if the "
                           f"config is ever used as a jit cache key")
                else:
                    msg = (f"frozen dataclass {node.name}: mutable "
                           f"default ({detail}) — unhashable instance")
                yield self.finding(ctx, stmt, msg)


class UnregisteredCarryDataclass(Rule):
    id = "JX105"
    slug = "pytree-dataclass"
    title = "array-carrying dataclass not registered as a pytree"
    hazard = (
        "In a module that threads values through lax.scan/lax.cond, a "
        "dataclass holding jax arrays MUST be registered "
        "(jax.tree_util.register_dataclass or register_pytree_node) "
        "before an instance enters a carry: unregistered instances are "
        "treated as static leaves, so the carried arrays leak tracers or "
        "get baked into the trace as constants."
    )
    bad = ("@dataclasses.dataclass(frozen=True)\n"
           "class Plan:\n"
           "    idx: jax.Array\n"
           "...\n"
           "x, _ = jax.lax.scan(step, (x0, Plan(idx)), ts)")
    good = ("@functools.partial(jax.tree_util.register_dataclass, ...)\n"
            "@dataclasses.dataclass(frozen=True)\n"
            "class Plan:\n"
            "    idx: jax.Array")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        uses_hof = any(
            isinstance(n, ast.Call) and call_tail(n) in _HOF_TRIGGERS
            for n in ast.walk(ctx.tree)
        )
        if not uses_hof:
            return
        registered = _registered_classes(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            is_dc, _ = _dataclass_decorator(node)
            if not is_dc or node.name in registered:
                continue
            array_fields = [
                stmt.target.id for stmt, kind, _ in _field_findings(node)
                if kind == "array"
            ]
            if array_fields:
                yield self.finding(
                    ctx, node,
                    f"dataclass {node.name} holds array fields "
                    f"({', '.join(array_fields)}) in a module using "
                    f"lax.scan/lax.cond but is not registered as a "
                    f"pytree — it cannot enter a carry safely",
                )
