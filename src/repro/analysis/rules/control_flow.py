"""Traced-control-flow and PRNG-discipline rules (JX103, JX106).

Python ``if``/``while`` evaluate their condition eagerly at trace time:
on a traced value that raises ``TracerBoolConversionError`` — or, when
the value happens to be concrete (weak types, shape-dependent consts),
silently specializes the trace to one branch.  ``jax.random`` calls are
only reproducible when their key is threaded from the caller; minting a
fresh ``PRNGKey`` at the call site yields the same "random" numbers on
every invocation and hides the seed from the request plumbing.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astlint import (
    Finding,
    ModuleContext,
    Rule,
    call_tail,
    dotted_name,
    is_jax_rooted,
)

#: jax.random functions that *derive* keys — an inline PRNGKey feeding
#: these is deterministic seed plumbing, not a sampling hazard.
_KEY_DERIVERS = frozenset({
    "PRNGKey", "key", "split", "fold_in", "wrap_key_data", "key_data",
    "clone",
})


class TracedPythonBranch(Rule):
    id = "JX103"
    slug = "traced-branch"
    title = "Python if/while on a traced value"
    hazard = (
        "A Python branch inside jitted/scanned code runs once, at trace "
        "time.  If the condition involves a device value it either "
        "raises TracerBoolConversionError or silently freezes the "
        "decision for every later call — the compiled program keeps "
        "taking the branch the tracer took.  Use lax.cond / lax.select / "
        "jnp.where so the decision stays in the compiled program."
    )
    bad = ("def body(x, t):      # lax.scan body\n"
           "    if jnp.any(jnp.isnan(x)):\n"
           "        x = jnp.zeros_like(x)")
    good = ("def body(x, t):\n"
            "    x = jnp.where(jnp.any(jnp.isnan(x)),\n"
            "                  jnp.zeros_like(x), x)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if not ctx.in_traced(node):
                continue
            if is_jax_rooted(node.test):
                kw = "while" if isinstance(node, ast.While) else "if"
                yield self.finding(
                    ctx, node,
                    f"Python `{kw}` on a traced (device-valued) condition "
                    f"inside traced code — trace-time crash or silently "
                    f"specialized branch; use lax.cond/jnp.where",
                )


class UnthreadedPRNGKey(Rule):
    id = "JX106"
    slug = "prng-key"
    title = "jax.random sampling with an inline (unthreaded) PRNGKey"
    hazard = (
        "jax.random.<sampler>(jax.random.PRNGKey(c), ...) draws the SAME "
        "numbers every call: the key is minted at the call site instead "
        "of being threaded from the caller.  Library code must accept a "
        "key argument (split/fold_in upstream) so randomness is "
        "reproducible AND actually varies across requests."
    )
    bad = "noise = jax.random.normal(jax.random.PRNGKey(0), shape)"
    good = ("def sample(key, shape):\n"
            "    noise = jax.random.normal(key, shape)   # key threaded in")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            name = dotted_name(node.func) or ""
            if not name.endswith(".random." + (call_tail(node) or "")):
                continue
            fn = call_tail(node)
            if fn in _KEY_DERIVERS:
                continue
            key_arg = node.args[0]
            if isinstance(key_arg, ast.Call) \
                    and call_tail(key_arg) in ("PRNGKey", "key"):
                yield self.finding(
                    ctx, node,
                    f"jax.random.{fn} called with an inline "
                    f"PRNGKey(...) — the key is not threaded, so every "
                    f"call draws identical values",
                )
