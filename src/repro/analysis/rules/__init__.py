"""Rule registry for the repro lint engine.

Every AST rule ships here; ``python -m repro.analysis --list-rules``
and ``--explain`` resolve through this module, and the kernel-contract
checker contributes its KC2xx rule metadata for ``--explain`` even
though those checks run outside the per-file AST pass.
"""

from __future__ import annotations

from repro.analysis.astlint import Rule
from repro.analysis.rules.configs import (
    UnhashableConfigField,
    UnregisteredCarryDataclass,
)
from repro.analysis.rules.control_flow import (
    TracedPythonBranch,
    UnthreadedPRNGKey,
)
from repro.analysis.rules.host_sync import HostSyncInTraced, ImplicitHostSync
from repro.analysis.rules.retry import UnboundedRetryLoop

#: AST rules, in reporting order.
ALL_RULES: list[type[Rule]] = [
    HostSyncInTraced,       # JX101
    ImplicitHostSync,       # JX102
    TracedPythonBranch,     # JX103
    UnhashableConfigField,  # JX104
    UnregisteredCarryDataclass,  # JX105
    UnthreadedPRNGKey,      # JX106
    UnboundedRetryLoop,     # RT305
]


def default_rules() -> list[Rule]:
    return [cls() for cls in ALL_RULES]


def rule_classes() -> list[type[Rule]]:
    """AST rules plus contract/sanitizer rule metadata, for --explain."""
    from repro.analysis.kernel_contracts import CONTRACT_RULES
    from repro.analysis.sanitize import SANITIZER_RULES

    return [*ALL_RULES, *CONTRACT_RULES, *SANITIZER_RULES]


def find_rule(token: str) -> type[Rule] | None:
    """Resolve a rule by id (``JX101``) or slug (``host-sync``)."""
    token = token.strip()
    for cls in rule_classes():
        if token.upper() == cls.id or token.lower() == cls.slug:
            return cls
    return None
