"""Static contract checker for the Pallas kernels in ``repro.kernels``.

``kernels/ref.py`` promises: *each* ``<name>`` *kernel in this package
has a* ``ref_<name>`` *here with the exact same signature*.  Nothing
enforced that promise until now — a drifted oracle signature means the
parity tests silently compare the kernel against the wrong reference
semantics (exactly how a dequant-path regression in one expert's slot
would ship unnoticed).  This module parses the kernels package (pure
AST, nothing imported) and verifies, per public kernel entry point:

KC201  a ``ref_<name>`` oracle exists in ``ref.py``;
KC202  the oracle's signature matches the kernel's, ignoring plumbing
       parameters (``interpret``, ``block_*``, ``chunk``, ...);
KC203  the entry declares an ``interpret`` parameter and threads it
       into every ``pl.pallas_call`` it makes;
KC204  at least one test references the kernel by name (interpret-mode
       parity coverage);
KC205  lane-tiling arithmetic (``% 128`` / ``// 128``) lives in the
       shared ``_tile_pad`` helper, not inlined per call site.

Findings reuse :class:`repro.analysis.astlint.Finding` and respect the
same ``# lint: allow-<slug>`` pragmas.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Iterator

from repro.analysis.astlint import (
    Finding,
    Rule,
    call_tail,
    suppressed,
)

#: parameters that tune execution, not semantics — a ref oracle runs in
#: plain jnp and legitimately omits them.
PLUMBING_PARAMS = frozenset({"interpret", "debug", "chunk", "head_block"})

#: files in kernels/ that are not kernel-entry modules.
_NON_KERNEL_FILES = frozenset({"ref.py", "ops.py", "__init__.py"})


def _is_plumbing(name: str) -> bool:
    return name in PLUMBING_PARAMS or name.startswith("block")


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args] \
        + [p.arg for p in a.kwonlyargs]


def _contract_params(fn: ast.FunctionDef) -> list[str]:
    return [p for p in _param_names(fn) if not _is_plumbing(p)]


# --- rule metadata (for --explain; checks run in check_kernel_contracts) ---


class MissingRefOracle(Rule):
    id = "KC201"
    slug = "missing-ref-oracle"
    title = "Pallas kernel without a ref_<name> oracle"
    hazard = (
        "ref.py is the correctness ground truth: every public kernel "
        "entry needs a pure-jnp ref_<name> with the same semantics, or "
        "there is nothing to parity-test the Pallas path against."
    )
    bad = "def my_kernel(x, *, interpret=False): ...   # no ref_my_kernel"
    good = ("# kernels/my_kernel.py\ndef my_kernel(x, *, interpret=False)"
            "\n# kernels/ref.py\ndef ref_my_kernel(x): ...")


class OracleSignatureMismatch(Rule):
    id = "KC202"
    slug = "oracle-signature"
    title = "ref oracle signature drifted from its kernel"
    hazard = (
        "When the oracle's non-plumbing parameters differ from the "
        "kernel's, parity tests exercise different semantics than the "
        "kernel exposes — new kernel knobs (out_dtype, cfg_scale, ...) "
        "go unverified, and stale oracle knobs test dead paths."
    )
    bad = ("def kern(q, scale, *, out_dtype, interpret=False): ...\n"
           "def ref_kern(q, scale): ...   # out_dtype unverified")
    good = ("def kern(q, scale, *, out_dtype, interpret=False): ...\n"
            "def ref_kern(q, scale, *, out_dtype=jnp.float32): ...")


class MissingInterpretPlumbing(Rule):
    id = "KC203"
    slug = "interpret-plumbing"
    title = "kernel entry does not thread interpret= into pallas_call"
    hazard = (
        "Every kernel entry must accept interpret= and pass it to each "
        "pl.pallas_call so the whole suite runs on CPU in interpret "
        "mode; a hard-coded pallas_call only executes on TPU and is "
        "untestable in CI."
    )
    bad = "out = pl.pallas_call(kern, out_shape=...)(x)"
    good = ("def entry(x, *, interpret=False):\n"
            "    return pl.pallas_call(kern, ..., interpret=interpret)(x)")


class UntestedKernel(Rule):
    id = "KC204"
    slug = "untested-kernel"
    title = "kernel entry never referenced by any test"
    hazard = (
        "A kernel with no interpret-mode parity test is dead reckoning: "
        "the oracle may exist, but nothing runs kernel-vs-ref, so any "
        "regression ships silently."
    )
    bad = "def new_kernel(...): ...   # grep tests/ -> no hits"
    good = "tests/test_kernels.py::test_new_kernel_matches_ref"


class InlineTilePad(Rule):
    id = "KC205"
    slug = "tile-pad"
    title = "inline %128 //128 lane arithmetic outside _tile_pad"
    hazard = (
        "Lane-tiling padding (round a dimension up to the 128-lane "
        "register width) is subtle: the shared ops._tile_pad handles "
        "block-size clamping and remainders in one audited place.  An "
        "inlined `(t + 127) // 128 * 128` re-derivation eventually "
        "disagrees with it on some shape and produces a wrong BlockSpec."
    )
    bad = "pad = (t + 127) // 128 * 128   # ad-hoc copy"
    good = "padded, block = _tile_pad(t)"


CONTRACT_RULES: list[type[Rule]] = [
    MissingRefOracle, OracleSignatureMismatch, MissingInterpretPlumbing,
    UntestedKernel, InlineTilePad,
]


def _finding(rule: type[Rule], path: str, node: ast.AST, message: str,
             lines: list[str]) -> Finding:
    line = getattr(node, "lineno", 1)
    snippet = lines[line - 1] if 0 < line <= len(lines) else ""
    return Finding(rule=rule.id, slug=rule.slug, path=path, line=line,
                   col=getattr(node, "col_offset", 0), message=message,
                   snippet=snippet)


def _kernel_entries(tree: ast.Module) -> list[ast.FunctionDef]:
    """Public top-level functions that launch a pallas_call (directly or
    via a name bound to one inside the function)."""
    out = []
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and not node.name.startswith("_"):
            if any(isinstance(n, ast.Call) and call_tail(n) == "pallas_call"
                   for n in ast.walk(node)):
                out.append(node)
    return out


def _test_corpus(tests_dir: str | None) -> str:
    if tests_dir is None or not os.path.isdir(tests_dir):
        return ""
    chunks = []
    for name in sorted(os.listdir(tests_dir)):
        if name.endswith(".py"):
            with open(os.path.join(tests_dir, name), encoding="utf-8") as fh:
                chunks.append(fh.read())
    return "\n".join(chunks)


def check_kernel_contracts(
    kernels_dir: str,
    tests_dir: str | None = None,
) -> list[Finding]:
    """Run KC201–KC205 over a kernels package directory."""
    findings: list[Finding] = []

    ref_path = os.path.join(kernels_dir, "ref.py")
    refs: dict[str, ast.FunctionDef] = {}
    if os.path.exists(ref_path):
        with open(ref_path, encoding="utf-8") as fh:
            ref_tree = ast.parse(fh.read(), filename=ref_path)
        refs = {
            node.name: node for node in ref_tree.body
            if isinstance(node, ast.FunctionDef)
            and node.name.startswith("ref_")
        }

    corpus = _test_corpus(tests_dir)

    for name in sorted(os.listdir(kernels_dir)):
        if not name.endswith(".py") or name in _NON_KERNEL_FILES:
            continue
        path = os.path.join(kernels_dir, name)
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        lines = src.splitlines()
        tree = ast.parse(src, filename=path)

        for fn in _kernel_entries(tree):
            # KC201 / KC202 — oracle existence and signature parity.
            ref = refs.get(f"ref_{fn.name}")
            if ref is None:
                findings.append(_finding(
                    MissingRefOracle, path, fn,
                    f"kernel '{fn.name}' has no ref_{fn.name} oracle in "
                    f"ref.py — the docstring contract promises one",
                    lines))
            else:
                want = _contract_params(fn)
                got = _contract_params(ref)
                if want != got:
                    extra = [p for p in got if p not in want]
                    missing = [p for p in want if p not in got]
                    detail = []
                    if missing:
                        detail.append(
                            f"oracle missing {missing} (kernel semantics "
                            f"unverified)")
                    if extra:
                        detail.append(
                            f"oracle has stale params {extra} the kernel "
                            f"lacks")
                    if not detail:
                        detail.append(
                            f"parameter order differs: kernel {want} vs "
                            f"oracle {got}")
                    findings.append(_finding(
                        OracleSignatureMismatch, path, fn,
                        f"ref_{fn.name} signature drifted from kernel "
                        f"'{fn.name}': " + "; ".join(detail),
                        lines))

            # KC203 — interpret declared and threaded into every launch.
            params = set(_param_names(fn))
            calls = [
                n for n in ast.walk(fn)
                if isinstance(n, ast.Call) and call_tail(n) == "pallas_call"
            ]
            if "interpret" not in params:
                findings.append(_finding(
                    MissingInterpretPlumbing, path, fn,
                    f"kernel '{fn.name}' does not accept interpret= — "
                    f"it cannot run in CPU interpret mode",
                    lines))
            else:
                for call in calls:
                    if not any(kw.arg == "interpret" for kw in call.keywords):
                        findings.append(_finding(
                            MissingInterpretPlumbing, path, call,
                            f"pallas_call inside '{fn.name}' does not "
                            f"forward interpret=",
                            lines))

            # KC204 — referenced by at least one test.
            if corpus and not re.search(
                    rf"\b{re.escape(fn.name)}\b", corpus):
                findings.append(_finding(
                    UntestedKernel, path, fn,
                    f"kernel '{fn.name}' is not referenced by any file "
                    f"in {tests_dir} — no parity coverage",
                    lines))

        # KC205 — inline lane arithmetic (module-wide, incl. ops.py scan
        # below would be nice, but _tile_pad itself lives in ops.py; here
        # we flag kernel modules re-deriving it).
        findings.extend(_tile_pad_findings(path, tree, lines))

    # ops.py: allowed only inside _tile_pad itself.
    ops_path = os.path.join(kernels_dir, "ops.py")
    if os.path.exists(ops_path):
        with open(ops_path, encoding="utf-8") as fh:
            src = fh.read()
        lines = src.splitlines()
        tree = ast.parse(src, filename=ops_path)
        findings.extend(
            _tile_pad_findings(ops_path, tree, lines, allow_in="_tile_pad"))

    # pragma suppression, same grammar as the AST rules
    by_path: dict[str, list[str]] = {}
    kept: list[Finding] = []
    for f in findings:
        if f.path not in by_path:
            with open(f.path, encoding="utf-8") as fh:
                by_path[f.path] = fh.read().splitlines()
        if not suppressed(f, by_path[f.path]):
            kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept


def _tile_pad_findings(path: str, tree: ast.Module, lines: list[str],
                       allow_in: str | None = None) -> list[Finding]:
    out: list[Finding] = []

    def owner(node: ast.AST, parents: dict) -> str | None:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.FunctionDef):
                return cur.name
            cur = parents.get(cur)
        return None

    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    for node in ast.walk(tree):
        if not isinstance(node, ast.BinOp):
            continue
        if not isinstance(node.op, (ast.Mod, ast.FloorDiv)):
            continue
        rhs = node.right
        if isinstance(rhs, ast.Constant) and rhs.value == 128:
            fn_name = owner(node, parents)
            if allow_in is not None and fn_name == allow_in:
                continue
            out.append(_finding(
                InlineTilePad, path, node,
                "inline lane-tiling arithmetic (const 128) — use the "
                "shared ops._tile_pad helper",
                lines))
    return out
