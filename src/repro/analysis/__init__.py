"""Static + runtime hazard analysis for the repro codebase.

Three layers (see ``docs/analysis.md`` for the rule catalogue):

* :mod:`repro.analysis.astlint` + :mod:`repro.analysis.rules` — pure-AST
  lint rules for JAX hazards (JX1xx): host syncs reachable from traced
  code, Python branches on tracers, unhashable jit-cache-key configs,
  unregistered carry dataclasses, unthreaded PRNG keys.
* :mod:`repro.analysis.kernel_contracts` — the Pallas kernel contract
  (KC2xx): every kernel keeps a signature-matched ``ref_<name>`` oracle,
  threads ``interpret=``, reuses ``_tile_pad``, and is parity-tested.
* :mod:`repro.analysis.sanitize` — runtime serving invariants (RT3xx):
  trace budgets, NaN/Inf escape detection, store-sharding drift.

CLI: ``python -m repro.analysis --check src/`` (the CI gate),
``--explain JX101``, ``--baseline`` to adopt existing findings.

This package imports no heavy dependencies at lint time — the AST and
contract layers run without jax installed; only ``sanitize`` needs a
live engine.
"""

from __future__ import annotations

from repro.analysis.astlint import (
    Finding,
    Rule,
    apply_baseline,
    lint_paths,
    lint_source,
    load_baseline,
    write_baseline,
)
from repro.analysis.kernel_contracts import check_kernel_contracts
from repro.analysis.rules import ALL_RULES, default_rules, find_rule

__all__ = [
    "ALL_RULES",
    "Finding",
    "Rule",
    "apply_baseline",
    "check_kernel_contracts",
    "default_rules",
    "find_rule",
    "lint_paths",
    "lint_source",
    "load_baseline",
    "write_baseline",
]
