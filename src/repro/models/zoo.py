"""Unified model-zoo dispatch.

Every assigned architecture maps to one of four backbone modules; this
registry gives launch/, training/ and tests a single interface:

    init(cfg, key) -> params
    loss_fn(cfg, params, batch) -> (loss, metrics)
    forward_train(cfg, params, batch) -> (logits, aux)
    prefill(cfg, params, batch) -> (logits, cache)
    make_cache(cfg, batch_size, max_len) -> cache
    decode_step(cfg, params, cache, token, pos) -> (logits, cache)

``batch`` is a dict: tokens / labels (+ audio_embeds or vision_embeds for
the stubbed-frontend archs).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, mamba2, transformer
from repro.models.config import LMConfig

Array = jax.Array

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "audio": encdec,
}


def backbone(cfg: LMConfig):
    return _FAMILY[cfg.arch_type]


def init(cfg: LMConfig, key) -> Any:
    return backbone(cfg).init(cfg, key)


def _extra_kwargs(cfg: LMConfig, batch: dict) -> dict:
    if cfg.arch_type == "audio":
        return {"audio_embeds": batch["audio_embeds"]}
    if cfg.arch_type == "vlm":
        return {"vision_embeds": batch["vision_embeds"]}
    return {}


def loss_fn(cfg: LMConfig, params, batch: dict):
    m = backbone(cfg)
    return m.loss_fn(cfg, params, batch["tokens"], batch["labels"],
                     **_extra_kwargs(cfg, batch))


def forward_train(cfg: LMConfig, params, batch: dict):
    m = backbone(cfg)
    return m.forward_train(cfg, params, batch["tokens"],
                           **_extra_kwargs(cfg, batch))


def prefill(cfg: LMConfig, params, batch: dict):
    m = backbone(cfg)
    return m.prefill(cfg, params, batch["tokens"], **_extra_kwargs(cfg, batch))


def make_cache(cfg: LMConfig, batch_size: int, max_len: int):
    return backbone(cfg).make_cache(cfg, batch_size, max_len)


def decode_step(cfg: LMConfig, params, cache, token: Array, pos: Array):
    return backbone(cfg).decode_step(cfg, params, cache, token, pos)


def supports_long_context(cfg: LMConfig) -> bool:
    """True when 500k-token decode is sub-quadratic/O(1)-state.

    SSM/hybrid natively; attention archs only under a sliding/decode window
    (ring-buffer cache) — see DESIGN.md §Arch-applicability.
    """
    if cfg.arch_type in ("ssm",):
        return True
    if cfg.arch_type == "hybrid":
        return bool(cfg.decode_window or cfg.sliding_window)
    return bool(cfg.decode_window or cfg.sliding_window)
