"""Modality frontend STUBS (the one sanctioned carve-out).

Audio (whisper): the mel-spectrogram + conv feature extractor is stubbed —
we supply precomputed frame embeddings ``(B, n_frames, d_model)``.

Vision (paligemma): the SigLIP ViT encoder + projector input is stubbed —
we supply patch embeddings ``(B, 256, d_model)``.

Both stubs are *deterministic* functions of a seed so tests and examples
get reproducible "features", and both expose ShapeDtypeStruct specs for the
dry-run path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig


def audio_frame_embeddings(cfg: LMConfig, batch: int, seed: int = 0):
    """Stand-in for log-mel + conv1d×2 frontend output."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(
        key, (batch, cfg.encoder_seq_len, cfg.d_model),
        dtype=cfg.activation_dtype,
    )


def vision_patch_embeddings(cfg: LMConfig, batch: int, seed: int = 0):
    """Stand-in for SigLIP-So400m patch embeddings (already projected)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(
        key, (batch, cfg.vision_prefix_len, cfg.d_model),
        dtype=cfg.activation_dtype,
    )


def audio_spec(cfg: LMConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(
        (batch, cfg.encoder_seq_len, cfg.d_model), cfg.activation_dtype
    )


def vision_spec(cfg: LMConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(
        (batch, cfg.vision_prefix_len, cfg.d_model), cfg.activation_dtype
    )
