"""Diffusion Transformer expert with PixArt-α AdaLN-Single (paper §2.5).

Processes 32×32×4 VAE latents with 2×2 patch embedding (256 tokens).

AdaLN-Single (Eqs. 14–16): a single global MLP maps the timestep embedding
τ(t) to all ``L × 6 × d`` modulation vectors at once; each block adds its
learned embedding ``E_b`` (init N(0, 1/√d)).  Per block (Eqs. 17–19):

    h1 = h  + α_msa ⊙ MSA(LN(h) ⊙ (1+γ_msa) + β_msa)
    h2 = h1 + CrossAttn(LN(h1), e_text)
    h' = h2 + α_mlp ⊙ FFN(LN(h2) ⊙ (1+γ_mlp) + β_mlp)

LN has no learnable affine.  Zero-init: modulation-path final linear,
cross-attn output projections (§2.5 Initialization Strategy).

Timesteps: the discrete 1000-entry sinusoidal table from the pretrained
DiT is kept; continuous FM times are mapped through ``round(999 t)``
(Eq. 21) at runtime.

Parameter top-level groups intentionally mirror the Eq. 20 checkpoint-
conversion policy keys: patch_embed / pos_embed / blocks / t_embed /
adaln_single / cross_attn / text_proj / final_layer / null_text_embed.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import DiTConfig
from repro.core.param_store import (
    DenseStore, ExpertParamStore, QuantLeaf, dequant_leaf,
)
from repro.core.param_store import EXPERT_AXIS as EXPERT_AXIS  # re-export
from repro.core.schedules import to_ddpm_timestep
from repro.kernels import ops

Array = jax.Array


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def sinusoidal_table(num: int, dim: int) -> Array:
    """Frozen sinusoidal timestep features (the 'learned table' initializer)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(num)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def patchify(x: Array, p: int) -> Array:
    """(B, H, W, C) -> (B, H/p * W/p, p*p*C)."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // p, p, w // p, p, c)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, (h // p) * (w // p), p * p * c)


def unpatchify(x: Array, p: int, hw: int, c: int) -> Array:
    b, n, _ = x.shape
    g = hw // p
    x = x.reshape(b, g, g, p, p, c)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, hw, hw, c)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _block_init(cfg: DiTConfig, key) -> dict:
    d = cfg.d_model
    hd = d // cfg.num_heads
    ks = jax.random.split(key, 2)
    return {
        "attn": L.gqa_init(ks[0], d, cfg.num_heads, cfg.num_heads, hd,
                           cfg.param_dtype),
        "mlp": L.gelu_mlp_init(ks[1], d, cfg.d_ff, cfg.param_dtype),
    }


def _cross_attn_init(cfg: DiTConfig, key) -> dict:
    d = cfg.d_model
    hd = d // cfg.num_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, d, cfg.param_dtype),
        "wk": L.dense_init(ks[1], d, d, cfg.param_dtype),
        "wv": L.dense_init(ks[2], d, d, cfg.param_dtype),
        # §2.5: cross-attn output projection zero-initialized.
        "wo": L.zeros_dense_init(ks[3], d, d, cfg.param_dtype),
    }


def init(cfg: DiTConfig, key) -> dict:
    d = cfg.d_model
    p = cfg.patch_size
    in_dim = p * p * cfg.latent_channels
    ks = jax.random.split(key, 10)
    t_feat = 256

    params: dict = {
        "patch_embed": L.dense_init_b(ks[0], in_dim, d, cfg.param_dtype),
        "pos_embed": {
            "emb": (0.02 * jax.random.normal(ks[1], (cfg.num_tokens, d))
                    ).astype(cfg.param_dtype)
        },
        "t_embed": {
            "table": sinusoidal_table(cfg.num_timesteps, t_feat).astype(
                cfg.param_dtype
            ),
            "mlp1": L.dense_init_b(ks[2], t_feat, d, cfg.param_dtype),
            "mlp2": L.dense_init_b(ks[3], d, d, cfg.param_dtype),
        },
        "blocks": jax.vmap(lambda k: _block_init(cfg, k))(
            jax.random.split(ks[4], cfg.num_layers)
        ),
        "final_layer": {
            # zero-init final projection -> identity-ish start (§2.5).
            "mod": L.zeros_dense_init(ks[5], d, 2 * d, cfg.param_dtype),
            "out": L.zeros_dense_init(ks[5], d, in_dim, cfg.param_dtype),
        },
    }
    if cfg.adaln_single:
        params["adaln_single"] = {
            # Eq. 14 global MLP.  The (L,6,d) tensor of Eq. 15 is the global
            # (6,d) modulation broadcast over layers plus per-block E_b —
            # a literal d->6Ld dense would alone cost more than the
            # per-block MLPs it replaces (PixArt-α §2.3).  Final linear
            # zero-init (§2.5).
            "mlp1": L.dense_init_b(ks[6], d, d, cfg.param_dtype),
            "mlp2": L.zeros_dense_init(ks[6], d, 6 * d),
            # Eq. 16 per-block embeddings E_b ~ N(0, 1/sqrt(d)).
            "block_embed": (
                jax.random.normal(ks[7], (cfg.num_layers, 6, d))
                / math.sqrt(d)
            ).astype(cfg.param_dtype),
        }
    else:
        # classic per-block adaLN-Zero (ablation baseline; 30% more params)
        params["adaln_per_block"] = jax.vmap(
            lambda k: L.zeros_dense_init(k, d, 6 * d, cfg.param_dtype)
        )(jax.random.split(ks[6], cfg.num_layers))
    if cfg.use_text:
        params["text_proj"] = L.dense_init_b(ks[8], cfg.text_dim, d,
                                             cfg.param_dtype)
        params["cross_attn"] = jax.vmap(lambda k: _cross_attn_init(cfg, k))(
            jax.random.split(ks[9], cfg.num_layers)
        )
        params["null_text_embed"] = {
            "emb": (0.02 * jax.random.normal(ks[9], (cfg.text_len,
                                                     cfg.text_dim))
                    ).astype(cfg.param_dtype)
        }
    if cfg.num_classes:
        params["cls_head"] = L.dense_init_b(ks[8], d, cfg.num_classes,
                                            cfg.param_dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def timestep_embedding(cfg: DiTConfig, params, t: Array) -> Array:
    """τ(t) via the discrete table + MLP (Eq. 21 runtime mapping)."""
    idx = to_ddpm_timestep(t, cfg.num_timesteps)
    feat = jnp.take(params["t_embed"]["table"], idx, axis=0)
    h = jax.nn.silu(L.dense(params["t_embed"]["mlp1"], feat))
    return L.dense(params["t_embed"]["mlp2"], h)            # (B, d)


def global_modulation(cfg: DiTConfig, params, tau: Array) -> Array:
    """Eq. 14/15: (B, L, 6, d) modulation tensor C (+E_b added per block).

    Computed as a single global (6, d) modulation broadcast across the L
    layers (the per-layer variation comes from E_b in Eq. 16)."""
    b = tau.shape[0]
    h = jax.nn.silu(L.dense(params["adaln_single"]["mlp1"], tau))
    c = L.dense(params["adaln_single"]["mlp2"], h)
    c = c.reshape(b, 1, 6, cfg.d_model)
    return jnp.broadcast_to(c, (b, cfg.num_layers, 6, cfg.d_model))


def _modulate(x: Array, gamma: Array, beta: Array) -> Array:
    return x * (1.0 + gamma[:, None]) + beta[:, None]


def _self_attn(cfg: DiTConfig, p, x: Array) -> Array:
    d = cfg.d_model
    hd = d // cfg.num_heads
    b, s, _ = x.shape
    q, k, v = L.gqa_project(p, x, cfg.num_heads, cfg.num_heads, hd)
    pos = jnp.arange(s)
    out = L.chunked_attention(
        q, k, v, q_positions=pos, kv_positions=pos, causal=False,
        chunk_size=cfg.attn_chunk,
    )
    return L.dense(p["wo"], out.reshape(b, s, d))


def _cross_attn(cfg: DiTConfig, p, x: Array, text: Array) -> Array:
    d = cfg.d_model
    hd = d // cfg.num_heads
    b, s, _ = x.shape
    m = text.shape[1]
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = L.dense(p["wk"], text).reshape(b, m, cfg.num_heads, hd)
    v = L.dense(p["wv"], text).reshape(b, m, cfg.num_heads, hd)
    out = L.chunked_attention(
        q, k, v, q_positions=jnp.arange(s), kv_positions=jnp.arange(m),
        causal=False, chunk_size=cfg.attn_chunk,
    )
    return L.dense(p["wo"], out.reshape(b, s, d))


def apply(
    cfg: DiTConfig,
    params,
    x_t: Array,
    t: Array,
    *,
    text_emb: Array | None = None,
    drop_mask: Array | None = None,
) -> Array:
    """Predict ε or velocity (objective decided by the training loss).

    Args:
      x_t: (B, H, W, C) noisy latents.
      t: (B,) native-time (continuous [0,1] or discrete indices).
      text_emb: (B, text_len, text_dim) frozen CLIP embeddings; None uses the
        learned null embedding (CFG unconditional branch).
      drop_mask: optional (B,) bool — per-sample CFG dropout: True rows use
        the null embedding (train-time p=0.1 conditioning drop, §2.5).
    """
    b = x_t.shape[0]
    p = cfg.patch_size
    x = patchify(x_t.astype(cfg.activation_dtype), p)
    h = L.dense(params["patch_embed"], x)
    h = h + params["pos_embed"]["emb"][None].astype(h.dtype)

    tau = timestep_embedding(cfg, params, t)                 # (B, d)

    if cfg.use_text:
        null = jnp.broadcast_to(
            params["null_text_embed"]["emb"][None],
            (b, cfg.text_len, cfg.text_dim),
        )
        if text_emb is None:
            text_emb = null
        elif drop_mask is not None:
            text_emb = jnp.where(drop_mask[:, None, None], null, text_emb)
        text = L.dense(params["text_proj"],
                       text_emb.astype(cfg.activation_dtype))
    else:
        text = None

    if cfg.adaln_single:
        mods = global_modulation(cfg, params, tau)           # (B, L, 6, d)
        mods = mods + params["adaln_single"]["block_embed"][None].astype(
            mods.dtype
        )
        mods = jnp.moveaxis(mods, 1, 0)                      # (L, B, 6, d)
    else:
        def per_block(pb):
            return L.dense(pb, jax.nn.silu(tau)).reshape(b, 6, cfg.d_model)

        mods = jax.vmap(per_block)(params["adaln_per_block"])

    xs: tuple = (params["blocks"], mods)
    if cfg.use_text:
        xs = xs + (params["cross_attn"],)

    def body(h, inputs):
        if cfg.use_text:
            bp, mod, cp = inputs
        else:
            bp, mod = inputs
            cp = None
        g_msa, b_msa, a_msa = mod[:, 0], mod[:, 1], mod[:, 2]
        g_mlp, b_mlp, a_mlp = mod[:, 3], mod[:, 4], mod[:, 5]
        # Eq. 17
        hn = _modulate(L.layernorm({}, h), g_msa, b_msa)
        h = h + a_msa[:, None] * _self_attn(cfg, bp["attn"], hn)
        # Eq. 18
        if cp is not None:
            h = h + _cross_attn(cfg, cp, L.layernorm({}, h), text)
        # Eq. 19
        hn = _modulate(L.layernorm({}, h), g_mlp, b_mlp)
        h = h + a_mlp[:, None] * L.gelu_mlp(bp["mlp"], hn)
        return h, None

    h, _ = jax.lax.scan(body, h, xs)

    if cfg.num_classes:
        pooled = jnp.mean(h, axis=1)
        return L.dense(params["cls_head"], pooled)           # router logits

    # Final layer: adaLN modulation from tau, then linear to patch pixels.
    mod = L.dense(params["final_layer"]["mod"], jax.nn.silu(tau))
    shift, scale = jnp.split(mod, 2, axis=-1)
    h = L.layernorm({}, h) * (1.0 + scale[:, None]) + shift[:, None]
    out = L.dense(params["final_layer"]["out"], h)
    return unpatchify(out, p, cfg.latent_size,
                      cfg.latent_channels).astype(jnp.float32)


def stack_expert_params(params_list):
    """Stack K homogeneous-architecture expert pytrees into one pytree.

    Every leaf gains a leading expert axis ``(K, ...)``.  This is the
    precondition for the sampler's routed-expert-only execution, and the
    raw material for a typed ``core.param_store.ExpertParamStore``
    (``make_store`` wraps the result dense or int8/fp8-quantized).
    Raises if structures or leaf shapes differ — callers should check
    ``repro.core.params_are_stackable`` first and fall back to the dense
    path for heterogeneous expert sets.
    """
    if len(params_list) == 1:
        return jax.tree.map(lambda x: jnp.asarray(x)[None], params_list[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def stacked_param_logical_axes(stacked):
    """Logical sharding annotation for stacked expert params.

    Thin delegator to ``ExpertParamStore.logical_axes`` — the annotation
    lives with the storage layout now, so quantized stores' per-expert
    scales automatically ride the same leading ``EXPERT_AXIS`` as the
    leaves they rescale.  Accepts a store or the raw stacked pytree
    (wrapped in a bit-identical ``DenseStore``); returns a
    structure-matching pytree of axis-name tuples either way
    (``launch.sharding.expert_param_specs`` consumes it).
    """
    if isinstance(stacked, ExpertParamStore):
        return stacked.logical_axes()
    return DenseStore.from_stacked(stacked).logical_axes().stacked


def gather_expert_params(stacked, expert_idx: Array):
    """Gather per-sample expert params from a stacked pytree.

    Delegates to ``core.param_store``: ``expert_idx`` is ``(B,)``
    (per-sample routing — leaves become ``(B, ...)``, for a vmapped
    apply) or a scalar (batch-uniform routing — one expert's params, for
    a plain apply).  Accepts a store or the raw stacked pytree.
    """
    store = stacked if isinstance(stacked, ExpertParamStore) \
        else DenseStore.from_stacked(stacked)
    return store.gather(expert_idx)


# ---------------------------------------------------------------------------
# Ragged pair-major apply (dispatch='ragged')
# ---------------------------------------------------------------------------


def _ragged_dense(leaf: dict, x: Array, pe: Array) -> Array:
    """Per-pair expert dense through the one-kernel ragged GEMM.

    ``leaf`` is a ``{"w": ..., "b"?: ...}`` node of a store's
    ``ragged_view()``: weights stay raw (``QuantLeaf`` keeps int8/fp8
    bytes + scales all the way into the kernel's fused-dequant
    epilogue); the bias — tiny — expands through ``dequant_leaf``.
    """
    w = leaf["w"]
    if isinstance(w, QuantLeaf):
        wq, ws = w.q, w.scale
    else:
        wq, ws = w, None
    b = leaf.get("b")
    bias = None if b is None else dequant_leaf(b)
    return ops.ragged_expert_matmul(x, wq, pe, bias=bias, w_scale=ws)


def _layer_view(tree, layer: int):
    """Slice layer ``layer`` from stacked ``(K, L, ...)`` view leaves.

    ``QuantLeaf``s slice their bytes and keep their per-expert scales
    (quantization is per-expert per-leaf, so every layer of a leaf
    shares the same ``(K,)`` scale vector).
    """
    def f(a):
        if isinstance(a, QuantLeaf):
            return QuantLeaf(a.q[:, layer], a.scale, a.compute_dtype)
        return a[:, layer]

    return jax.tree.map(f, tree)


def make_ragged_expert_apply(cfg: DiTConfig):
    """Pair-major ragged forward, matching ``ExpertSpec.ragged_apply_fn``.

    The grouped executor treats ``apply_fn`` as a black box, so it must
    run every guidance replica as an independent row; this adapter sees
    the whole routed step at once and exploits the structure the plan
    guarantees — the ``g`` CFG replicas of a (sample, slot) pair share
    the latent, the timestep AND the routed expert:

    * every dense layer runs as ONE ragged grouped GEMM over all
      resident experts' row groups (``kernels.ops.ragged_expert_matmul``
      walking the plan-derived per-pair expert ids) — no per-expert
      ``lax.switch`` branches and no power-of-two bucket padding;
    * the conditioning-independent prefix (patch/pos embed, timestep
      path, AdaLN-Single modulations, the layer-0 self-attention, which
      precedes the first cross-attention) computes once per *pair* and
      broadcasts to the replicas — conditioning first touches the
      stream at layer-0 cross-attention;
    * quantized stores never materialize: weight leaves reach the GEMM
      as raw int8/fp8 bytes + scales (``QuantLeaf``) and contract on
      quantized operands with int32/f32 accumulation.

    Signature::

        ragged_apply_fn(view, x_p, t_p, cond_pg, expert_ids, g)

    ``view`` = ``ExpertParamStore.ragged_view()``; ``x_p`` ``(P, H, W,
    C)`` one latent per routed pair; ``t_p`` ``(P,)``; ``cond_pg``
    leaves ``(P, g, ...)`` (``text_emb``/``drop_mask`` follow
    ``dit.apply`` semantics exactly — absent text uses the learned null
    embedding, ``drop_mask`` rows substitute it per replica); returns
    ``(P·g, H, W, C)`` float32, pair-major (replicas of a pair
    adjacent).  Bitwise-identical to the grouped executor for dense
    float32 params.
    """
    if cfg.num_classes:
        raise ValueError(
            "ragged apply serves expert prediction only; the router head "
            "(num_classes > 0) goes through the dense apply"
        )

    def ragged_apply(view, x_p, t_p, cond, pe, g):
        p_pairs = x_p.shape[0]
        d = cfg.d_model
        hd = d // cfg.num_heads
        ps = cfg.patch_size

        def pd(leaf, x):
            return _ragged_dense(leaf, x, pe)

        xp = patchify(x_p.astype(cfg.activation_dtype), ps)
        h_r = pd(view["patch_embed"], xp)                  # (P, T, d)
        h_r = h_r + dequant_leaf(view["pos_embed"]["emb"])[pe].astype(
            h_r.dtype
        )

        # Timestep path — replicas share t, so one row per pair.
        idx = to_ddpm_timestep(t_p, cfg.num_timesteps)
        feat = dequant_leaf(view["t_embed"]["table"])[pe, idx]
        ht = jax.nn.silu(pd(view["t_embed"]["mlp1"], feat))
        tau = pd(view["t_embed"]["mlp2"], ht)              # (P, d)

        if cfg.adaln_single:
            hm = jax.nn.silu(pd(view["adaln_single"]["mlp1"], tau))
            c = pd(view["adaln_single"]["mlp2"], hm).reshape(
                p_pairs, 1, 6, d
            )
            mods = jnp.broadcast_to(c, (p_pairs, cfg.num_layers, 6, d))
            mods = mods + dequant_leaf(
                view["adaln_single"]["block_embed"]
            )[pe].astype(mods.dtype)
            mods = jnp.moveaxis(mods, 1, 0)                # (L, P, 6, d)
        else:
            mods = jnp.stack([
                pd(_layer_view(view["adaln_per_block"], l),
                   jax.nn.silu(tau)).reshape(p_pairs, 6, d)
                for l in range(cfg.num_layers)
            ])                                             # (L, P, 6, d)

        def self_attn(bp, h, mod):
            # h: (P, T, d) prefix or (P, g, T, d) expanded; mod (P, 6, d)
            nb = h.ndim - 2
            g_msa, b_msa, a_msa = mod[:, 0], mod[:, 1], mod[:, 2]
            ex = (slice(None),) + (None,) * (nb - 1)
            hn = L.layernorm({}, h) * (1.0 + g_msa[ex + (None,)]) \
                + b_msa[ex + (None,)]
            t_tok = hn.shape[-2]
            q = pd(bp["attn"]["wq"], hn).reshape(
                -1, t_tok, cfg.num_heads, hd)
            k = pd(bp["attn"]["wk"], hn).reshape(
                -1, t_tok, cfg.num_heads, hd)
            v = pd(bp["attn"]["wv"], hn).reshape(
                -1, t_tok, cfg.num_heads, hd)
            pos = jnp.arange(t_tok)
            att = L.chunked_attention(
                q, k, v, q_positions=pos, kv_positions=pos, causal=False,
                chunk_size=cfg.attn_chunk,
            )
            att = pd(bp["attn"]["wo"], att.reshape(h.shape))
            return h + a_msa[ex + (None,)] * att

        # Prefix: layer-0 self-attention on the per-pair representative —
        # exact because cross-attention (the first conditioning-dependent
        # op) runs AFTER self-attention within a block (Eqs. 17→18).
        h_r = self_attn(_layer_view(view["blocks"], 0), h_r, mods[0])
        # Expand to the replicas: pure broadcast, no recompute.
        h = jnp.broadcast_to(h_r[:, None], (p_pairs, g) + h_r.shape[1:])

        if cfg.use_text:
            nulle = dequant_leaf(view["null_text_embed"]["emb"])[pe]
            text_emb = cond.get("text_emb")
            if text_emb is None:
                text_emb = jnp.broadcast_to(
                    nulle[:, None], (p_pairs, g) + nulle.shape[1:]
                )
            else:
                drop = cond.get("drop_mask")
                if drop is not None:
                    text_emb = jnp.where(
                        drop[..., None, None], nulle[:, None], text_emb
                    )
            text = pd(view["text_proj"],
                      text_emb.astype(cfg.activation_dtype))
            t_txt = text.shape[-2]

        for layer in range(cfg.num_layers):
            bp = _layer_view(view["blocks"], layer)
            mod = mods[layer]
            g_mlp, b_mlp, a_mlp = mod[:, 3], mod[:, 4], mod[:, 5]
            if layer > 0:
                h = self_attn(bp, h, mod)                  # Eq. 17
            if cfg.use_text:                               # Eq. 18
                cp = _layer_view(view["cross_attn"], layer)
                t_tok = h.shape[-2]
                hn = L.layernorm({}, h)
                q = pd(cp["wq"], hn).reshape(-1, t_tok, cfg.num_heads, hd)
                k = pd(cp["wk"], text).reshape(
                    -1, t_txt, cfg.num_heads, hd)
                v = pd(cp["wv"], text).reshape(
                    -1, t_txt, cfg.num_heads, hd)
                att = L.chunked_attention(
                    q, k, v, q_positions=jnp.arange(t_tok),
                    kv_positions=jnp.arange(t_txt), causal=False,
                    chunk_size=cfg.attn_chunk,
                )
                h = h + pd(cp["wo"], att.reshape(h.shape))
            hn = L.layernorm({}, h) * (1.0 + g_mlp[:, None, None]) \
                + b_mlp[:, None, None]                     # Eq. 19
            hmid = jax.nn.gelu(pd(bp["mlp"]["w1"], hn))
            h = h + a_mlp[:, None, None] * pd(bp["mlp"]["w2"], hmid)

        mod = pd(view["final_layer"]["mod"], jax.nn.silu(tau))
        shift, scale = jnp.split(mod, 2, axis=-1)
        h = L.layernorm({}, h) * (1.0 + scale[:, None, None]) \
            + shift[:, None, None]
        out = pd(view["final_layer"]["out"], h)
        out = out.reshape((p_pairs * g,) + out.shape[2:])
        return unpatchify(out, ps, cfg.latent_size,
                          cfg.latent_channels).astype(jnp.float32)

    return ragged_apply


def make_expert_apply(cfg: DiTConfig):
    """Adapter matching the ``ExpertSpec.apply_fn`` signature."""

    def apply_fn(params, x_t, t, **cond):
        return apply(cfg, params, x_t, t,
                     text_emb=cond.get("text_emb"),
                     drop_mask=cond.get("drop_mask"))

    return apply_fn


def make_router_fn(cfg: DiTConfig, params):
    """Router posterior p(k | x_t, t) (Eq. 2)."""

    def router_fn(x_t, t):
        logits = apply(cfg, params, x_t, t)
        return jax.nn.softmax(logits, axis=-1)

    return router_fn
