"""Decoder-only transformer backbone (dense GQA / MoE / VLM-prefix).

Covers the assigned architectures: deepseek-coder-33b, deepseek-67b,
stablelm-1.6b, internlm2-1.8b (dense GQA), mixtral-8x7b / 8x22b (MoE with
sliding-window attention) and paligemma-3b (vision-prefix LM; the SigLIP
frontend is a stub that supplies patch embeddings).

Layers are homogeneous and *scanned* (stacked params + ``jax.lax.scan``) so
62–95-layer configs keep HLO size and compile time bounded.

Three entry points per model:
  * ``forward_train(params, tokens, ...) -> (logits, aux)``
  * ``prefill(params, tokens, ...) -> (last_logits, cache)``
  * ``decode_step(params, cache, token, pos) -> (logits, cache)``
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import LMConfig
from repro.launch.fsdp import maybe_unshard

Array = jax.Array


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _block_init(cfg: LMConfig, key) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.gqa_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd,
            cfg.param_dtype,
        ),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if cfg.num_experts:
        p["moe"] = L.moe_init(
            ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.param_dtype
        )
    else:
        p["ffn"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def init(cfg: LMConfig, key) -> dict:
    k_emb, k_blocks, k_out, k_vis = jax.random.split(key, 4)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: _block_init(cfg, k))(block_keys)
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                              cfg.param_dtype),
        "blocks": blocks,
        "ln_final": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                cfg.param_dtype),
    }
    if cfg.vision_prefix_len:
        # Projector from stubbed SigLIP patch embeddings into d_model.
        params["vision_proj"] = L.dense_init(
            k_vis, cfg.d_model, cfg.d_model, cfg.param_dtype
        )
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _attn_full(cfg: LMConfig, p, h, positions, prefix_len: int, window: int):
    hd = cfg.resolved_head_dim
    q, k, v = L.gqa_project(p["attn"], h, cfg.num_heads, cfg.num_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    out = L.chunked_attention(
        q, k, v,
        q_positions=positions, kv_positions=positions,
        causal=True, window=window, prefix_len=prefix_len,
        chunk_size=cfg.attn_chunk, kv_chunk=cfg.attn_kv_chunk,
        f32_softmax=cfg.attn_f32_softmax,
    )
    b, s = h.shape[:2]
    y = L.dense(p["attn"]["wo"], out.reshape(b, s, cfg.num_heads * hd))
    return y, (k, v)


def _block_apply(
    cfg: LMConfig, p, h, positions, *, prefix_len: int = 0
):
    window = cfg.sliding_window
    a, kv = _attn_full(cfg, p, L.rmsnorm(p["ln_attn"], h, cfg.norm_eps),
                       positions, prefix_len, window)
    h = h + a
    hn = L.rmsnorm(p["ln_ffn"], h, cfg.norm_eps)
    if cfg.num_experts:
        f, aux = L.moe_apply(
            p["moe"], hn,
            num_experts_per_tok=cfg.num_experts_per_tok,
            capacity_factor=cfg.moe_capacity_factor,
            impl=cfg.moe_impl,
        )
    else:
        f, aux = L.swiglu(p["ffn"], hn), jnp.zeros((), jnp.float32)
    return h + f, kv, aux


def _embed_inputs(cfg: LMConfig, params, tokens: Array,
                  vision_embeds: Array | None) -> tuple[Array, int]:
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)
    prefix = 0
    if cfg.vision_prefix_len and vision_embeds is not None:
        vis = L.dense(params["vision_proj"],
                      vision_embeds.astype(cfg.activation_dtype))
        h = jnp.concatenate([vis, h], axis=1)
        prefix = vision_embeds.shape[1]
    return h, prefix


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward_train(
    cfg: LMConfig,
    params,
    tokens: Array,
    *,
    vision_embeds: Array | None = None,
) -> tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits, moe_aux_loss)."""
    h, prefix = _embed_inputs(cfg, params, tokens, vision_embeds)
    s = h.shape[1]
    positions = jnp.arange(s)

    def body(carry, block_p):
        h, aux = carry
        block_p = maybe_unshard(block_p)
        h, _, a = _block_apply(cfg, block_p, h, positions, prefix_len=prefix)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    h = L.rmsnorm(params["ln_final"], h, cfg.norm_eps)
    if prefix:
        h = h[:, prefix:]
    logits = L.dense(params["unembed"], h)
    return logits, aux / max(cfg.num_layers, 1)


def loss_fn(
    cfg: LMConfig,
    params,
    tokens: Array,
    labels: Array,
    *,
    vision_embeds: Array | None = None,
) -> tuple[Array, dict]:
    logits, aux = forward_train(cfg, params, tokens,
                                vision_embeds=vision_embeds)
    ce = cross_entropy(logits, labels, chunk=cfg.logits_chunk)
    loss = ce + cfg.aux_loss_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


def cross_entropy(logits: Array, labels: Array, *, chunk: int = 0) -> Array:
    """Token-mean CE.  ``chunk`` > 0 evaluates the softmax over sequence
    chunks (memory optimization for huge-vocab archs; §Perf lever)."""
    if chunk and logits.shape[1] > chunk:
        b, s, v = logits.shape
        n = s // chunk

        def one(c):
            lg = jax.lax.dynamic_slice_in_dim(logits, c * chunk, chunk, 1)
            lb = jax.lax.dynamic_slice_in_dim(labels, c * chunk, chunk, 1)
            return _ce(lg, lb)

        return jnp.mean(jax.lax.map(one, jnp.arange(n)))
    return _ce(logits, labels)


def _ce(logits: Array, labels: Array) -> Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with (ring-buffer) KV cache
# ---------------------------------------------------------------------------


def make_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """KV cache pytree.  ``max_len`` is the window size for SWA decode."""
    hd = cfg.resolved_head_dim
    dtype = dtype or cfg.activation_dtype
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def prefill(
    cfg: LMConfig,
    params,
    tokens: Array,
    *,
    vision_embeds: Array | None = None,
) -> tuple[Array, dict]:
    """Run the prompt, return last-token logits + a full KV cache."""
    h, prefix = _embed_inputs(cfg, params, tokens, vision_embeds)
    b, s = h.shape[:2]
    positions = jnp.arange(s)

    def body(h, block_p):
        block_p = maybe_unshard(block_p)
        h, (k, v), _ = _block_apply(cfg, block_p, h, positions,
                                    prefix_len=prefix)
        return h, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(params["ln_final"], h[:, -1:], cfg.norm_eps)
    logits = L.dense(params["unembed"], h)[:, 0]
    cache = {
        "k": ks, "v": vs,
        "pos": jnp.broadcast_to(positions[None], (b, s)),
    }
    return logits, cache


def decode_step(
    cfg: LMConfig,
    params,
    cache: dict,
    token: Array,
    pos: Array,
) -> tuple[Array, dict]:
    """One decode step.

    Args:
      cache: from :func:`make_cache` / :func:`prefill`; ring-buffer when
        ``cfg.decode_window`` > 0 (slot = pos % window).
      token: (B, 1) int32 new token ids.
      pos: (B,) absolute position of the new token.

    Returns (logits (B, V), updated cache).
    """
    hd = cfg.resolved_head_dim
    h = L.embed(params["embed"], token, cfg.activation_dtype)   # (B, 1, D)
    w = cache["k"].shape[2]
    slot = (pos % w) if cfg.decode_window else jnp.minimum(pos, w - 1)
    window = cfg.decode_window or cfg.sliding_window
    new_pos = cache["pos"].at[jnp.arange(h.shape[0]), slot].set(pos)

    def body(h, xs):
        block_p, k_c, v_c = xs
        block_p = maybe_unshard(block_p)
        hn = L.rmsnorm(block_p["ln_attn"], h, cfg.norm_eps)
        q, k, v = L.gqa_project(
            block_p["attn"], hn, cfg.num_heads, cfg.num_kv_heads, hd
        )
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
        bidx = jnp.arange(h.shape[0])
        k_c = k_c.at[bidx, slot].set(k[:, 0])
        v_c = v_c.at[bidx, slot].set(v[:, 0])
        out = L.decode_attention(
            q, k_c, v_c, q_position=pos, kv_positions=new_pos, window=window
        )
        a = L.dense(block_p["attn"]["wo"],
                    out.reshape(h.shape[0], 1, cfg.num_heads * hd))
        h = h + a
        hn = L.rmsnorm(block_p["ln_ffn"], h, cfg.norm_eps)
        if cfg.num_experts:
            f, _ = L.moe_apply(
                block_p["moe"], hn,
                num_experts_per_tok=cfg.num_experts_per_tok,
                capacity_factor=cfg.moe_capacity_factor,
                impl=cfg.moe_impl,
            )
        else:
            f = L.swiglu(block_p["ffn"], hn)
        return h + f, (k_c, v_c)

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"],
                                         cache["v"]))
    h = L.rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = L.dense(params["unembed"], h)[:, 0]
    return logits, {"k": ks, "v": vs, "pos": new_pos}
