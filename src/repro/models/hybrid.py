"""Zamba2-style hybrid backbone [arXiv:2411.15242].

Mamba2 trunk with a single *shared* attention block (one parameter set)
applied after every ``attn_every`` mamba layers — Zamba2's key trick for
getting attention quality at SSM parameter cost.  Each application of the
shared block sees a different input, so decode keeps one KV cache *per
application*.

Layout for zamba2-2.7b: 54 mamba layers, shared GQA block every 6 layers
(9 applications).  Structured as an outer ``lax.scan`` over groups with an
inner scan over each group's mamba layers; the shared block's params are
closed over (replicated, single copy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.config import LMConfig
from repro.launch.fsdp import maybe_unshard

Array = jax.Array


def _shared_attn_init(cfg: LMConfig, key) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.gqa_init(ks[0], cfg.d_model, cfg.num_heads,
                           cfg.num_kv_heads, hd, cfg.param_dtype),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "ffn": L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def num_groups(cfg: LMConfig) -> int:
    assert cfg.attn_every and cfg.num_layers % cfg.attn_every == 0
    return cfg.num_layers // cfg.attn_every


def init(cfg: LMConfig, key) -> dict:
    k_emb, k_blocks, k_shared, k_out = jax.random.split(key, 4)
    per = cfg.attn_every
    g = num_groups(cfg)
    blocks = jax.vmap(
        lambda k: {
            "ln": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mixer": M.mixer_init(cfg, k),
        }
    )(jax.random.split(k_blocks, cfg.num_layers))
    # Reshape stacked layer params to (groups, per_group, ...).
    blocks = jax.tree.map(
        lambda x: x.reshape((g, per) + x.shape[1:]), blocks
    )
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                              cfg.param_dtype),
        "blocks": blocks,
        "shared_attn": _shared_attn_init(cfg, k_shared),
        "ln_final": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                cfg.param_dtype),
    }


def _shared_attn_apply(cfg: LMConfig, p, h: Array, positions: Array) -> Array:
    hd = cfg.resolved_head_dim
    hn = L.rmsnorm(p["ln_attn"], h, cfg.norm_eps)
    q, k, v = L.gqa_project(p["attn"], hn, cfg.num_heads, cfg.num_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    out = L.chunked_attention(
        q, k, v, q_positions=positions, kv_positions=positions,
        causal=True, window=cfg.sliding_window, chunk_size=cfg.attn_chunk, kv_chunk=cfg.attn_kv_chunk,
        f32_softmax=cfg.attn_f32_softmax,
    )
    b, s = h.shape[:2]
    h = h + L.dense(p["attn"]["wo"], out.reshape(b, s, cfg.num_heads * hd))
    h = h + L.swiglu(p["ffn"], L.rmsnorm(p["ln_ffn"], h, cfg.norm_eps))
    return h


def forward_train(cfg: LMConfig, params, tokens: Array) -> tuple[Array, Array]:
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)
    positions = jnp.arange(tokens.shape[1])

    def inner(h, block_p):
        block_p = maybe_unshard(block_p)
        y, _ = M.mixer_apply(
            cfg, block_p["mixer"], L.rmsnorm(block_p["ln"], h, cfg.norm_eps)
        )
        return h + y, None

    def outer(h, group_p):
        h, _ = jax.lax.scan(inner, h, group_p)
        h = _shared_attn_apply(cfg, params["shared_attn"], h, positions)
        return h, None

    outer_fn = jax.checkpoint(outer) if cfg.remat else outer
    h, _ = jax.lax.scan(outer_fn, h, params["blocks"])
    h = L.rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = L.dense(params["unembed"], h)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: LMConfig, params, tokens: Array, labels: Array):
    from repro.models.transformer import cross_entropy

    logits, _ = forward_train(cfg, params, tokens)
    ce = cross_entropy(logits, labels, chunk=cfg.logits_chunk)
    return ce, {"ce": ce}


def make_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    """Hybrid cache: per-layer SSM states + per-application KV cache."""
    g = num_groups(cfg)
    hd = cfg.resolved_head_dim
    conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_ch),
            cfg.activation_dtype,
        ),
        "ssm": jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_nheads, cfg.ssm_headdim,
             cfg.ssm_state),
            jnp.float32,
        ),
        "k": jnp.zeros((g, batch, max_len, cfg.num_kv_heads, hd),
                       cfg.activation_dtype),
        "v": jnp.zeros((g, batch, max_len, cfg.num_kv_heads, hd),
                       cfg.activation_dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def prefill(cfg: LMConfig, params, tokens: Array) -> tuple[Array, dict]:
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)
    b, s = tokens.shape
    positions = jnp.arange(s)
    hd = cfg.resolved_head_dim

    def inner(h, block_p):
        block_p = maybe_unshard(block_p)
        y, (conv_tail, state) = M.mixer_apply(
            cfg, block_p["mixer"], L.rmsnorm(block_p["ln"], h, cfg.norm_eps)
        )
        return h + y, (conv_tail, state)

    def outer(h, group_p):
        h, (convs, states) = jax.lax.scan(inner, h, group_p)
        p = params["shared_attn"]
        hn = L.rmsnorm(p["ln_attn"], h, cfg.norm_eps)
        q, k, v = L.gqa_project(p["attn"], hn, cfg.num_heads,
                                cfg.num_kv_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        out = L.chunked_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=True, window=cfg.sliding_window, chunk_size=cfg.attn_chunk, kv_chunk=cfg.attn_kv_chunk,
        f32_softmax=cfg.attn_f32_softmax,
        )
        h = h + L.dense(p["attn"]["wo"],
                        out.reshape(b, s, cfg.num_heads * hd))
        h = h + L.swiglu(p["ffn"], L.rmsnorm(p["ln_ffn"], h, cfg.norm_eps))
        return h, (convs, states, k, v)

    h, (convs, states, ks, vs) = jax.lax.scan(outer, h, params["blocks"])
    hl = L.rmsnorm(params["ln_final"], h[:, -1:], cfg.norm_eps)
    logits = L.dense(params["unembed"], hl)[:, 0]
    g, per = num_groups(cfg), cfg.attn_every
    cache = {
        "conv": convs.reshape((g * per,) + convs.shape[2:]),
        "ssm": states.reshape((g * per,) + states.shape[2:]),
        "k": ks, "v": vs,
        "pos": jnp.broadcast_to(positions[None], (b, s)),
    }
    return logits, cache


def decode_step(
    cfg: LMConfig, params, cache: dict, token: Array, pos: Array
) -> tuple[Array, dict]:
    h = L.embed(params["embed"], token, cfg.activation_dtype)
    b = token.shape[0]
    g, per = num_groups(cfg), cfg.attn_every
    hd = cfg.resolved_head_dim
    w = cache["k"].shape[2]
    window = cfg.decode_window or cfg.sliding_window
    slot = (pos % w) if (cfg.decode_window or window) else jnp.minimum(pos, w - 1)
    new_pos = cache["pos"].at[jnp.arange(b), slot].set(pos)

    conv = jax.tree.map(lambda x: x.reshape((g, per) + x.shape[1:]),
                        cache["conv"])
    ssm = jax.tree.map(lambda x: x.reshape((g, per) + x.shape[1:]),
                       cache["ssm"])

    def inner(h, xs):
        block_p, conv_c, ssm_c = xs
        block_p = maybe_unshard(block_p)
        y, (conv_tail, state) = M.mixer_apply(
            cfg, block_p["mixer"], L.rmsnorm(block_p["ln"], h, cfg.norm_eps),
            conv_state=conv_c, ssm_state=ssm_c, mode="decode",
        )
        return h + y, (conv_tail, state)

    def outer(h, xs):
        group_p, conv_g, ssm_g, k_c, v_c = xs
        h, (convs, states) = jax.lax.scan(inner, h, (group_p, conv_g, ssm_g))
        p = params["shared_attn"]
        hn = L.rmsnorm(p["ln_attn"], h, cfg.norm_eps)
        q, k, v = L.gqa_project(p["attn"], hn, cfg.num_heads,
                                cfg.num_kv_heads, hd)
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
        bidx = jnp.arange(b)
        k_c = k_c.at[bidx, slot].set(k[:, 0])
        v_c = v_c.at[bidx, slot].set(v[:, 0])
        out = L.decode_attention(
            q, k_c, v_c, q_position=pos, kv_positions=new_pos, window=window
        )
        h = h + L.dense(p["attn"]["wo"],
                        out.reshape(b, 1, cfg.num_heads * hd))
        h = h + L.swiglu(p["ffn"], L.rmsnorm(p["ln_ffn"], h, cfg.norm_eps))
        return h, (convs, states, k_c, v_c)

    h, (convs, states, ks, vs) = jax.lax.scan(
        outer, h, (params["blocks"], conv, ssm, cache["k"], cache["v"])
    )
    h = L.rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = L.dense(params["unembed"], h)[:, 0]
    cache = {
        "conv": convs.reshape((g * per,) + convs.shape[2:]),
        "ssm": states.reshape((g * per,) + states.shape[2:]),
        "k": ks, "v": vs, "pos": new_pos,
    }
    return logits, cache
