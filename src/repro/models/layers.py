"""Shared neural-net primitives for the model zoo.

Pure-function style: every layer is an ``init(key, ...) -> params`` plus an
``apply(params, x, ...) -> y`` pair operating on plain dict pytrees (no flax
dependency).  Attention is implemented with a chunked online-softmax scan —
the XLA analogue of flash attention — so that 32k-token prefill lowers
without materializing an S×S logits tensor.  The Pallas kernel in
``repro/kernels/flash_attention.py`` is the TPU fast path; this module is the
semantics-defining reference used on CPU and in dry-runs.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale=None):
    scale = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    return {
        "w": (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)
    }


def dense_init_b(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale=None):
    p = dense_init(key, in_dim, out_dim, dtype, scale)
    p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def zeros_dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32):
    del key
    return {"w": jnp.zeros((in_dim, out_dim), dtype)}


def dense(params, x: Array) -> Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"emb": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


def embed(params, ids: Array, dtype=None) -> Array:
    tbl = params["emb"]
    if dtype is not None:
        tbl = tbl.astype(dtype)
    return jnp.take(tbl, ids, axis=0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32, affine: bool = True):
    if not affine:
        return {}
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if "scale" in params:
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """Rotate pairs.  ``x``: (B, S, H, D); ``positions``: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)              # (D/2,)
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None] * freqs[None, None, :]     # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — chunked online softmax (flash-style, pure XLA)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_expand(q: Array, num_kv: int) -> Array:
    """(B, S, Hq, D) -> (B, S, Hkv, G, D) grouping query heads per kv head."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, num_kv, hq // num_kv, d)


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    q_positions: Array,
    kv_positions: Array,
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    kv_valid: Array | None = None,
    chunk_size: int = 512,
    kv_chunk: int = 0,
    f32_softmax: bool = True,
    softmax_scale: float | None = None,
) -> Array:
    """Memory-efficient attention with GQA, causality, SWA and prefix-LM.

    Args:
      q: (B, Sq, Hq, D);  k/v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
      q_positions / kv_positions: absolute positions, (Sq,)/(Skv,) or (B, ·).
      causal: apply ``kv_pos <= q_pos``.
      window: if > 0, also require ``q_pos - kv_pos < window`` (SWA).
      prefix_len: positions < prefix_len attend bidirectionally (PaliGemma
        prefix-LM); only meaningful with ``causal=True``.
      kv_valid: optional (B, Skv) bool mask of valid cache slots.
      chunk_size: query-block length for the online-softmax scan.
      kv_chunk: if > 0, additionally block the KV axis with an
        online-softmax accumulator (flash-attention semantics in pure
        XLA): per-(q,kv)-block logits only, never a (chunk, Skv) f32
        tensor.  This is the §Perf 'online' attention variant; 0 keeps
        the single-level baseline.

    Never materializes an (Sq, Skv) tensor larger than
    (chunk, kv_chunk or Skv).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)

    if q_positions.ndim == 1:
        q_positions = jnp.broadcast_to(q_positions[None], (b, sq))
    if kv_positions.ndim == 1:
        kv_positions = jnp.broadcast_to(kv_positions[None], (b, skv))

    # Pad Sq to a multiple of the chunk size.
    n_chunks = max(1, -(-sq // chunk_size))
    pad = n_chunks * chunk_size - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))

    qg = _gqa_expand(q, hkv)                               # (B, S, Hkv, G, D)
    qg = jnp.moveaxis(qg, 1, -2)                           # (B, Hkv, G, S, D)
    qg = qg.reshape(b, hkv, g, n_chunks, chunk_size, d)
    qpos = q_positions.reshape(b, n_chunks, chunk_size)

    kT = jnp.moveaxis(k, 1, 3)                             # (B, Hkv, D, Skv)
    vv = jnp.moveaxis(v, 1, 2)                             # (B, Hkv, Skv, D)

    # No masking at all (encoder/cross attention with no cache): skip the
    # where() — it materializes a full logits-sized copy in unfused HLO.
    unmasked = not causal and not window and kv_valid is None

    def _block_mask(qp, kp):
        """(B, C) q-positions × (B, K) kv-positions -> (B, C, K) bool."""
        mask = jnp.ones((qp.shape[0], qp.shape[1], kp.shape[1]), bool)
        if causal:
            cmask = kp[:, None, :] <= qp[:, :, None]
            if prefix_len:
                bidir = (kp[:, None, :] < prefix_len) & (
                    qp[:, :, None] < prefix_len
                )
                cmask = cmask | bidir
            mask = mask & cmask
        if window:
            mask = mask & (qp[:, :, None] - kp[:, None, :] < window)
        return mask

    # Softmax-chain precision: f32 (default) materializes the (chunk, Skv)
    # logits/probs chain in f32; bf16 halves the dominant HBM traffic of
    # long-sequence prefill (§Perf iteration) — the MXU still accumulates
    # the dots in f32 internally, and the row max/denominator stay f32.
    sdtype = jnp.float32 if f32_softmax else jnp.bfloat16
    neg = jnp.asarray(NEG_INF if f32_softmax else -3e38, sdtype)

    def one_chunk(c):
        qc = qg[:, :, :, c]                                # (B, Hkv, G, C, D)
        qp = qpos[:, c]                                    # (B, C)
        logits = jnp.einsum(
            "bhgcd,bhds->bhgcs", qc.astype(sdtype), kT.astype(sdtype),
            preferred_element_type=sdtype,
        ) * jnp.asarray(scale, sdtype)                     # (B,Hkv,G,C,Skv)
        if not unmasked:
            mask = _block_mask(qp, kv_positions)
            if kv_valid is not None:
                mask = mask & kv_valid[:, None, :]
            logits = jnp.where(mask[:, None, None], logits, neg)
        m = jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True)
        m = jnp.maximum(m, NEG_INF)
        p = jnp.exp((logits - m.astype(sdtype)).astype(sdtype))
        denom = jnp.maximum(
            jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True), 1e-30
        )
        out = jnp.einsum(
            "bhgcs,bhsd->bhgcd", p, vv.astype(sdtype),
            preferred_element_type=jnp.float32,
        ) / denom
        return out                                         # (B,Hkv,G,C,D)

    def one_chunk_online(c):
        """Double-blocked online softmax (flash semantics in XLA).

        Inner lax.scan over kv blocks carries (m, l, acc); per-step
        materialization is only (B, Hkv, G, C, kv_chunk)."""
        qc = qg[:, :, :, c].astype(jnp.float32)            # (B,Hkv,G,C,D)
        qp = qpos[:, c]                                    # (B, C)
        nk = skv // kv_chunk
        kT_blk = kT.reshape(b, hkv, d, nk, kv_chunk)
        vv_blk = vv.reshape(b, hkv, nk, kv_chunk, d)
        kp_blk = kv_positions.reshape(b, nk, kv_chunk)
        valid_blk = (kv_valid.reshape(b, nk, kv_chunk)
                     if kv_valid is not None else None)

        def kv_step(carry, j):
            m, l, acc = carry
            logits = jnp.einsum(
                "bhgcd,bhdk->bhgck", qc,
                kT_blk[:, :, :, j].astype(jnp.float32),
            ) * scale
            mask = _block_mask(qp, kp_blk[:, j])
            if valid_blk is not None:
                mask = mask & valid_blk[:, j][:, None, :]
            logits = jnp.where(mask[:, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, -1, keepdims=True))
            p = jnp.exp(logits - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, -1, keepdims=True)
            acc = alpha * acc + jnp.einsum(
                "bhgck,bhkd->bhgcd", p,
                vv_blk[:, :, j].astype(jnp.float32),
            )
            return (m_new, l, acc), None

        init = (
            jnp.full((b, hkv, g, chunk_size, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, chunk_size, 1), jnp.float32),
            jnp.zeros((b, hkv, g, chunk_size, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)                 # (B,Hkv,G,C,D)

    if kv_chunk and skv % kv_chunk == 0 and skv > kv_chunk:
        one_chunk = one_chunk_online

    outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))    # (N,B,Hkv,G,C,D)
    out = jnp.moveaxis(outs, 0, 3)                         # (B,Hkv,G,N,C,D)
    out = out.reshape(b, hkv, g, n_chunks * chunk_size, d)
    out = jnp.moveaxis(out, 3, 1)                          # (B,S,Hkv,G,D)
    out = out.reshape(b, n_chunks * chunk_size, hq, d)
    if pad:
        out = out[:, :sq]
    return out.astype(q.dtype)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    *,
    q_position: Array,
    kv_positions: Array,
    window: int = 0,
    softmax_scale: float | None = None,
) -> Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    Args:
      q: (B, 1, Hq, D).
      k_cache/v_cache: (B, Skv, Hkv, D).
      q_position: (B,) absolute position of the new token.
      kv_positions: (B, Skv) absolute positions stored in each slot; slots
        with position < 0 or > q_position or outside the window are masked.
    """
    b, skv, hkv, d = k_cache.shape
    hq = q.shape[2]
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", qg.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * scale
    valid = (kv_positions >= 0) & (kv_positions <= q_position[:, None])
    if window:
        valid = valid & (q_position[:, None] - kv_positions < window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + attention)
# ---------------------------------------------------------------------------


def gqa_init(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    dtype=jnp.float32,
    qkv_bias: bool = False,
):
    ks = jax.random.split(key, 4)
    mk = dense_init_b if qkv_bias else dense_init
    return {
        "wq": mk(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": mk(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": mk(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }


def gqa_project(params, x: Array, num_heads: int, num_kv_heads: int,
                head_dim: int):
    b, s, _ = x.shape
    q = dense(params["wq"], x).reshape(b, s, num_heads, head_dim)
    k = dense(params["wk"], x).reshape(b, s, num_kv_heads, head_dim)
    v = dense(params["wv"], x).reshape(b, s, num_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Feed-forward variants
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def swiglu(params, x: Array) -> Array:
    return dense(
        params["w_down"],
        jax.nn.silu(dense(params["w_gate"], x)) * dense(params["w_up"], x),
    )


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "w1": dense_init_b(ks[0], d_model, d_ff, dtype),
        "w2": dense_init_b(ks[1], d_ff, d_model, dtype),
    }


def gelu_mlp(params, x: Array) -> Array:
    return dense(params["w2"], jax.nn.gelu(dense(params["w1"], x)))


# ---------------------------------------------------------------------------
# MoE (Mixtral-style top-k with capacity + scatter dispatch)
# ---------------------------------------------------------------------------


def moe_init(key, d_model: int, d_ff: int, num_experts: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d_model)
    sf = 1.0 / math.sqrt(d_ff)
    return {
        "router": dense_init(ks[0], d_model, num_experts, jnp.float32),
        "w_gate": (
            jax.random.normal(ks[1], (num_experts, d_model, d_ff)) * sc
        ).astype(dtype),
        "w_up": (
            jax.random.normal(ks[2], (num_experts, d_model, d_ff)) * sc
        ).astype(dtype),
        "w_down": (
            jax.random.normal(ks[3], (num_experts, d_ff, d_model)) * sf
        ).astype(dtype),
    }


def moe_apply(
    params,
    x: Array,
    *,
    num_experts_per_tok: int = 2,
    capacity_factor: float = 1.25,
    impl: str = "dropping",
) -> tuple[Array, Array]:
    """Top-k routed MoE FFN.

    Returns ``(y, aux_loss)`` where ``aux_loss`` is the Switch/Mixtral
    load-balance loss ``E * sum_e f_e * p_e``.

    ``impl='dropping'``: GShard-style capacity dispatch via scatter — only
    top-k expert FLOPs are spent (plus drops).  ``impl='dense'``: every
    expert processes every token (upper-bound FLOPs; used as the naive
    baseline in §Perf).
    """
    b, s, d = x.shape
    e = params["w_gate"].shape[0]
    k = num_experts_per_tok
    xf = x.reshape(b * s, d)
    t = xf.shape[0]

    logits = dense(params["router"], xf.astype(jnp.float32))    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                        # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch eq. 4): E * sum_e f_e p_e.
    sel_mask = jax.nn.one_hot(tope[:, 0], e, dtype=jnp.float32)
    f = sel_mask.mean(axis=0)
    p = probs.mean(axis=0)
    aux = e * jnp.sum(f * p)

    def ffn_all(h):     # (..., d) -> per-expert ffn, h has leading E axis
        g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"].astype(h.dtype))
        u = jnp.einsum("ecd,edf->ecf", h, params["w_up"].astype(h.dtype))
        return jnp.einsum(
            "ecf,efd->ecd", jax.nn.silu(g) * u,
            params["w_down"].astype(h.dtype),
        )

    if impl in ("dense", "dense_scan"):
        # Every expert processes every token (upper-bound FLOPs: E/k× the
        # active compute), weighted by its gate.  Sharding-friendly: no
        # scatter/gather, tokens stay batch-sharded, expert weights stay
        # (data, model)-sharded.  'dense_scan' accumulates expert-by-expert
        # so peak memory is one (T, F) buffer instead of (E, T, F).
        w_full = jnp.zeros((t, e), xf.dtype)
        w_full = w_full.at[jnp.arange(t)[:, None], tope].set(
            topw.astype(xf.dtype)
        )
        if impl == "dense":
            y_all = ffn_all(jnp.broadcast_to(xf[None], (e, t, d)))
            y = jnp.einsum("etd,te->td", y_all, w_full)
            return y.reshape(b, s, d), aux

        def one_expert(y, packed):
            wg, wu, wd, we = packed
            g = xf @ wg.astype(xf.dtype)
            u = xf @ wu.astype(xf.dtype)
            yo = (jax.nn.silu(g) * u) @ wd.astype(xf.dtype)
            return y + yo * we[:, None], None

        y, _ = jax.lax.scan(
            one_expert, jnp.zeros_like(xf),
            (params["w_gate"], params["w_up"], params["w_down"],
             jnp.moveaxis(w_full, 0, 1)),
        )
        return y.reshape(b, s, d), aux

    if impl == "dense_fused":
        # §Perf variant: batch all experts into single dots so the
        # row-parallel (F-sharded) contraction incurs ONE partial-sum
        # all-reduce per layer instead of one per expert (dense_scan's
        # per-iteration matmul each triggers its own reduction).  Peak
        # activation is (E, T, F/shards) — fine at F-sharded widths.
        w_full = jnp.zeros((t, e), xf.dtype)
        w_full = w_full.at[jnp.arange(t)[:, None], tope].set(
            topw.astype(xf.dtype)
        )
        g = jnp.einsum("td,edf->etf", xf, params["w_gate"].astype(xf.dtype))
        u = jnp.einsum("td,edf->etf", xf, params["w_up"].astype(xf.dtype))
        z = jax.nn.silu(g) * u
        # single contraction over (e, f): weights folded in first so the
        # all-reduce output is only (T, D).
        y = jnp.einsum("etf,efd,te->td", z,
                       params["w_down"].astype(xf.dtype), w_full)
        return y.reshape(b, s, d), aux

    # --- capacity dispatch ---
    cap = int(max(1, math.ceil(t * k / e * capacity_factor)))
    flat_e = tope.reshape(-1)                                  # (T*k,)
    flat_w = topw.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    # position within expert: cumulative count of earlier assignments.
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                  # exclusive
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    slot = jnp.where(keep, flat_e * cap + flat_pos, e * cap)   # overflow slot
    buf = jnp.zeros((e * cap + 1, d), xf.dtype)
    buf = buf.at[slot].add(xf[flat_t])
    y_buf = ffn_all(buf[: e * cap].reshape(e, cap, d))
    y_flat = y_buf.reshape(e * cap, d)
    y_tok = jnp.where(
        keep[:, None], jnp.take(y_flat, jnp.minimum(slot, e * cap - 1), axis=0), 0.0
    )
    y = jnp.zeros_like(xf)
    y = y.at[flat_t].add(y_tok * flat_w[:, None].astype(xf.dtype))
    return y.reshape(b, s, d), aux
