"""Architecture configuration dataclasses (model zoo + DiT experts)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Sequence-model backbone config covering all 6 assigned families.

    ``arch_type``: dense | moe | ssm | hybrid | audio | vlm.
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 2
    moe_capacity_factor: float = 1.25
    moe_impl: str = "dropping"            # 'dropping' | 'dense'
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2-style shared attention) ---
    attn_every: int = 0                   # shared attn block period; 0 = none
    # --- attention variant ---
    sliding_window: int = 0               # 0 = full attention
    decode_window: int = 0                # SWA window used only for decode
    rope_theta: float = 10000.0
    # --- enc-dec (whisper backbone) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500           # mel-frame embeddings (stub)
    # --- VLM (paligemma backbone) ---
    vision_prefix_len: int = 0            # SigLIP patch embeddings (stub)
    # --- numerics ---
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    activation_dtype: Any = jnp.float32
    attn_chunk: int = 512
    #: > 0 enables double-blocked online-softmax attention (flash
    #: semantics in pure XLA) with this kv-block size — §Perf variant.
    attn_kv_chunk: int = 0
    #: False keeps the softmax chain in bf16 (f32 row max/denominator) —
    #: §Perf lever halving long-context attention HBM traffic.
    attn_f32_softmax: bool = True
    logits_chunk: int = 0                 # 0 = unchunked loss
    # --- training ---
    remat: bool = False
    aux_loss_weight: float = 0.01
    #: shard weight matrices over the data axis too (explicit FSDP via the
    #: launch.fsdp gather-before-use hook).  Needed for archs whose
    #: TP-only train state exceeds HBM (>= ~8B params on v5e).
    fsdp_params: bool = False
    source: str = ""                      # citation for the config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    def reduced(self, **overrides) -> "LMConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        upd: dict[str, Any] = dict(
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=(d // heads) if heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            decode_window=min(self.decode_window, 64)
            if self.decode_window else 0,
            encoder_seq_len=min(self.encoder_seq_len, 16),
            vision_prefix_len=min(self.vision_prefix_len, 8),
            attn_chunk=64,
            param_dtype=jnp.float32,
            activation_dtype=jnp.float32,
            remat=False,
        )
        if self.num_experts:
            upd["num_experts"] = min(self.num_experts, 4)
        if self.num_encoder_layers:
            upd["num_encoder_layers"] = 2
        if self.ssm_state:
            upd["ssm_state"] = min(self.ssm_state, 16)
            upd["ssm_headdim"] = 32
            upd["ssm_chunk"] = 16
        if self.attn_every:
            upd["attn_every"] = 1
        upd.update(overrides)
        return dataclasses.replace(self, **upd)


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    """Diffusion Transformer expert (paper §2.5 / §6.2)."""

    name: str
    num_layers: int
    d_model: int
    num_heads: int
    patch_size: int = 2
    latent_size: int = 32                # 32x32x4 VAE latents
    latent_channels: int = 4
    mlp_ratio: float = 4.0
    text_dim: int = 768                  # frozen CLIP ViT-L/14
    text_len: int = 77
    use_text: bool = True                # router variant sets False
    num_classes: int = 0                 # router classifier head size
    adaln_single: bool = True            # PixArt-α AdaLN-Single (Eq. 14-16)
    param_dtype: Any = jnp.float32
    activation_dtype: Any = jnp.float32
    num_timesteps: int = 1000            # discrete embedding table (Eq. 21)
    attn_chunk: int = 256

    @property
    def num_tokens(self) -> int:
        return (self.latent_size // self.patch_size) ** 2

    @property
    def d_ff(self) -> int:
        return int(self.d_model * self.mlp_ratio)

    def reduced(self, **overrides) -> "DiTConfig":
        upd = dict(
            num_layers=2,
            d_model=128,
            num_heads=4,
            latent_size=8,
            text_dim=32,
            text_len=8,
            attn_chunk=32,
        )
        upd.update(overrides)
        return dataclasses.replace(self, **upd)


# Canonical paper architectures (§6.2, §6.3).
def dit_xl2(**kw) -> DiTConfig:
    return DiTConfig(
        name="dit-xl2", num_layers=28, d_model=1152, num_heads=16, **kw
    )


def dit_b2(**kw) -> DiTConfig:
    return DiTConfig(
        name="dit-b2", num_layers=12, d_model=768, num_heads=12, **kw
    )


def router_b2(num_clusters: int = 8, **kw) -> DiTConfig:
    """Router: DiT-B/2 without text conditioning, classifier head (§6.3)."""
    return DiTConfig(
        name="router-b2", num_layers=12, d_model=768, num_heads=12,
        use_text=False, num_classes=num_clusters, **kw
    )
