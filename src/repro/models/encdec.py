"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the modality carve-out, the audio frontend (mel spectrogram + conv
feature extractor) is a STUB: ``input_specs`` supplies precomputed frame
embeddings ``(B, n_frames, d_model)``.  This module implements the
transformer: a bidirectional encoder over frames and a causal decoder with
cross-attention, learned positional embeddings, pre-LN blocks with GELU
MLPs (whisper uses LayerNorm with bias, not RMSNorm).

Decode carries a self-attention KV cache (ring-buffer under
``decode_window``) plus per-layer cross-attention K/V computed once at
prefill from the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import LMConfig
from repro.launch.fsdp import maybe_unshard

Array = jax.Array


def _enc_block_init(cfg: LMConfig, key):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.gqa_init(ks[0], cfg.d_model, cfg.num_heads,
                           cfg.num_kv_heads, hd, cfg.param_dtype,
                           qkv_bias=True),
        "ln_ffn": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "ffn": L.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def _dec_block_init(cfg: LMConfig, key):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 3)
    return {
        "ln_self": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "self_attn": L.gqa_init(ks[0], cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, hd, cfg.param_dtype,
                                qkv_bias=True),
        "ln_cross": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "cross_attn": L.gqa_init(ks[1], cfg.d_model, cfg.num_heads,
                                 cfg.num_heads, hd, cfg.param_dtype,
                                 qkv_bias=True),
        "ln_ffn": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "ffn": L.gelu_mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init(cfg: LMConfig, key) -> dict:
    k_emb, k_pe, k_pd, k_enc, k_dec, k_out = jax.random.split(key, 6)
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                              cfg.param_dtype),
        "enc_pos": (jax.random.normal(k_pe, (cfg.encoder_seq_len,
                                             cfg.d_model)) * 0.01
                    ).astype(cfg.param_dtype),
        "dec_pos_table": (jax.random.normal(k_pd, (8192, cfg.d_model)) * 0.01
                          ).astype(cfg.param_dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(cfg, k))(
            jax.random.split(k_enc, n_enc)
        ),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(cfg, k))(
            jax.random.split(k_dec, cfg.num_layers)
        ),
        "ln_enc_final": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "ln_dec_final": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                cfg.param_dtype),
    }


def _dec_positions(cfg: LMConfig, params, positions: Array) -> Array:
    tbl = params["dec_pos_table"]
    return jnp.take(tbl, jnp.clip(positions, 0, tbl.shape[0] - 1), axis=0)


def encode(cfg: LMConfig, params, frames: Array) -> Array:
    """frames: (B, n_frames, d_model) stubbed conv-frontend output."""
    h = frames.astype(cfg.activation_dtype)
    s = h.shape[1]
    h = h + params["enc_pos"][None, :s].astype(h.dtype)
    positions = jnp.arange(s)
    hd = cfg.resolved_head_dim

    def body(h, p):
        p = maybe_unshard(p, "enc_blocks")
        hn = L.layernorm(p["ln_attn"], h, cfg.norm_eps)
        q, k, v = L.gqa_project(p["attn"], hn, cfg.num_heads,
                                cfg.num_kv_heads, hd)
        out = L.chunked_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=False, chunk_size=cfg.attn_chunk, kv_chunk=cfg.attn_kv_chunk,
        f32_softmax=cfg.attn_f32_softmax,
        )
        h = h + L.dense(p["attn"]["wo"],
                        out.reshape(h.shape[0], s, cfg.num_heads * hd))
        h = h + L.gelu_mlp(p["ffn"], L.layernorm(p["ln_ffn"], h, cfg.norm_eps))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["enc_blocks"])
    return L.layernorm(params["ln_enc_final"], h, cfg.norm_eps)


def _cross_kv(cfg: LMConfig, p, memory: Array):
    hd = cfg.resolved_head_dim
    b, m, _ = memory.shape
    k = L.dense(p["cross_attn"]["wk"], memory).reshape(b, m, cfg.num_heads, hd)
    v = L.dense(p["cross_attn"]["wv"], memory).reshape(b, m, cfg.num_heads, hd)
    return k, v


def _dec_block(cfg: LMConfig, p, h: Array, memory: Array, positions: Array):
    hd = cfg.resolved_head_dim
    b, s = h.shape[:2]
    m = memory.shape[1]
    # causal self-attention
    hn = L.layernorm(p["ln_self"], h, cfg.norm_eps)
    q, k, v = L.gqa_project(p["self_attn"], hn, cfg.num_heads,
                            cfg.num_kv_heads, hd)
    out = L.chunked_attention(
        q, k, v, q_positions=positions, kv_positions=positions,
        causal=True, window=cfg.sliding_window, chunk_size=cfg.attn_chunk, kv_chunk=cfg.attn_kv_chunk,
        f32_softmax=cfg.attn_f32_softmax,
    )
    h = h + L.dense(p["self_attn"]["wo"],
                    out.reshape(b, s, cfg.num_heads * hd))
    self_kv = (k, v)
    # cross-attention
    hn = L.layernorm(p["ln_cross"], h, cfg.norm_eps)
    qc = L.dense(p["cross_attn"]["wq"], hn).reshape(b, s, cfg.num_heads, hd)
    kc, vc = _cross_kv(cfg, p, memory)
    out = L.chunked_attention(
        qc, kc, vc, q_positions=positions, kv_positions=jnp.arange(m),
        causal=False, chunk_size=cfg.attn_chunk, kv_chunk=cfg.attn_kv_chunk,
        f32_softmax=cfg.attn_f32_softmax,
    )
    h = h + L.dense(p["cross_attn"]["wo"],
                    out.reshape(b, s, cfg.num_heads * hd))
    h = h + L.gelu_mlp(p["ffn"], L.layernorm(p["ln_ffn"], h, cfg.norm_eps))
    return h, self_kv


def forward_train(
    cfg: LMConfig, params, tokens: Array, *, audio_embeds: Array,
) -> tuple[Array, Array]:
    """Teacher-forced decoder over encoded audio.  Returns (logits, aux)."""
    memory = encode(cfg, params, audio_embeds)
    b, s = tokens.shape
    positions = jnp.arange(s)
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)
    h = h + _dec_positions(cfg, params, positions)[None].astype(h.dtype)

    def body(h, p):
        p = maybe_unshard(p, "dec_blocks")
        h, _ = _dec_block(cfg, p, h, memory, positions)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["dec_blocks"])
    h = L.layernorm(params["ln_dec_final"], h, cfg.norm_eps)
    return L.dense(params["unembed"], h), jnp.zeros((), jnp.float32)


def loss_fn(cfg: LMConfig, params, tokens: Array, labels: Array, *,
            audio_embeds: Array):
    from repro.models.transformer import cross_entropy

    logits, _ = forward_train(cfg, params, tokens, audio_embeds=audio_embeds)
    ce = cross_entropy(logits, labels, chunk=cfg.logits_chunk)
    return ce, {"ce": ce}


def make_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    m = cfg.encoder_seq_len
    lyr = cfg.num_layers
    return {
        "k": jnp.zeros((lyr, batch, max_len, cfg.num_kv_heads, hd),
                       cfg.activation_dtype),
        "v": jnp.zeros((lyr, batch, max_len, cfg.num_kv_heads, hd),
                       cfg.activation_dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "cross_k": jnp.zeros((lyr, batch, m, cfg.num_heads, hd),
                             cfg.activation_dtype),
        "cross_v": jnp.zeros((lyr, batch, m, cfg.num_heads, hd),
                             cfg.activation_dtype),
    }


def prefill(
    cfg: LMConfig, params, tokens: Array, *, audio_embeds: Array,
) -> tuple[Array, dict]:
    memory = encode(cfg, params, audio_embeds)
    b, s = tokens.shape
    positions = jnp.arange(s)
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)
    h = h + _dec_positions(cfg, params, positions)[None].astype(h.dtype)

    def body(h, p):
        p = maybe_unshard(p, "dec_blocks")
        h, (k, v) = _dec_block(cfg, p, h, memory, positions)
        kc, vc = _cross_kv(cfg, p, memory)
        return h, (k, v, kc, vc)

    h, (ks, vs, kcs, vcs) = jax.lax.scan(body, h, params["dec_blocks"])
    hl = L.layernorm(params["ln_dec_final"], h[:, -1:], cfg.norm_eps)
    logits = L.dense(params["unembed"], hl)[:, 0]
    cache = {
        "k": ks, "v": vs,
        "pos": jnp.broadcast_to(positions[None], (b, s)),
        "cross_k": kcs, "cross_v": vcs,
    }
    return logits, cache


def decode_step(
    cfg: LMConfig, params, cache: dict, token: Array, pos: Array
) -> tuple[Array, dict]:
    hd = cfg.resolved_head_dim
    b = token.shape[0]
    h = L.embed(params["embed"], token, cfg.activation_dtype)
    h = h + _dec_positions(cfg, params, pos[:, None]).astype(h.dtype)
    w = cache["k"].shape[2]
    window = cfg.decode_window or cfg.sliding_window
    slot = (pos % w) if cfg.decode_window else jnp.minimum(pos, w - 1)
    new_pos = cache["pos"].at[jnp.arange(b), slot].set(pos)
    m = cache["cross_k"].shape[2]

    def body(h, xs):
        p, k_c, v_c, kc, vc = xs
        p = maybe_unshard(p, "dec_blocks")
        hn = L.layernorm(p["ln_self"], h, cfg.norm_eps)
        q, k, v = L.gqa_project(p["self_attn"], hn, cfg.num_heads,
                                cfg.num_kv_heads, hd)
        bidx = jnp.arange(b)
        k_c = k_c.at[bidx, slot].set(k[:, 0])
        v_c = v_c.at[bidx, slot].set(v[:, 0])
        out = L.decode_attention(
            q, k_c, v_c, q_position=pos, kv_positions=new_pos, window=window
        )
        h = h + L.dense(p["self_attn"]["wo"],
                        out.reshape(b, 1, cfg.num_heads * hd))
        hn = L.layernorm(p["ln_cross"], h, cfg.norm_eps)
        qc = L.dense(p["cross_attn"]["wq"], hn).reshape(b, 1, cfg.num_heads,
                                                        hd)
        out = L.decode_attention(
            qc, kc, vc,
            q_position=jnp.full((b,), m, jnp.int32),
            kv_positions=jnp.broadcast_to(jnp.arange(m)[None], (b, m)),
        )
        h = h + L.dense(p["cross_attn"]["wo"],
                        out.reshape(b, 1, cfg.num_heads * hd))
        h = h + L.gelu_mlp(p["ffn"],
                           L.layernorm(p["ln_ffn"], h, cfg.norm_eps))
        return h, (k_c, v_c)

    h, (ks, vs) = jax.lax.scan(
        body, h,
        (params["dec_blocks"], cache["k"], cache["v"],
         cache["cross_k"], cache["cross_v"]),
    )
    h = L.layernorm(params["ln_dec_final"], h, cfg.norm_eps)
    logits = L.dense(params["unembed"], h)[:, 0]
    return logits, {
        "k": ks, "v": vs, "pos": new_pos,
        "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
    }
