"""Mamba2 / SSD (state-space duality) backbone [arXiv:2405.21060].

Assigned architectures: mamba2-2.7b (pure SSM) and the mamba trunk of
zamba2-2.7b (hybrid).  The SSD recurrence per head h with state (P, N):

    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * x_t ⊗ B_t
    y_t = s_t · C_t + D_h * x_t

Training uses the *chunked* SSD algorithm (matmul-rich, MXU-friendly —
this is the TPU adaptation of the paper's GPU scan): intra-chunk terms via
masked (C Bᵀ ⊙ L) x matmuls, inter-chunk terms via a `lax.scan` over chunk
states.  Decode is the O(1) single-token state update — this is why the SSM
archs run long_500k natively.

``ssd_sequential`` is the slow oracle used by tests and mirrored by the
Pallas kernel in ``repro/kernels/ssd_scan.py``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import LMConfig
from repro.launch.fsdp import maybe_unshard

Array = jax.Array


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_sequential(
    x: Array, dt: Array, A: Array, B: Array, C: Array,
    init_state: Array | None = None,
) -> tuple[Array, Array]:
    """Reference recurrence.

    Args:
      x: (b, s, h, p) inner activations.
      dt: (b, s, h) positive step sizes.
      A: (h,) negative decay rates.
      B, C: (b, s, n) input/output projections (single group).
      init_state: optional (b, h, p, n).

    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None else init_state.astype(jnp.float32)
    )

    def step(state, inputs):
        xt, dtt, Bt, Ct = inputs
        decay = jnp.exp(dtt * A)[:, :, None, None]          # (b,h,1,1)
        upd = (dtt[:, :, None] * xt)[..., None] * Bt[:, None, None, :]
        state = decay * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def _segsum(a: Array) -> Array:
    """Stable segment-sum: L[i, j] = sum_{k=j+1..i} a_k for i >= j, -inf else.

    a: (..., q).  Returns (..., q, q).
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]               # sum_{j+1..i}
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: Array, dt: Array, A: Array, B: Array, C: Array,
    *, chunk: int = 128, init_state: Array | None = None,
) -> tuple[Array, Array]:
    """Chunked SSD (training path).  Same signature as ssd_sequential."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, q, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, q, n)

    a = dtf * A[None, None, None, :]                          # (b,nc,q,h) log-decay
    a_h = jnp.moveaxis(a, -1, 2)                              # (b,nc,h,q)
    Lmat = jnp.exp(_segsum(a_h))                              # (b,nc,h,q,q)

    # Intra-chunk output: y[i] += sum_{j<=i} C_i·B_j L_ij dt_j x_j
    CB = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)                # (b,nc,q,q)
    scores = CB[:, :, None] * Lmat                            # (b,nc,h,i,j)
    xdt = xf * dtf[..., None]                                 # (b,nc,q,h,p)
    y_intra = jnp.einsum(
        "bchij,bcjhp->bcihp", scores, xdt
    )

    # Per-chunk final-state contribution and decay-to-end factors.
    cum = jnp.cumsum(a_h, axis=-1)                            # (b,nc,h,q)
    total = cum[..., -1:]                                     # (b,nc,h,1)
    decay_to_end = jnp.exp(total - cum)                       # (b,nc,h,q)
    # state_c = sum_j exp(sum_{k>j} a_k) dt_j x_j ⊗ B_j
    w = jnp.moveaxis(decay_to_end, 2, -1)                     # (b,nc,q,h)
    states = jnp.einsum("bcqhp,bcqh,bcqn->bchpn", xf, dtf * w, Bf)

    chunk_decay = jnp.exp(total[..., 0])                      # (b,nc,h)

    init = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None else init_state.astype(jnp.float32)
    )

    def carry_fn(state, inputs):
        st_c, dec_c = inputs                                  # (b,h,p,n), (b,h)
        prev = state
        state = dec_c[..., None, None] * state + st_c
        return state, prev

    final, prevs = jax.lax.scan(
        carry_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prevs = jnp.moveaxis(prevs, 0, 1)                         # (b,nc,h,p,n)

    # Inter-chunk output: y[i] += exp(cum_i) C_i · state_{c-1}
    decay_in = jnp.exp(cum)                                   # (b,nc,h,q)
    y_inter = jnp.einsum(
        "bcin,bchpn,bchi->bcihp", Cf, prevs, decay_in
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: Array, x: Array, dt: Array, A: Array, B: Array, C: Array
) -> tuple[Array, Array]:
    """Single-token state update.

    state: (b,h,p,n); x: (b,h,p); dt: (b,h); B,C: (b,n).
    Returns (y (b,h,p), new_state).
    """
    decay = jnp.exp(dt * A)[:, :, None, None]
    upd = (dt[:, :, None] * x)[..., None] * B[:, None, None, :]
    state = decay * state.astype(jnp.float32) + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 mixer block
# ---------------------------------------------------------------------------


def mixer_init(cfg: LMConfig, key) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_nheads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 5)
    dt = jnp.exp(
        jax.random.uniform(ks[3], (h,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "in_proj": L.dense_init(
            ks[0], d, 2 * di + 2 * n + h, cfg.param_dtype
        ),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch))
                   * (1.0 / math.sqrt(cfg.ssm_conv_width))
                   ).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": (jnp.log(jnp.expm1(dt))).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": L.rmsnorm_init(di, cfg.param_dtype),
        "out_proj": L.dense_init(ks[2], di, d, cfg.param_dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 init: Array | None = None) -> tuple[Array, Array]:
    """Depthwise causal conv1d (width K).  x: (B, S, C); w: (K, C).

    Returns (y, tail) where tail (B, K-1, C) is the new conv cache.
    """
    kw = w.shape[0]
    if init is None:
        init = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None].astype(x.dtype)
        for i in range(kw)
    )
    tail = xp[:, -(kw - 1):] if kw > 1 else init
    return y + b.astype(x.dtype), tail


def _split_proj(cfg: LMConfig, zxbcdt: Array):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt_raw = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt_raw


def mixer_apply(
    cfg: LMConfig, p, hid: Array, *,
    conv_state: Array | None = None,
    ssm_state: Array | None = None,
    mode: str = "train",
) -> tuple[Array, tuple[Array, Array]]:
    """Apply the Mamba2 mixer.  mode: 'train' (chunked) | 'decode' (S==1)."""
    b, s, _ = hid.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    pdim = cfg.ssm_headdim

    zxbcdt = L.dense(p["in_proj"], hid)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :di].reshape(b, s, h, pdim)
    B = xbc[..., di : di + n]
    C = xbc[..., di + n :]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"][None, None]
    )
    A = -jnp.exp(p["A_log"])

    if mode == "decode":
        assert s == 1
        y1, new_state = ssd_decode_step(
            ssm_state, x[:, 0].astype(jnp.float32), dt[:, 0], A,
            B[:, 0].astype(jnp.float32), C[:, 0].astype(jnp.float32),
        )
        y = y1[:, None]
    else:
        y, new_state = ssd_chunked(
            x, dt, A, B, C, chunk=cfg.ssm_chunk, init_state=ssm_state
        )
    y = y.astype(hid.dtype)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * x.astype(y.dtype)
    y = y.reshape(b, s, di)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z.astype(y.dtype)),
                  cfg.norm_eps)
    out = L.dense(p["out_proj"], y)
    return out.astype(hid.dtype), (conv_tail.astype(hid.dtype), new_state)


# ---------------------------------------------------------------------------
# Full pure-SSM model (mamba2-2.7b)
# ---------------------------------------------------------------------------


def _layer_init(cfg: LMConfig, key):
    return {
        "ln": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "mixer": mixer_init(cfg, key),
    }


def init(cfg: LMConfig, key) -> dict:
    k_emb, k_blocks, k_out = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: _layer_init(cfg, k))(
        jax.random.split(k_blocks, cfg.num_layers)
    )
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                              cfg.param_dtype),
        "blocks": blocks,
        "ln_final": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                cfg.param_dtype),
    }


def forward_train(cfg: LMConfig, params, tokens: Array) -> tuple[Array, Array]:
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)

    def body(h, block_p):
        block_p = maybe_unshard(block_p)
        y, _ = mixer_apply(
            cfg, block_p["mixer"],
            L.rmsnorm(block_p["ln"], h, cfg.norm_eps),
        )
        return h + y, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["blocks"])
    h = L.rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = L.dense(params["unembed"], h)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: LMConfig, params, tokens: Array, labels: Array):
    from repro.models.transformer import cross_entropy

    logits, _ = forward_train(cfg, params, tokens)
    ce = cross_entropy(logits, labels, chunk=cfg.logits_chunk)
    return ce, {"ce": ce}


def make_cache(cfg: LMConfig, batch: int, max_len: int = 0) -> dict:
    """SSM decode cache: conv tail + state per layer.  O(1) in seq len."""
    del max_len
    conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_ch),
            cfg.activation_dtype,
        ),
        "ssm": jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_nheads, cfg.ssm_headdim,
             cfg.ssm_state),
            jnp.float32,
        ),
    }


def prefill(cfg: LMConfig, params, tokens: Array) -> tuple[Array, dict]:
    h = L.embed(params["embed"], tokens, cfg.activation_dtype)

    def body(h, block_p):
        block_p = maybe_unshard(block_p)
        y, (conv_tail, state) = mixer_apply(
            cfg, block_p["mixer"],
            L.rmsnorm(block_p["ln"], h, cfg.norm_eps),
        )
        return h + y, (conv_tail, state)

    h, (convs, states) = jax.lax.scan(body, h, params["blocks"])
    hl = L.rmsnorm(params["ln_final"], h[:, -1:], cfg.norm_eps)
    logits = L.dense(params["unembed"], hl)[:, 0]
    return logits, {"conv": convs, "ssm": states}


def decode_step(
    cfg: LMConfig, params, cache: dict, token: Array, pos: Array
) -> tuple[Array, dict]:
    del pos  # state carries all history
    h = L.embed(params["embed"], token, cfg.activation_dtype)

    def body(h, xs):
        block_p, conv_c, ssm_c = xs
        block_p = maybe_unshard(block_p)
        y, (conv_tail, state) = mixer_apply(
            cfg, block_p["mixer"],
            L.rmsnorm(block_p["ln"], h, cfg.norm_eps),
            conv_state=conv_c, ssm_state=ssm_c, mode="decode",
        )
        return h + y, (conv_tail, state)

    h, (convs, states) = jax.lax.scan(
        body, h, (params["blocks"], cache["conv"], cache["ssm"])
    )
    h = L.rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = L.dense(params["unembed"], h)[:, 0]
    return logits, {"conv": convs, "ssm": states}
