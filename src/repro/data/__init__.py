from repro.data.synthetic import (
    SyntheticSpec, sample_batch, category_stats, frechet_distance,
    fit_gaussian, sample_fid, pairwise_diversity,
)
from repro.data.features import extract_features, FEATURE_DIM
from repro.data.pipeline import (
    ExpertDataStream, RouterDataStream, fit_clusters, lm_batch,
)
