"""Stub DINOv2 feature extractor (modality-frontend carve-out).

The paper extracts 1024-d [CLS] features from DINOv2-ViT-L/14.  Here the
extractor is a frozen, deterministic 2-layer random-projection network over
latents — it preserves the property that matters for the pipeline: images
from the same semantic category land near each other in feature space, so
hierarchical k-means recovers meaningful partitions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

FEATURE_DIM = 1024


@functools.lru_cache(maxsize=4)
def _frozen_weights(in_dim: int, seed: int = 7):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    hidden = 512
    w1 = jax.random.normal(k1, (in_dim, hidden)) / jnp.sqrt(in_dim)
    w2 = jax.random.normal(k2, (hidden, FEATURE_DIM)) / jnp.sqrt(hidden)
    return w1, w2


def extract_features(latents: Array, *, seed: int = 7) -> Array:
    """(B, H, W, C) latents -> (B, 1024) unit-norm 'DINOv2' features."""
    b = latents.shape[0]
    x = latents.reshape(b, -1).astype(jnp.float32)
    w1, w2 = _frozen_weights(x.shape[1], seed)
    h = jnp.tanh(x @ w1)
    f = h @ w2
    return f / jnp.maximum(jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-8)
