"""Stub DINOv2 feature extractor (modality-frontend carve-out).

The paper extracts 1024-d [CLS] features from DINOv2-ViT-L/14.  Here the
extractor is a frozen, deterministic random-projection network over
latents — it preserves the property that matters for the pipeline: images
from the same semantic category land near each other in feature space, so
hierarchical k-means recovers meaningful partitions.

Two frozen branches are combined:

* a 2-layer random projection of the full latent (fine-grained texture
  signal, low SNR — per-pixel noise dominates the norm);
* spatially pooled per-channel statistics projected to the same space.
  Spatial pooling averages the i.i.d. per-pixel noise down by ~1/√(H·W)
  while the category mean survives, so this branch carries most of the
  class-discriminative signal; it is weighted up accordingly.

This mirrors what a real frozen encoder provides (globally pooled,
denoised semantics) and is what makes k-means partitions align with the
generating categories instead of per-sample noise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

FEATURE_DIM = 1024

#: relative weight of the pooled (high-SNR) branch in the unit-norm output.
POOLED_GAIN = 3.0


@functools.lru_cache(maxsize=8)
def _frozen_weights(in_dim: int, pooled_dim: int, seed: int = 7):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    hidden = 512
    w1 = jax.random.normal(k1, (in_dim, hidden)) / jnp.sqrt(in_dim)
    w2 = jax.random.normal(k2, (hidden, FEATURE_DIM)) / jnp.sqrt(hidden)
    w3 = jax.random.normal(k3, (pooled_dim, FEATURE_DIM)) / jnp.sqrt(
        max(pooled_dim, 1)
    )
    return w1, w2, w3


def _unit(x: Array, eps: float = 1e-8) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def extract_features(latents: Array, *, seed: int = 7) -> Array:
    """(B, H, W, C) latents -> (B, 1024) unit-norm 'DINOv2' features."""
    b, c = latents.shape[0], latents.shape[-1]
    x = latents.reshape(b, -1).astype(jnp.float32)
    pooled = latents.astype(jnp.float32).mean(axis=(1, 2))       # (B, C)
    w1, w2, w3 = _frozen_weights(x.shape[1], c, seed)
    h = jnp.tanh(x @ w1)
    fine = _unit(h @ w2)
    coarse = _unit(pooled @ w3)
    f = fine + POOLED_GAIN * coarse
    return _unit(f)
