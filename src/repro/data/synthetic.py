"""Synthetic latent-space dataset standing in for LAION-Aesthetics.

The CPU container cannot host 11M images + a VAE, so the data substrate
generates a *structured* synthetic corpus that preserves everything the
paper's pipeline needs to be exercised end-to-end:

* latents: K-component Gaussian-mixture in (H, W, C) latent space — each
  component plays the role of a semantic category (portraits, landscapes,
  ...), giving the clustering stage real structure to find;
* captions: deterministic pseudo-CLIP embeddings (text_len, text_dim)
  correlated with the latent's component (so routing/text conditioning is
  learnable);
* an exact Fréchet distance is computable against the generating mixture,
  which is what the benchmark harness uses as its FID analogue.

Everything is a pure function of (seed, index) — no files, infinitely
shardable, reproducible across hosts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    num_categories: int = 8
    latent_size: int = 8
    latent_channels: int = 4
    text_len: int = 8
    text_dim: int = 32
    #: distance between mixture-component means (higher = more separable)
    separation: float = 2.5
    #: per-component covariance scale
    scale: float = 0.5
    seed: int = 1234


def _component_means(spec: SyntheticSpec) -> Array:
    key = jax.random.PRNGKey(spec.seed)
    d = spec.latent_size * spec.latent_size * spec.latent_channels
    means = jax.random.normal(key, (spec.num_categories, d))
    means = means / jnp.linalg.norm(means, axis=-1, keepdims=True)
    return means * spec.separation


def _caption_basis(spec: SyntheticSpec) -> Array:
    key = jax.random.PRNGKey(spec.seed + 1)
    return jax.random.normal(
        key, (spec.num_categories, spec.text_len, spec.text_dim)
    )


def sample_batch(
    spec: SyntheticSpec, key: jax.Array, batch: int,
    *, category: int | None = None,
) -> dict:
    """Returns {'latents', 'text_emb', 'category'} for a random batch."""
    k1, k2, k3 = jax.random.split(key, 3)
    if category is None:
        cats = jax.random.randint(k1, (batch,), 0, spec.num_categories)
    else:
        cats = jnp.full((batch,), category, jnp.int32)
    means = _component_means(spec)[cats]                     # (B, D)
    d = spec.latent_size * spec.latent_size * spec.latent_channels
    noise = jax.random.normal(k2, (batch, d)) * spec.scale
    latents = (means + noise).reshape(
        batch, spec.latent_size, spec.latent_size, spec.latent_channels
    )
    text = _caption_basis(spec)[cats]
    text = text + 0.1 * jax.random.normal(k3, text.shape)
    return {"latents": latents, "text_emb": text, "category": cats}


def category_stats(spec: SyntheticSpec) -> tuple[np.ndarray, np.ndarray]:
    """Exact (mean, cov) of the full generating mixture — used by the
    Fréchet-distance benchmark as the 'real data' statistics."""
    means = np.asarray(_component_means(spec))
    d = means.shape[1]
    mu = means.mean(axis=0)
    centered = means - mu
    cov_means = centered.T @ centered / means.shape[0]
    cov = cov_means + (spec.scale ** 2) * np.eye(d)
    return mu, cov


def frechet_distance(
    mu1: np.ndarray, cov1: np.ndarray, mu2: np.ndarray, cov2: np.ndarray
) -> float:
    """Exact Fréchet distance between Gaussians (the FID formula)."""
    diff = mu1 - mu2
    # sqrtm via eigendecomposition of the symmetrized product.
    c1h = _sqrtm_psd(cov1)
    inner = c1h @ cov2 @ c1h
    tr_sqrt = np.trace(_sqrtm_psd(inner))
    return float(diff @ diff + np.trace(cov1) + np.trace(cov2) - 2 * tr_sqrt)


def _sqrtm_psd(m: np.ndarray) -> np.ndarray:
    m = (m + m.T) / 2.0
    w, v = np.linalg.eigh(m)
    w = np.clip(w, 0.0, None)
    return (v * np.sqrt(w)) @ v.T


def fit_gaussian(samples: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    x = samples.reshape(samples.shape[0], -1).astype(np.float64)
    mu = x.mean(axis=0)
    xc = x - mu
    cov = xc.T @ xc / max(x.shape[0] - 1, 1)
    return mu, cov


def sample_fid(spec: SyntheticSpec, samples: np.ndarray) -> float:
    """FID analogue: Fréchet distance between generated samples and the
    exact generating-mixture statistics."""
    mu_r, cov_r = category_stats(spec)
    mu_g, cov_g = fit_gaussian(samples)
    return frechet_distance(mu_r, cov_r, mu_g, cov_g)


def pairwise_diversity(samples: np.ndarray) -> float:
    """Mean pairwise L2 distance — the LPIPS↑ diversity analogue."""
    x = samples.reshape(samples.shape[0], -1)
    diffs = x[:, None] - x[None]
    d = np.sqrt((diffs ** 2).sum(-1))
    n = x.shape[0]
    return float(d.sum() / (n * (n - 1)))
