"""Data pipeline: clustering-driven partitions + per-expert iterators.

Mirrors the paper's Fig. 6 training pipeline:

  corpus -> (stub) DINOv2 features -> hierarchical k-means -> K disjoint
  partitions S_1..S_K -> one isolated iterator per expert.

Expert iterators are *rejection-sampled* streams over the synthetic corpus
conditioned on the expert's cluster — each expert only ever sees its own
partition, structurally enforcing the zero-synchronization property.  The
router iterator streams all clusters with ground-truth labels.

Also provides token-LM batches for the assigned architectures (synthetic
text corpus with a Zipfian unigram model — enough structure for loss-drop
smoke training).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import ClusterModel, hierarchical_kmeans
from repro.data.features import extract_features
from repro.data.synthetic import SyntheticSpec, sample_batch

Array = jax.Array


def fit_clusters(
    spec: SyntheticSpec, *, corpus_size: int = 4096, num_clusters: int = 8,
    num_fine: int = 256, seed: int = 0,
) -> tuple[ClusterModel, np.ndarray]:
    """Fit the two-stage clustering on a corpus sample (paper §6.1)."""
    key = jax.random.PRNGKey(seed)
    batch = sample_batch(spec, key, corpus_size)
    feats = extract_features(batch["latents"])
    model = hierarchical_kmeans(
        jax.random.PRNGKey(seed + 1), feats,
        num_coarse=num_clusters, num_fine=num_fine,
    )
    assignment = np.asarray(model.assign(feats))
    return model, assignment


@dataclasses.dataclass
class ExpertDataStream:
    """Isolated per-expert stream: only samples assigned to cluster_id."""

    spec: SyntheticSpec
    cluster_model: ClusterModel
    cluster_id: int
    batch_size: int
    seed: int = 0
    oversample: int = 4

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.next_batch(step)
            step += 1

    def next_batch(self, step: int) -> dict:
        """Rejection-sample a batch belonging to this expert's cluster.

        Draws additional pools until ``batch_size`` matching samples are
        found (bounded retries); a short batch is topped up by repeating
        *matching* samples, never by leaking other clusters' data — the
        zero-synchronization isolation invariant is structural.
        """
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        need = self.batch_size
        pools: list[dict] = []
        matched: list[np.ndarray] = []
        total = 0
        for attempt in range(8):
            key = jax.random.fold_in(base, attempt)
            pool = sample_batch(self.spec, key, need * self.oversample)
            feats = extract_features(pool["latents"])
            assign = np.asarray(self.cluster_model.assign(feats))
            idx = np.nonzero(assign == self.cluster_id)[0]
            pools.append(pool)
            matched.append(idx)
            total += len(idx)
            if total >= need:
                break
        latents = np.concatenate(
            [np.asarray(p["latents"])[i] for p, i in zip(pools, matched)]
        )
        text = np.concatenate(
            [np.asarray(p["text_emb"])[i] for p, i in zip(pools, matched)]
        )
        cats = np.concatenate(
            [np.asarray(p["category"])[i] for p, i in zip(pools, matched)]
        )
        if len(latents) == 0:
            raise RuntimeError(
                f"cluster {self.cluster_id} produced no samples in "
                f"{8 * need * self.oversample} draws — clustering degenerate?"
            )
        sel = np.arange(need) % len(latents)     # wraparound within cluster
        return {
            "latents": jnp.asarray(latents[sel]),
            "text_emb": jnp.asarray(text[sel]),
            "category": jnp.asarray(cats[sel]),
        }


@dataclasses.dataclass
class RouterDataStream:
    """Full-corpus stream with cluster labels (router trains on all data)."""

    spec: SyntheticSpec
    cluster_model: ClusterModel
    batch_size: int
    seed: int = 100

    def next_batch(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        batch = sample_batch(self.spec, key, self.batch_size)
        feats = extract_features(batch["latents"])
        labels = self.cluster_model.assign(feats)
        return {**batch, "cluster": jnp.asarray(labels)}


# ---------------------------------------------------------------------------
# Token batches for the assigned LM architectures
# ---------------------------------------------------------------------------


def lm_batch(
    key: jax.Array, batch: int, seq_len: int, vocab: int
) -> dict:
    """Zipf-ish synthetic token batch with next-token labels."""
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, (batch, seq_len + 1), minval=1e-6)
    # inverse-CDF of a truncated zipf(1.1)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1.0
    tokens = jnp.clip(ranks.astype(jnp.int32), 0, vocab - 1)
    mix = jax.random.randint(k2, tokens.shape, 0, vocab)
    tokens = jnp.where(jax.random.bernoulli(k2, 0.1, tokens.shape),
                       mix, tokens)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
