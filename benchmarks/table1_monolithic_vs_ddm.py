"""Table 1 — monolithic vs decentralized multi-expert (Top-1/Top-2/Full).

Paper claim: Top-2 beats both the monolithic baseline (23.7% FID
improvement) and the Full ensemble (prediction conflicts).  Here: same
comparison at CPU scale with the exact-Fréchet analogue.
"""

from __future__ import annotations

from benchmarks.common import (
    Ensemble,
    evaluate_sampler,
    train_ensemble,
    write_report,
)
from repro.core import ExpertSpec


def run() -> list[tuple[str, float, float]]:
    ens = train_ensemble(num_clusters=4, objectives=["fm"] * 4,
                         train_monolithic=True)
    rows = []
    # monolithic: single expert, full weight
    mono_expert = [ExpertSpec("mono", "fm", "linear", ens.apply_fn, -1)]
    mono = evaluate_sampler(
        ens, strategy="full", experts=mono_expert,
        params=[ens.monolithic_params],
    )
    rows.append(("table1_monolithic", mono["us_per_call"], mono["fid"]))
    results = {"monolithic": mono}
    for strat, k, label in [("top1", 1, "top1"), ("topk", 2, "top2"),
                            ("full", 4, "full_ensemble")]:
        r = evaluate_sampler(ens, strategy=strat, top_k=k)
        rows.append((f"table1_{label}", r["us_per_call"], r["fid"]))
        results[label] = r

    lines = ["# Table 1 — Monolithic vs DDM (FID analogue, lower better)",
             "", "| inference | FID-proxy | diversity | us/img |",
             "|---|---|---|---|"]
    for k, v in results.items():
        lines.append(f"| {k} | {v['fid']:.3f} | {v['diversity']:.3f} | "
                     f"{v['us_per_call']:.0f} |")
    best = min(results, key=lambda k: results[k]["fid"])
    lines += ["", f"best: **{best}** — paper's Table 1 finds Top-2 best "
              "(selective activation beats both monolithic and "
              "indiscriminate Full averaging)."]
    write_report("table1", lines)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
