"""Serving hot-path benchmark: seed dense sampler vs compute-sparse engine.

Measures, for the paper's 8-expert top-2 + CFG serving configuration:

* **expert forwards per step** — counted exactly by tracing the sampler
  with an instrumented ``apply_fn`` (``lax.scan`` traces its body once, so
  trace-time call counts == per-step execution counts).  Seed path:
  ``2·K`` (every expert, twice for CFG).  Sparse path: ``k`` (routed
  experts only, CFG batched) — within the ``(k+1)`` acceptance budget.
* **img/s** — wall-clock of the jitted end-to-end sampler (compile
  excluded via warmup; median of repeated runs).
* **retrace count** — ``ServingEngine.stats['traces']`` across repeated
  same-shape requests (must stay at 1).

Emits ``name,us_per_call,derived`` CSV rows for the harness and a JSON
artifact (``BENCH_sampler.json``) via ``--json-out`` / ``write_json`` so
future PRs can track the perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.launch.serve import ServingEngine
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2

NUM_EXPERTS = 8
BATCH = int(os.environ.get("REPRO_BENCH_SAMPLER_BATCH", 8))
STEPS = int(os.environ.get("REPRO_BENCH_SAMPLER_STEPS", 8))
TOP_K = 2
CFG_SCALE = 7.5
LATENT = int(os.environ.get("REPRO_BENCH_SAMPLER_LATENT", 16))
REPS = int(os.environ.get("REPRO_BENCH_SAMPLER_REPS", 5))


def _build():
    """8 heterogeneous (DDPM/FM) experts sharing one instrumented apply.

    16×16 latents (256-token sequences after 2×2 patching at d=128) are
    the smallest scale where CPU wall-clock is forward-compute- rather
    than dispatch/gather-dominated, so the measured speedup reflects the
    forward-count reduction rather than scan overhead.
    """
    cfg = dit_b2().reduced(latent_size=LATENT)
    base_apply = D.make_expert_apply(cfg)
    counter = {"n": 0}

    def counted_apply(params, x, t, **cond):
        counter["n"] += 1                       # trace-time call counter
        return base_apply(params, x, t, **cond)

    experts, params = [], []
    for i in range(NUM_EXPERTS):
        obj = "ddpm" if i % 4 == 0 else "fm"    # paper-style 2 DDPM : 6 FM
        experts.append(ExpertSpec(
            f"e{i}", obj, "cosine" if obj == "ddpm" else "linear",
            counted_apply, i,
        ))
        params.append(D.init(cfg, jax.random.PRNGKey(10 + i)))
    rcfg = router_b2(num_clusters=NUM_EXPERTS).reduced(latent_size=LATENT)
    router_fn = D.make_router_fn(rcfg, D.init(rcfg, jax.random.PRNGKey(99)))
    text = jax.random.normal(
        jax.random.PRNGKey(5), (BATCH, cfg.text_len, cfg.text_dim)
    )
    return cfg, experts, params, router_fn, text, counter


def _sampler_fn(experts, params, router_fn, text, engine):
    sampler = SamplerConfig(
        num_steps=STEPS, cfg_scale=CFG_SCALE, strategy="topk", top_k=TOP_K,
    )

    def fn(key):
        return sample_ensemble(
            key, experts, params, router_fn,
            (BATCH, LATENT, LATENT, 4),
            cond={"text_emb": text}, null_cond={"text_emb": None},
            config=sampler, engine=engine,
        )

    return fn


def _forwards_per_step(counter, fn) -> float:
    # ``lax.scan`` traces its body exactly once, so the trace-time call
    # count of the instrumented apply IS the per-step forward count.
    counter["n"] = 0
    jax.eval_shape(fn, jax.random.PRNGKey(0))
    return float(counter["n"])


def _time_imgs_per_s(*fns) -> list[tuple[float, bool]]:
    """Interleaved best-of-REPS timing (min is robust to load spikes)."""
    jitted = [jax.jit(fn) for fn in fns]
    outs = [jax.block_until_ready(f(jax.random.PRNGKey(0)))
            for f in jitted]                                # compile
    times = [[] for _ in fns]
    for r in range(REPS):
        for i, f in enumerate(jitted):
            t0 = time.time()
            outs[i] = jax.block_until_ready(f(jax.random.PRNGKey(r + 1)))
            times[i].append(time.time() - t0)
    return [
        (BATCH / float(np.min(ts)),
         bool(np.isfinite(np.asarray(out)).all()))
        for ts, out in zip(times, outs)
    ]


def _retrace_count(experts, params, router_fn, text, requests=3) -> int:
    engine = ServingEngine(
        experts=experts, expert_params=params, router_fn=router_fn,
        latent_shape=(LATENT, LATENT, 4),
        sampler=SamplerConfig(num_steps=STEPS, cfg_scale=CFG_SCALE,
                              strategy="topk", top_k=TOP_K),
    )
    for r in range(requests):
        jax.block_until_ready(
            engine.generate(jax.random.PRNGKey(r), text, BATCH)
        )
    return int(engine.stats["traces"])


def collect() -> dict:
    cfg, experts, params, router_fn, text, counter = _build()

    seed_fn = _sampler_fn(experts, params, router_fn, text, "reference")
    sparse_fn = _sampler_fn(experts, params, router_fn, text, "auto")

    seed_fwd = _forwards_per_step(counter, seed_fn)
    sparse_fwd = _forwards_per_step(counter, sparse_fn)
    (seed_ips, seed_ok), (sparse_ips, sparse_ok) = _time_imgs_per_s(
        seed_fn, sparse_fn
    )
    retraces = _retrace_count(experts, params, router_fn, text)

    return {
        "config": {
            "num_experts": NUM_EXPERTS, "top_k": TOP_K, "batch": BATCH,
            "num_steps": STEPS, "cfg_scale": CFG_SCALE,
            "latent": [LATENT, LATENT, 4], "model": cfg.name,
            "backend": jax.default_backend(),
        },
        "seed": {
            "expert_forwards_per_step": seed_fwd,
            "img_per_s": seed_ips,
            "finite": seed_ok,
        },
        "sparse": {
            "expert_forwards_per_step": sparse_fwd,
            "img_per_s": sparse_ips,
            "finite": sparse_ok,
            "serving_retraces_3_requests": retraces,
        },
        "speedup": sparse_ips / max(seed_ips, 1e-9),
        "forward_reduction": seed_fwd / max(sparse_fwd, 1e-9),
        "meets_forward_budget": sparse_fwd <= TOP_K + 1,   # ≤ (k+1)/step
        "meets_2x_speedup": sparse_ips >= 2.0 * seed_ips,
    }


_LAST: dict = {}


def run():
    """Harness entry — yields ``name,us_per_call,derived`` rows."""
    res = collect()
    _LAST.clear()
    _LAST.update(res)
    us = lambda ips: 1e6 / max(ips, 1e-9)  # noqa: E731
    yield ("sampler_seed_dense", f"{us(res['seed']['img_per_s']):.1f}",
           f"fwd/step={res['seed']['expert_forwards_per_step']:.0f}")
    yield ("sampler_sparse_routed", f"{us(res['sparse']['img_per_s']):.1f}",
           f"fwd/step={res['sparse']['expert_forwards_per_step']:.0f}")
    yield ("sampler_speedup", "0", f"{res['speedup']:.2f}x")
    yield ("sampler_retraces", "0",
           str(res['sparse']['serving_retraces_3_requests']))


def write_json(path: str, res: dict | None = None) -> str:
    res = res or _LAST or collect()
    with open(path, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default="BENCH_sampler.json")
    args = ap.parse_args()
    for row in run():
        print(",".join(str(x) for x in row))
    path = write_json(args.json_out)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
