"""Serving hot-path benchmark: seed dense sampler vs compute-sparse engine.

Measures, for the paper's 8-expert top-2 + CFG serving configuration:

* **expert forwards per step** — counted exactly by tracing the sampler
  with an instrumented ``apply_fn`` (``lax.scan`` traces its body once, so
  trace-time call counts == per-step execution counts).  Seed path:
  ``2·K`` (every expert, twice for CFG).  Sparse path: ``k`` (routed
  experts only, CFG batched) — within the ``(k+1)`` acceptance budget.
* **img/s** — wall-clock of the jitted end-to-end sampler (compile
  excluded via warmup; median of repeated runs).
* **retrace count** — ``ServingEngine.stats['traces']`` across repeated
  same-shape requests (must stay at 1).

* **dispatch backends** (``--dispatch grouped``) — the ``core.dispatch``
  executor axis: sort-based grouped execution is measured against the
  per-sample gathered baseline on the same ensemble.  Grouped forwards
  are counted at *runtime* (``jax.debug.callback``): the grouped trace
  compiles one bucket branch per power-of-two segment size, so a
  trace-time count would tally every branch while only one executes per
  expert per step.  Budget: executed segment passes ≤ resident experts,
  vs ``B·k·2`` gathered model-rows with batched CFG.

* **quantized expert stores** (``--param-dtype {bf16,int8,fp8}``) — the
  ``core.param_store`` storage axis: resident expert-param bytes
  (``ExpertParamStore.nbytes()``, int8 gate ≥ 3.5× smaller than dense
  fp32), img/s, and max-abs final-latent parity vs the dense store on the
  same key, recorded under the ``quantized`` section keyed by dtype.

* **step fusion + plan reuse** (``--plan-refresh N``, always collected) —
  the ``core.sampling`` step-fused hot path vs the unfused grouped
  baseline, two JSON sections:

  - ``fused_step``: img/s of the step-fused sampler (R=1 and R=N),
    parity vs the unfused path (gate: max-abs diff == 0 at R=1 — the
    ``hetero_fuse_step`` oracle reuses the exact unfused math), and an
    HBM-bytes-per-step estimate from XLA's own cost model
    (``launch.hlo_analysis.compiled_bytes_accessed``); acceptance:
    img/s ≥ 1.1× the unfused grouped baseline;
  - ``plan_reuse``: keyed ``R<N>`` (sub-merged like ``quantized``), with
    per-interval img/s, refreshes/run, and max-abs drift vs per-step
    routing (the FID-proxy for the router-posteriors-change-slowly
    premise).

Emits ``name,us_per_call,derived`` CSV rows for the harness and a JSON
artifact (``BENCH_sampler.json``) via ``--json-out`` / ``write_json`` so
future PRs can track the perf trajectory.  ``write_json`` merges into an
existing artifact by top-level section, so a ``--shards``-only or
``--dispatch``-only rerun refreshes its own section without dropping the
others.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import sys
import time


def _peek_shards() -> int:
    """Parse --shards from argv BEFORE importing jax: the sharded mode
    needs that many host devices, and jax locks the device count at first
    init (same constraint as launch/dryrun.py)."""
    for i, a in enumerate(sys.argv):
        if a == "--shards" and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith("--shards="):
            return int(a.split("=", 1)[1])
    return 1


_SHARDS = _peek_shards()
if _SHARDS > 1 and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_SHARDS}"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.launch.serve import ServingEngine
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2

NUM_EXPERTS = 8
BATCH = int(os.environ.get("REPRO_BENCH_SAMPLER_BATCH", 8))
STEPS = int(os.environ.get("REPRO_BENCH_SAMPLER_STEPS", 8))
TOP_K = 2
CFG_SCALE = 7.5
LATENT = int(os.environ.get("REPRO_BENCH_SAMPLER_LATENT", 16))
REPS = int(os.environ.get("REPRO_BENCH_SAMPLER_REPS", 5))


@functools.lru_cache(maxsize=2)
def _build(latent: int = LATENT):
    """8 heterogeneous (DDPM/FM) experts sharing one instrumented apply.

    16×16 latents (256-token sequences after 2×2 patching at d=128) are
    the smallest scale where CPU wall-clock is forward-compute- rather
    than dispatch/gather-dominated, so the measured speedup reflects the
    forward-count reduction rather than scan overhead.  (The continuous
    section passes a smaller ``latent`` — see ``collect_continuous``.)
    """
    cfg = dit_b2().reduced(latent_size=latent)
    base_apply = D.make_expert_apply(cfg)
    counter = {"n": 0}

    def counted_apply(params, x, t, **cond):
        counter["n"] += 1                       # trace-time call counter
        return base_apply(params, x, t, **cond)

    experts, params = [], []
    for i in range(NUM_EXPERTS):
        obj = "ddpm" if i % 4 == 0 else "fm"    # paper-style 2 DDPM : 6 FM
        experts.append(ExpertSpec(
            f"e{i}", obj, "cosine" if obj == "ddpm" else "linear",
            counted_apply, i,
        ))
        params.append(D.init(cfg, jax.random.PRNGKey(10 + i)))
    rcfg = router_b2(num_clusters=NUM_EXPERTS).reduced(latent_size=latent)
    router_fn = D.make_router_fn(rcfg, D.init(rcfg, jax.random.PRNGKey(99)))
    text = jax.random.normal(
        jax.random.PRNGKey(5), (BATCH, cfg.text_len, cfg.text_dim)
    )
    return cfg, experts, params, router_fn, text, counter


def _sampler_fn(experts, params, router_fn, text, engine, dispatch="auto",
                param_dtype="native", step_fused=True, plan_refresh=1,
                latent=LATENT, top_k=TOP_K):
    sampler = SamplerConfig(
        num_steps=STEPS, cfg_scale=CFG_SCALE, strategy="topk", top_k=top_k,
        dispatch=dispatch, param_dtype=param_dtype,
        step_fused=step_fused, plan_refresh_every=plan_refresh,
    )

    def fn(key):
        return sample_ensemble(
            key, experts, params, router_fn,
            (BATCH, latent, latent, 4),
            cond={"text_emb": text}, null_cond={"text_emb": None},
            config=sampler, engine=engine,
        )

    return fn


def _forwards_per_step(counter, fn) -> float:
    # ``lax.scan`` traces its body exactly once, so the trace-time call
    # count of the instrumented apply IS the per-step forward count.
    counter["n"] = 0
    jax.eval_shape(fn, jax.random.PRNGKey(0))
    return float(counter["n"])


def _time_imgs_per_s(*fns, return_outputs=False, pre_compiled=False):
    """Interleaved best-of-REPS timing (min is robust to load spikes).

    ``return_outputs=True`` additionally returns each fn's warm-up output
    (all computed from ``PRNGKey(0)``, so they are directly comparable —
    the parity inputs for cross-backend/cross-store sections).
    ``pre_compiled=True`` accepts AOT-compiled executables (from
    ``jax.jit(fn).lower(key).compile()``) and times them as-is, so a
    caller that also needs the compiled object (cost analysis) pays for
    exactly one compile.
    """
    jitted = list(fns) if pre_compiled else [jax.jit(fn) for fn in fns]
    outs = [jax.block_until_ready(f(jax.random.PRNGKey(0)))
            for f in jitted]                                # compile
    warm = list(outs)
    times = [[] for _ in fns]
    for r in range(REPS):
        for i, f in enumerate(jitted):
            t0 = time.time()
            outs[i] = jax.block_until_ready(f(jax.random.PRNGKey(r + 1)))
            times[i].append(time.time() - t0)
    res = [
        (BATCH / float(np.min(ts)),
         bool(np.isfinite(np.asarray(out)).all()))
        for ts, out in zip(times, outs)
    ]
    return (res, warm) if return_outputs else res


def _retrace_count(experts, params, router_fn, text, requests=3) -> int:
    engine = ServingEngine(
        experts=experts, expert_params=params, router_fn=router_fn,
        latent_shape=(LATENT, LATENT, 4),
        sampler=SamplerConfig(num_steps=STEPS, cfg_scale=CFG_SCALE,
                              strategy="topk", top_k=TOP_K),
    )
    for r in range(requests):
        jax.block_until_ready(
            engine.generate(jax.random.PRNGKey(r), text, BATCH)
        )
    return int(engine.stats["traces"])


def collect() -> dict:
    cfg, experts, params, router_fn, text, counter = _build()

    seed_fn = _sampler_fn(experts, params, router_fn, text, "reference")
    # dispatch pinned to 'gathered': this section's forwards/step is
    # counted at TRACE time, and the grouped backend (what 'auto' now
    # resolves to) traces every power-of-two bucket branch — its runtime
    # forward count is tracked separately in the 'grouped' section
    # (--dispatch grouped), with jax.debug.callback counting.
    sparse_fn = _sampler_fn(experts, params, router_fn, text, "auto",
                            dispatch="gathered")

    seed_fwd = _forwards_per_step(counter, seed_fn)
    sparse_fwd = _forwards_per_step(counter, sparse_fn)
    (seed_ips, seed_ok), (sparse_ips, sparse_ok) = _time_imgs_per_s(
        seed_fn, sparse_fn
    )
    retraces = _retrace_count(experts, params, router_fn, text)

    return {
        "config": {
            "num_experts": NUM_EXPERTS, "top_k": TOP_K, "batch": BATCH,
            "num_steps": STEPS, "cfg_scale": CFG_SCALE,
            "latent": [LATENT, LATENT, 4], "model": cfg.name,
            "backend": jax.default_backend(),
        },
        "seed": {
            "expert_forwards_per_step": seed_fwd,
            "img_per_s": seed_ips,
            "finite": seed_ok,
        },
        "sparse": {
            "expert_forwards_per_step": sparse_fwd,
            "img_per_s": sparse_ips,
            "finite": sparse_ok,
            "serving_retraces_3_requests": retraces,
        },
        "speedup": sparse_ips / max(seed_ips, 1e-9),
        "forward_reduction": seed_fwd / max(sparse_fwd, 1e-9),
        "meets_forward_budget": sparse_fwd <= TOP_K + 1,   # ≤ (k+1)/step
        "meets_2x_speedup": sparse_ips >= 2.0 * seed_ips,
    }


def collect_sharded(shards: int) -> dict:
    """Expert-parallel serving benchmark on a forced multi-device host.

    Places the stacked 8-expert pytree on an ("expert", "data") mesh with
    ``shards`` expert shards (run with ``--shards N`` so the module forces
    N host devices) and reports per-shard forwards/step — each shard
    holds K/N resident experts and owns 1/N of the routed gather — plus
    end-to-end img/s against the unsharded engine on the same host.
    """
    ndev = jax.device_count()
    if ndev < shards:
        raise RuntimeError(
            f"--shards {shards} needs {shards} devices, have {ndev} "
            f"(pass --shards on the command line so XLA_FLAGS is set "
            f"before jax initializes)"
        )
    if NUM_EXPERTS % shards:
        # ServingEngine would raise too; fail here with bench context so
        # BENCH_sampler.json never records fictitious per-shard stats.
        raise RuntimeError(
            f"--shards {shards} must divide NUM_EXPERTS={NUM_EXPERTS}"
        )
    cfg, experts, params, router_fn, text, counter = _build()
    sampler = SamplerConfig(
        num_steps=STEPS, cfg_scale=CFG_SCALE, strategy="topk", top_k=TOP_K,
    )

    def make_engine(**shard_kw):
        return ServingEngine(
            experts=experts, expert_params=params, router_fn=router_fn,
            latent_shape=(LATENT, LATENT, 4), sampler=sampler, **shard_kw,
        )

    engines = [
        make_engine(),
        make_engine(n_expert_shards=shards,
                    n_data_shards=max(1, ndev // shards)),
    ]
    # compile each (scan body traces once -> counter == forwards/step),
    # then interleave the timed reps (min is robust to load spikes, and
    # interleaving keeps the sharded-vs-unsharded ratio fair under load —
    # same policy as _time_imgs_per_s).
    fwds, outs = [], []
    for engine in engines:
        counter["n"] = 0
        outs.append(jax.block_until_ready(
            engine.generate(jax.random.PRNGKey(0), text, BATCH)
        ))
        fwds.append(float(counter["n"]))
    times = [[] for _ in engines]
    for r in range(REPS):
        for i, engine in enumerate(engines):
            t0 = time.time()
            outs[i] = jax.block_until_ready(
                engine.generate(jax.random.PRNGKey(r + 1), text, BATCH)
            )
            times[i].append(time.time() - t0)
    (base_fwd, sh_fwd) = fwds
    base_ips, sh_ips = (BATCH / float(np.min(ts)) for ts in times)
    base_ok, sh_ok = (bool(np.isfinite(np.asarray(o)).all()) for o in outs)
    engine = engines[1]
    return {
        "shards": shards,
        "devices": ndev,
        "mesh": {k: int(v) for k, v in engine.mesh.shape.items()},
        "resident_experts_per_shard": NUM_EXPERTS / shards,
        "expert_forwards_per_step_global": sh_fwd,
        "expert_forwards_per_step_unsharded": base_fwd,
        "per_shard_forwards_per_step": sh_fwd / shards,
        "img_per_s": sh_ips,
        "img_per_s_unsharded_same_host": base_ips,
        "finite": sh_ok and base_ok,
        "parity_note": "outputs asserted equal in tests/"
                       "test_sharded_serving.py + launch/sharded_parity.py",
    }


def collect_dispatch(dispatch: str) -> dict:
    """Executor-backend section (``core.dispatch``), vs the gathered path.

    Measures, for the same 8-expert top-2 + CFG ensemble:

    * **executed forwards/step** — counted at runtime via
      ``jax.debug.callback`` (fires only in the bucket branch that
      actually runs), since the grouped trace contains every power-of-two
      bucket branch and a trace-time count would tally all of them;
    * **model-rows/step** — total latent rows pushed through expert
      forwards (grouped: padded segment rows; gathered reference:
      ``B·k·2`` with batched CFG);
    * **img/s** vs the gathered backend, interleaved timing;
    * **parity** — max |grouped − gathered| on the same key.
    """
    cfg, experts, params, router_fn, text, counter = _build()
    shared_apply = experts[0].apply_fn

    runtime = {"calls": 0, "rows": 0}

    def _bump(rows):
        runtime["calls"] += 1
        runtime["rows"] += int(rows)

    def rt_apply(p, x, t, **cond):
        jax.debug.callback(_bump, x.shape[0])
        return shared_apply(p, x, t, **cond)

    rt_experts = [dataclasses.replace(e, apply_fn=rt_apply)
                  for e in experts]

    base_fn = jax.jit(_sampler_fn(experts, params, router_fn, text,
                                  "routed", dispatch="gathered"))
    disp_fn = jax.jit(_sampler_fn(experts, params, router_fn, text,
                                  "routed", dispatch=dispatch))
    # compile (once per backend) + parity on the same key
    out_b = jax.block_until_ready(base_fn(jax.random.PRNGKey(0)))
    out_d = jax.block_until_ready(disp_fn(jax.random.PRNGKey(0)))
    max_diff = float(jnp.abs(out_d - out_b).max())
    times: list[list[float]] = [[], []]
    for r in range(REPS):
        for i, f in enumerate((base_fn, disp_fn)):
            t0 = time.time()
            out = jax.block_until_ready(f(jax.random.PRNGKey(r + 1)))
            times[i].append(time.time() - t0)
            if i:
                out_d = out
            else:
                out_b = out
    base_ips, disp_ips = (BATCH / float(np.min(ts)) for ts in times)
    base_ok = bool(np.isfinite(np.asarray(out_b)).all())
    disp_ok = bool(np.isfinite(np.asarray(out_d)).all())

    # runtime forward count: one warm-up compile, then a counted run.
    # block_until_ready only waits for array outputs; on asynchronous
    # backends debug callbacks can still be in flight, so fence with
    # effects_barrier before touching the host-side counters.
    rt_fn = jax.jit(_sampler_fn(rt_experts, params, router_fn, text,
                                "routed", dispatch=dispatch))
    jax.block_until_ready(rt_fn(jax.random.PRNGKey(0)))
    jax.effects_barrier()
    runtime["calls"] = runtime["rows"] = 0
    jax.block_until_ready(rt_fn(jax.random.PRNGKey(1)))
    jax.effects_barrier()
    fwd_per_step = runtime["calls"] / STEPS
    rows_per_step = runtime["rows"] / STEPS

    gathered_rows = BATCH * TOP_K * 2           # B·k lanes × batched CFG
    # routed rows the plan actually asked for; anything above this in the
    # runtime row count is bucket padding (grouped pads each expert's
    # segment to a power of two so segment growth doesn't retrace).
    routed_rows = BATCH * TOP_K * 2
    return {
        "dispatch": dispatch,
        "expert_forwards_per_step_executed": fwd_per_step,
        "model_rows_per_step": rows_per_step,
        "padded_rows_per_step": rows_per_step,
        "routed_rows_per_step": routed_rows,
        "padding_overhead": rows_per_step / routed_rows - 1.0,
        "resident_experts": NUM_EXPERTS,
        "meets_resident_forward_budget": fwd_per_step <= NUM_EXPERTS,
        "gathered_rows_per_step": gathered_rows,
        "img_per_s": disp_ips,
        "img_per_s_gathered": base_ips,
        "speedup_vs_gathered": disp_ips / max(base_ips, 1e-9),
        "finite": disp_ok and base_ok,
        "parity_max_abs_diff_vs_gathered": max_diff,
    }


def collect_ragged(top_k: int = 4, latent: int = 20) -> dict:
    """One-kernel ragged backend section, vs the grouped backend.

    ``collect_dispatch`` measures a backend against the *gathered*
    reference and counts rows through the per-expert ``apply_fn`` — the
    ragged backend never calls it (one pair-major forward per step), so
    this section instead compares ragged against grouped directly:

    * **img/s** both backends, interleaved timing, plus the tracked
      ``meets_1p15x_vs_grouped`` acceptance gate;
    * **parity** — max |ragged − grouped| on the same key; dense float32
      params must be *bitwise* (the pair-major unscatter is exact);
    * **rows/step** — runtime-counted via an instrumented ragged
      forward.  Ragged runs exactly the ``B·k·g`` routed rows — zero
      bucket padding — so ``padding_overhead`` is the measured 0.0
      against the grouped section's padded number.

    Regime choice: like ``collect_continuous``, this section pins its
    own routing width — ``top_k=4`` against the other sections'
    ``TOP_K=2``.  What the ragged kernel removes is the grouped
    backend's *per-expert* costs: power-of-two segment buckets and one
    ``lax.switch`` branch per resident expert.  Those scale with how
    finely the routed rows split across experts, and at ``top_k=2``
    the B=8 bench batch lands segments on bucket boundaries (measured
    padding only +12.5%), hiding the effect the kernel exists to
    delete.  ``top_k=4`` (heavier per-sample fusion — more experts
    blended per image, the serving knob this ensemble exposes) makes
    the bench router's skew land 5–7-pair segments that grouped rounds
    to 8: +28% padded rows on average over steps/keys, never below
    +15% — while ragged still runs exactly ``B·k·g`` rows (measured
    below, ``padding_overhead == 0.0``).  ``latent=20`` keeps per-row
    compute large enough that the CPU fallback's per-pair weight
    gather (``wd[expert_ids]`` — a fixed byte cost per routed pair
    that the Pallas path doesn't pay; its tiles index the stacked
    leaves in place) doesn't mask the padding difference the section
    exists to measure.

    Timing: the host is a single shared core, so load drift between
    the two arms' windows is the dominant error.  Each rep times the
    two samplers back-to-back (the pair shares one load regime) and
    the tracked ``speedup_vs_grouped`` is the *median of the per-rep
    paired ratios* — robust both to spikes (unlike a ratio of sums)
    and to drift between windows (unlike a ratio of per-arm minima).
    The per-arm ``img_per_s`` floors stay best-of-reps, matching the
    other sections.

    The timed ragged sampler is *uninstrumented*: the rows counter is a
    runtime ``jax.debug.callback`` (a host round-trip every step) that
    the grouped arm does not pay — it runs in a separate jit used only
    for the rows/parity measurement.
    """
    cfg, experts, params, router_fn, text, counter = _build(latent)
    ragged_apply = D.make_ragged_expert_apply(cfg)

    runtime = {"rows": 0}

    def _bump(rows):
        runtime["rows"] += int(rows)

    def rt_ragged(view, x_p, t_p, cond, pe, g):
        jax.debug.callback(_bump, x_p.shape[0] * g)
        return ragged_apply(view, x_p, t_p, cond, pe, g)

    r_experts = [dataclasses.replace(e, ragged_apply_fn=ragged_apply)
                 for e in experts]
    rt_experts = [dataclasses.replace(e, ragged_apply_fn=rt_ragged)
                  for e in experts]
    mk = functools.partial(_sampler_fn, top_k=top_k, latent=latent)
    grouped_fn = jax.jit(mk(experts, params, router_fn, text,
                            "routed", dispatch="grouped"))
    ragged_fn = jax.jit(mk(r_experts, params, router_fn, text,
                           "routed", dispatch="ragged"))
    rt_ragged_fn = jax.jit(mk(rt_experts, params, router_fn, text,
                              "routed", dispatch="ragged"))
    out_g = jax.block_until_ready(grouped_fn(jax.random.PRNGKey(0)))
    out_r = jax.block_until_ready(rt_ragged_fn(jax.random.PRNGKey(0)))
    jax.effects_barrier()
    max_diff = float(jnp.abs(out_r - out_g).max())

    runtime["rows"] = 0
    jax.block_until_ready(rt_ragged_fn(jax.random.PRNGKey(1)))
    jax.effects_barrier()
    rows_per_step = runtime["rows"] / STEPS

    jax.block_until_ready(ragged_fn(jax.random.PRNGKey(0)))  # compile
    reps = max(REPS, 9)
    times: list[list[float]] = [[], []]
    for r in range(reps):
        for i, f in enumerate((grouped_fn, ragged_fn)):
            t0 = time.time()
            out = jax.block_until_ready(f(jax.random.PRNGKey(r + 1)))
            times[i].append(time.time() - t0)
            if i:
                out_r = out
            else:
                out_g = out
    grouped_ips, ragged_ips = (BATCH / float(np.min(ts)) for ts in times)
    speedup = float(np.median(np.asarray(times[0]) / np.asarray(times[1])))

    routed_rows = BATCH * top_k * 2             # B·k pairs × CFG branches
    return {
        "dispatch": "ragged",
        "top_k": top_k,
        "latent": latent,
        "img_per_s": ragged_ips,
        "img_per_s_grouped": grouped_ips,
        "speedup_vs_grouped": speedup,
        "meets_1p15x_vs_grouped": bool(speedup >= 1.15),
        "parity_max_abs_diff_vs_grouped": max_diff,
        "bitwise_vs_grouped": bool(max_diff == 0.0),
        "padded_rows_per_step": rows_per_step,
        "routed_rows_per_step": routed_rows,
        "padding_overhead": rows_per_step / routed_rows - 1.0,
        "finite": bool(np.isfinite(np.asarray(out_r)).all()
                       and np.isfinite(np.asarray(out_g)).all()),
    }


def collect_step_fusion(plan_refresh: int) -> tuple[dict, dict]:
    """Step-fused hot path + plan-reuse sections, vs the unfused baseline.

    Three samplers on the same grouped 8-expert top-2 + CFG ensemble:

    * **unfused** — ``step_fused=False``, per-step routing: the PR-3/4
      grouped baseline (``fused_velocity`` → ``cfg_combine`` → Euler as
      separate ops);
    * **fused R=1** — the step-fused kernel, per-step routing.  Must be
      *bit-identical* to unfused (``parity_max_abs_diff == 0``: the
      oracle delegates to the same convert-and-fuse math);
    * **fused R=N** — plan recomputed every N-th step only (``--plan-
      refresh``), the full new hot path.  Drift vs R=1 is the tracked
      quality proxy.

    Also records an HBM-bytes-per-step estimate for the fused vs unfused
    executable (``launch.hlo_analysis.compiled_bytes_accessed`` — XLA's
    own "bytes accessed" cost model, 0.0 where the backend reports none).

    Returns ``(fused_step_section, plan_reuse_section)``; ``plan_reuse``
    is keyed ``"R<N>"`` so reruns with other refresh intervals merge.
    """
    from repro.launch.hlo_analysis import compiled_bytes_accessed

    cfg, experts, params, router_fn, text, counter = _build()
    mk = functools.partial(_sampler_fn, experts, params, router_fn, text,
                           "routed", dispatch="grouped")
    unfused_fn = mk(step_fused=False)
    fused_fn = mk(step_fused=True)

    # AOT-compile each sampler exactly once: the same executables feed
    # the timing loop AND XLA's cost analysis.  plan_refresh == 1 IS the
    # fused R=1 sampler — don't compile and time the same config twice.
    key0 = jax.random.PRNGKey(0)
    fns = [unfused_fn, fused_fn]
    if plan_refresh > 1:
        fns.append(mk(step_fused=True, plan_refresh=plan_refresh))
    compiled = [jax.jit(fn).lower(key0).compile() for fn in fns]
    bytes_unfused = compiled_bytes_accessed(compiled[0])
    bytes_fused = compiled_bytes_accessed(compiled[1])

    timings, outs = _time_imgs_per_s(
        *compiled, return_outputs=True, pre_compiled=True)
    if plan_refresh == 1:
        timings = timings + [timings[1]]
        outs = outs + [outs[1]]
    ((unf_ips, unf_ok), (fus_ips, fus_ok), (reuse_ips, reuse_ok)) = timings
    (out_u, out_f, out_r) = outs
    fused_parity = float(jnp.abs(out_f - out_u).max())
    drift = float(jnp.abs(out_r - out_f).max())
    latent_scale = float(jnp.abs(out_f).max())

    fused_step = {
        "plan_refresh": plan_refresh,
        "img_per_s": reuse_ips,
        "img_per_s_fused_R1": fus_ips,
        "img_per_s_unfused": unf_ips,
        # step fusion in isolation (R=1 both sides): on CPU this hovers
        # around 1.0 — its gate only demands no regression, so a fusion
        # slowdown can't hide behind a healthy plan-reuse number ...
        "speedup_vs_unfused": fus_ips / max(unf_ips, 1e-9),
        "meets_1p0x_speedup_fusion_only": bool(fus_ips >= 1.0 * unf_ips),
        # ... while the 1.1x acceptance gate reads the full hot path
        # (fusion + plan reuse at R=N) and says so in its name.
        "speedup_with_plan_reuse": reuse_ips / max(unf_ips, 1e-9),
        "meets_1p1x_speedup_with_plan_reuse": bool(
            reuse_ips >= 1.1 * unf_ips
        ),
        "parity_max_abs_diff_vs_unfused": fused_parity,   # R=1, must be 0
        "hbm_bytes_per_step": bytes_fused / STEPS,
        "hbm_bytes_per_step_unfused": bytes_unfused / STEPS,
        "hbm_bytes_per_step_saved": (bytes_unfused - bytes_fused) / STEPS,
        "finite": bool(unf_ok and fus_ok and reuse_ok),
    }
    plan_reuse = {
        "R1": {
            "plan_refresh": 1,
            "img_per_s": fus_ips,
            "plan_refreshes_per_run": STEPS,
            # acceptance gate: R=1 must match the unfused path exactly
            "parity_max_abs_diff": fused_parity,
        },
    }
    if plan_refresh > 1:
        plan_reuse[f"R{plan_refresh}"] = {
            "plan_refresh": plan_refresh,
            "img_per_s": reuse_ips,
            "plan_refreshes_per_run": -(-STEPS // plan_refresh),
            "speedup_vs_R1": reuse_ips / max(fus_ips, 1e-9),
            "drift_max_abs_vs_R1": drift,
            "drift_rel_to_latent_scale": drift / max(latent_scale, 1e-9),
        }
    return fused_step, plan_reuse


def collect_and_merge_step_fusion(
    json_out: str | None, plan_refresh: int,
) -> tuple[dict, dict]:
    """Collect the ``fused_step``/``plan_reuse`` sections and stage them
    for ``write_json``.

    The single entry point shared by this module's ``main`` and
    ``benchmarks/run.py --plan-refresh``: runs :func:`collect_step_fusion`,
    stashes both sections in ``_LAST``, and sub-merges ``plan_reuse`` by
    refresh interval against any existing artifact at ``json_out``.
    """
    fused_sec, reuse_sec = collect_step_fusion(max(1, plan_refresh))
    _LAST["fused_step"] = fused_sec
    _LAST["plan_reuse"] = (
        submerge_section(json_out, "plan_reuse", reuse_sec)
        if json_out else reuse_sec
    )
    return fused_sec, reuse_sec


def _jitter_params(tree, key):
    """Add small noise to every leaf (defeats §2.5 zero-init layers)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten([
        leaf + 0.02 * jax.random.normal(k, leaf.shape, leaf.dtype)
        for leaf, k in zip(leaves, keys)
    ])


def collect_quantized(param_dtype: str) -> dict:
    """Quantized expert-store section (``core.param_store``), vs dense.

    Measures, for the same 8-expert top-2 + CFG ensemble:

    * **resident param bytes** — ``ExpertParamStore.nbytes()`` of the
      requested storage vs the native (fp32) dense store.  int8 must hit
      the ≥ 3.5× reduction acceptance gate;
    * **img/s** vs the dense store on the same dispatch backend,
      interleaved timing;
    * **parity** — max |quantized − dense| over the final latents for
      the same key (the FID-proxy tracked across PRs).
    """
    from repro.core.param_store import make_store
    from repro.models import dit as D

    cfg, experts, params, router_fn, text, counter = _build()
    # Freshly-initialized DiT experts carry §2.5 zero-init output layers,
    # which make the forward weight-independent (identically zero final
    # projection) and the parity metric vacuously 0.  Jitter every leaf
    # so the recorded parity measures real quantization error.
    params = [_jitter_params(p, jax.random.PRNGKey(1234 + i))
              for i, p in enumerate(params)]
    stacked = D.stack_expert_params(params)
    dense_bytes = make_store(stacked, dtype="native").nbytes()
    q_bytes = make_store(stacked, dtype=param_dtype).nbytes()

    dense_fn = _sampler_fn(experts, params, router_fn, text, "routed")
    quant_fn = _sampler_fn(experts, params, router_fn, text, "routed",
                           param_dtype=param_dtype)
    ((dense_ips, dense_ok), (quant_ips, quant_ok)), (out_d, out_q) = \
        _time_imgs_per_s(dense_fn, quant_fn, return_outputs=True)
    max_diff = float(jnp.abs(out_q - out_d).max())
    dense_scale = float(jnp.abs(out_d).max())
    reduction = dense_bytes / max(q_bytes, 1)
    return {
        "param_dtype": param_dtype,
        "resident_param_bytes": int(q_bytes),
        "resident_param_bytes_dense": int(dense_bytes),
        "byte_reduction_vs_dense": reduction,
        "meets_3p5x_byte_reduction": bool(reduction >= 3.5)
        if param_dtype in ("int8", "fp8") else None,
        "img_per_s": quant_ips,
        "img_per_s_dense": dense_ips,
        "parity_max_abs_diff_vs_dense": max_diff,
        "parity_rel_to_dense_latent_scale": max_diff / max(dense_scale,
                                                          1e-9),
        "finite": bool(dense_ok and quant_ok),
    }


def collect_continuous(
    n_requests: int = 144, max_resident: int = 48, arrival_every: int = 1,
    arrivals_per_tick: int = 6, latent: int = 4,
) -> dict:
    """Continuous-batching section (``repro.serving``), vs lockstep flush.

    Two arms over the same DiT ensemble and the same ``n_requests``
    single-image text-conditioned requests:

    * **continuous** — ``arrivals_per_tick`` requests arrive every
      ``arrival_every`` scheduler ticks into a
      :class:`repro.serving.ContinuousScheduler` rolling batch of
      ``max_resident``; mixed-timestep residents share one fused-step
      launch per tick, so arrivals overlap instead of queueing behind
      full ``num_steps`` runs.  Latency percentiles come from the
      scheduler's own ``LatencyRecorder`` (what ``ServingEngine.stats``
      reports in production).
    * **lockstep flush baseline** — the pre-existing serving path: each
      request is a dedicated ``submit`` + ``flush()`` pair, i.e. a full
      ``num_steps`` batch-1 scan per request, one after another.

    Regime choice: this harness runs on a single CPU core, where the
    expert forward itself scales nearly linearly in batch — the only
    real batching economy is the grouped executor's per-expert gemms,
    whose dispatch/sort/padding overhead amortizes at LARGE resident
    batches and SMALL latents.  Measured per-row-step cost at
    ``latent=4``: lockstep B=1 ≈ 2.1 ms vs rolling B=16 ≈ 1.28 ms,
    B=48 ≈ 0.87 ms — the headroom the gate certifies.
    ``arrivals_per_tick=6`` matches the offered load to the service
    rate (``max_resident/num_steps`` = 6 requests per tick), keeping
    the rolling batch full; at 1/tick the steady-state residency is
    only ``num_steps`` rows and capacity padding burns the advantage.
    At the other sections' ``LATENT=16``, batch-1 already saturates the
    core and no scheduler can beat sequential lockstep on wall-clock —
    that regime measures kernels, not scheduling.

    Both arms pay one warm-up request first (compile excluded; the
    scheduler's recorder is reset after warm-up).  Acceptance gate:
    continuous img/s ≥ 1.2× the lockstep baseline.
    """
    from repro.serving import ContinuousScheduler

    cfg, experts, params, router_fn, text, counter = _build(latent)
    sampler = SamplerConfig(
        num_steps=STEPS, cfg_scale=CFG_SCALE, strategy="topk", top_k=TOP_K,
    )
    text1 = text[:1]

    def make_engine():
        return ServingEngine(
            experts=experts, expert_params=params, router_fn=router_fn,
            latent_shape=(latent, latent, 4), sampler=sampler,
        )

    # --- continuous arm -------------------------------------------------
    engine = make_engine()
    sched = ContinuousScheduler(engine, max_resident=max_resident)
    warm = sched.submit(jax.random.PRNGKey(0), text1)     # compile
    sched.run_until_idle()
    jax.block_until_ready(warm.result())
    sched.metrics.reset()
    t0 = time.time()
    handles = []
    r = 0
    while r < n_requests:
        for _ in range(min(arrivals_per_tick, n_requests - r)):
            handles.append(sched.submit(jax.random.PRNGKey(100 + r), text1))
            r += 1
        for _ in range(arrival_every):
            sched.step()
    sched.run_until_idle()
    outs = [h.result() for h in handles]
    jax.block_until_ready(outs)
    cont_s = time.time() - t0
    snap = sched.metrics.snapshot()
    cont_ips = n_requests / cont_s
    cont_ok = all(bool(np.isfinite(np.asarray(o)).all()) for o in outs)

    # --- lockstep flush baseline ----------------------------------------
    twin = make_engine()
    h = twin.submit(jax.random.PRNGKey(0), text1, 1)      # compile
    twin.flush()
    jax.block_until_ready(h.result())
    e2e: list[float] = []
    t0 = time.time()
    for r in range(n_requests):
        rt0 = time.time()
        h = twin.submit(jax.random.PRNGKey(100 + r), text1, 1)
        twin.flush()
        out = h.result()
        jax.block_until_ready(out)
        e2e.append(time.time() - rt0)
    base_s = time.time() - t0
    base_ips = n_requests / base_s
    base_ok = bool(np.isfinite(np.asarray(out)).all())

    from repro.serving import percentile
    return {
        "n_requests": n_requests,
        "max_resident": max_resident,
        "arrival_every_ticks": arrival_every,
        "arrivals_per_tick": arrivals_per_tick,
        "latent": [latent, latent, 4],
        "img_per_s": cont_ips,
        "img_per_s_lockstep_flush": base_ips,
        "speedup_vs_lockstep": cont_ips / max(base_ips, 1e-9),
        "meets_1p2x_throughput": bool(cont_ips >= 1.2 * base_ips),
        "latency_p50_s": snap["latency_p50_s"],
        "latency_p95_s": snap["latency_p95_s"],
        "queue_wait_p50_s": snap["queue_wait_p50_s"],
        "queue_wait_p95_s": snap["queue_wait_p95_s"],
        "latency_p50_s_lockstep": percentile(e2e, 50),
        "latency_p95_s_lockstep": percentile(e2e, 95),
        "scheduler_traces": int(engine.stats["traces"]),
        "finite": bool(cont_ok and base_ok),
    }


_LAST: dict = {}


def run():
    """Harness entry — yields ``name,us_per_call,derived`` rows."""
    res = collect()
    _LAST.clear()
    _LAST.update(res)
    us = lambda ips: 1e6 / max(ips, 1e-9)  # noqa: E731
    yield ("sampler_seed_dense", f"{us(res['seed']['img_per_s']):.1f}",
           f"fwd/step={res['seed']['expert_forwards_per_step']:.0f}")
    yield ("sampler_sparse_routed", f"{us(res['sparse']['img_per_s']):.1f}",
           f"fwd/step={res['sparse']['expert_forwards_per_step']:.0f}")
    yield ("sampler_speedup", "0", f"{res['speedup']:.2f}x")
    yield ("sampler_retraces", "0",
           str(res['sparse']['serving_retraces_3_requests']))


def submerge_section(path: str, section: str, new: dict) -> dict:
    """Merge ``new`` into an existing artifact's sub-keyed section.

    ``write_json`` merges by *top-level* section, so sections keyed by a
    sweep axis (``quantized`` by dtype, ``plan_reuse`` by refresh
    interval) would drop their other keys on a single-axis rerun; this
    reads the current artifact's sub-dict and overlays the fresh entries.
    """
    existing: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f).get(section, {}) or {}
        except (OSError, ValueError):
            existing = {}
    existing.update(new)
    return existing


def write_json(path: str, res: dict | None = None) -> str:
    """Write (merging by top-level section into any existing artifact).

    The baseline, ``sharded`` and dispatch sections are produced by
    different invocations (``--shards`` needs a forced multi-device
    host); merging keeps one ``BENCH_sampler.json`` tracking all axes.
    """
    res = res or _LAST or collect()
    merged: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(res)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default="BENCH_sampler.json")
    ap.add_argument("--shards", type=int, default=1,
                    help="expert-parallel shards; > 1 forces that many "
                         "host devices (must be a command-line arg so it "
                         "is seen before jax initializes)")
    ap.add_argument("--dispatch", default=None,
                    choices=("gathered", "grouped", "ragged"),
                    help="benchmark a core.dispatch executor backend "
                         "against the gathered baseline (ragged: against "
                         "the grouped backend it replaces) and record it "
                         "as a JSON section")
    ap.add_argument("--param-dtype", default=None,
                    choices=("bf16", "int8", "fp8"),
                    help="benchmark a quantized/cast expert store "
                         "(core.param_store) against the dense baseline "
                         "and record it under the 'quantized' JSON "
                         "section (keyed by dtype)")
    ap.add_argument("--continuous", action="store_true",
                    help="benchmark the repro.serving continuous-batching "
                         "scheduler (staggered single-image requests, "
                         "rolling mixed-timestep batch) against the "
                         "lockstep submit+flush baseline and record it "
                         "under the 'continuous' JSON section")
    ap.add_argument("--plan-refresh", type=int, default=8,
                    help="refresh interval R for the plan-reuse arm of "
                         "the step-fusion benchmark: the fused_step and "
                         "plan_reuse sections compare unfused vs "
                         "step-fused (R=1, bit-exact) vs plan-reused "
                         "(every R-th step) samplers; plan_reuse "
                         "sub-merges by R so reruns keep other intervals")
    args = ap.parse_args()
    if args.shards > 1:
        # fail fast on a bad flag BEFORE the ~1 min unsharded benchmark
        if jax.device_count() < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs that many devices, have "
                f"{jax.device_count()}"
            )
        if NUM_EXPERTS % args.shards:
            raise SystemExit(
                f"--shards {args.shards} must divide NUM_EXPERTS="
                f"{NUM_EXPERTS}"
            )
    for row in run():
        print(",".join(str(x) for x in row))
    fused_sec, reuse_sec = collect_and_merge_step_fusion(
        args.json_out, args.plan_refresh
    )
    print(f"sampler_fused_step,{1e6 / max(fused_sec['img_per_s'], 1e-9):.1f},"
          f"{fused_sec['speedup_with_plan_reuse']:.2f}x_vs_unfused "
          f"parity={fused_sec['parity_max_abs_diff_vs_unfused']:.3g}")
    rkey = f"R{max(1, args.plan_refresh)}"
    print(f"sampler_plan_reuse_{rkey},"
          f"{1e6 / max(reuse_sec[rkey]['img_per_s'], 1e-9):.1f},"
          f"refreshes/run={reuse_sec[rkey]['plan_refreshes_per_run']} "
          f"drift={reuse_sec[rkey].get('drift_max_abs_vs_R1', 0.0):.3g}")
    if args.shards > 1:
        sharded = collect_sharded(args.shards)
        _LAST["sharded"] = sharded
        yield_us = 1e6 / max(sharded["img_per_s"], 1e-9)
        print(f"sampler_sharded_{args.shards}x,{yield_us:.1f},"
              f"fwd/step/shard={sharded['per_shard_forwards_per_step']:.2f}")
    if args.dispatch == "ragged":
        sec = collect_ragged()
        _LAST["ragged"] = sec
        us = 1e6 / max(sec["img_per_s"], 1e-9)
        print(f"sampler_dispatch_ragged,{us:.1f},"
              f"{sec['speedup_vs_grouped']:.2f}x_vs_grouped "
              f"parity={sec['parity_max_abs_diff_vs_grouped']:.3g} "
              f"padding={sec['padding_overhead']:.3f}")
    elif args.dispatch:
        sec = collect_dispatch(args.dispatch)
        _LAST[args.dispatch] = sec
        us = 1e6 / max(sec["img_per_s"], 1e-9)
        print(f"sampler_dispatch_{args.dispatch},{us:.1f},"
              f"fwd/step={sec['expert_forwards_per_step_executed']:.1f}")
    if args.continuous:
        sec = collect_continuous()
        _LAST["continuous"] = sec
        us = 1e6 / max(sec["img_per_s"], 1e-9)
        print(f"sampler_continuous,{us:.1f},"
              f"{sec['speedup_vs_lockstep']:.2f}x_vs_lockstep "
              f"p50={sec['latency_p50_s']:.2f}s "
              f"p95={sec['latency_p95_s']:.2f}s")
    if args.param_dtype:
        sec = collect_quantized(args.param_dtype)
        # sub-merge by dtype so an --param-dtype bf16 rerun doesn't drop
        # the tracked int8 numbers (write_json merges whole sections).
        _LAST["quantized"] = submerge_section(
            args.json_out, "quantized", {args.param_dtype: sec}
        )
        us = 1e6 / max(sec["img_per_s"], 1e-9)
        print(f"sampler_quantized_{args.param_dtype},{us:.1f},"
              f"bytes={sec['resident_param_bytes']} "
              f"({sec['byte_reduction_vs_dense']:.2f}x smaller) "
              f"parity={sec['parity_max_abs_diff_vs_dense']:.3g}")
    path = write_json(args.json_out)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
