"""Fig. 4 / §3.3.3 — routing-threshold sweep for the 2-expert
(converted-DDPM + native-FM) deterministic threshold router.

Paper: low thresholds (0.2–0.3, FM-dominated) favor quality; mid-range
(0.4–0.5) favors diversity — a clear quality/diversity trade-off curve.
"""

from __future__ import annotations

from benchmarks.common import evaluate_sampler, train_ensemble, write_report

THRESHOLDS = [0.2, 0.3, 0.4, 0.5, 0.6, 0.7]


def run() -> list[tuple[str, float, float]]:
    ens = train_ensemble(
        num_clusters=2, objectives=["ddpm", "fm"], same_cluster=True,
    )
    rows, results = [], {}
    for th in THRESHOLDS:
        r = evaluate_sampler(ens, strategy="threshold", threshold=th,
                             seed=3)
        results[th] = r
        rows.append((f"fig4_threshold_{th}", r["us_per_call"], r["fid"]))

    lines = ["# Fig. 4 — Router threshold sweep (quality vs diversity)",
             "", "| threshold | FID-proxy↓ | diversity↑ |", "|---|---|---|"]
    for th, r in results.items():
        lines.append(f"| {th} | {r['fid']:.3f} | {r['diversity']:.3f} |")
    best_fid = min(results, key=lambda t: results[t]["fid"])
    best_div = max(results, key=lambda t: results[t]["diversity"])
    lines += ["", f"best FID at threshold {best_fid}; best diversity at "
              f"{best_div}. Paper: FID best at 0.2 (FM-dominated), "
              "diversity best around 0.5."]
    write_report("fig4", lines)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
