"""Table 3 — sampling-quality comparison (§3.3).

Five configurations on the SAME data cluster (isolating objective effects
from data-distribution effects, exactly as §3.3.1):

  native DDPM | native FM | DDPM→FM (training-free conversion) |
  combined same-schedule | combined different-schedules

Paper findings to reproduce directionally: conversion beats native DDPM
sampling; FM is the strongest single expert; combined raises diversity at
an FID cost; same-schedule combo edges different-schedule.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    EVAL_SAMPLES,
    LATENT,
    SAMPLE_STEPS,
    evaluate_sampler,
    train_ensemble,
    write_report,
)
from repro.core import sample_ddpm_ancestral
from repro.data import pairwise_diversity, sample_fid


def run() -> list[tuple[str, float, float]]:
    # 2 experts, both on cluster 0: expert0 = DDPM(cosine), expert1 = FM.
    # Plus a third FM expert trained with the *cosine* schedule for the
    # same-schedule combination row.
    ens = train_ensemble(
        num_clusters=2,
        objectives=["ddpm", "fm", "fm"][:2],
        same_cluster=True,
    )
    ens_same_sched = train_ensemble(
        num_clusters=2,
        objectives=["ddpm", "fm"],
        schedules=["cosine", "cosine"],
        same_cluster=True, seed=13,
    )

    results: dict[str, dict] = {}

    # native DDPM ancestral sampler
    import time
    t0 = time.time()
    shape = (EVAL_SAMPLES, LATENT, LATENT, 4)
    out = sample_ddpm_ancestral(
        jax.random.PRNGKey(0), ens.apply_fn, ens.params[0], shape,
        num_steps=SAMPLE_STEPS, cfg_scale=1.0,
    )
    out = np.asarray(jax.block_until_ready(out))
    results["native_ddpm"] = {
        "fid": sample_fid(ens.spec, out),
        "diversity": pairwise_diversity(out),
        "us_per_call": (time.time() - t0) / EVAL_SAMPLES * 1e6,
    }

    # native FM (single expert ODE)
    results["native_fm"] = evaluate_sampler(
        ens, strategy="full", experts=[ens.experts[1]],
        params=[ens.params[1]],
    )
    # DDPM→FM: converted DDPM expert alone in the ODE sampler
    results["ddpm_to_fm"] = evaluate_sampler(
        ens, strategy="full", experts=[ens.experts[0]],
        params=[ens.params[0]],
    )
    # beyond-paper: same expert, SNR-matched cross-schedule rebase (§5.ii)
    results["ddpm_to_fm_snr_match"] = evaluate_sampler(
        ens, strategy="full", experts=[ens.experts[0]],
        params=[ens.params[0]], time_map="snr_match",
    )
    # combined, different schedules (DDPM-cosine + FM-linear), threshold 0.5
    results["combined_diff_sched"] = evaluate_sampler(
        ens, strategy="threshold", threshold=0.5,
    )
    # combined, same schedule (both cosine)
    results["combined_same_sched"] = evaluate_sampler(
        ens_same_sched, strategy="threshold", threshold=0.5,
    )

    lines = ["# Table 3 — Sampling quality (conversion study, §3.3)",
             "", "| method | FID-proxy↓ | diversity↑ | us/img |",
             "|---|---|---|---|"]
    for k, v in results.items():
        lines.append(f"| {k} | {v['fid']:.3f} | {v['diversity']:.3f} | "
                     f"{v['us_per_call']:.0f} |")
    checks = []
    checks.append(("conversion_beats_native_ddpm",
                   results["ddpm_to_fm"]["fid"]
                   <= results["native_ddpm"]["fid"] * 1.15))
    checks.append(("fm_strongest_single",
                   results["native_fm"]["fid"]
                   <= min(results["native_ddpm"]["fid"],
                          results["ddpm_to_fm"]["fid"]) * 1.15))
    checks.append(("combined_raises_diversity",
                   max(results["combined_same_sched"]["diversity"],
                       results["combined_diff_sched"]["diversity"])
                   >= results["native_fm"]["diversity"] * 0.95))
    lines += ["", "paper-direction checks:"]
    for name, ok in checks:
        lines.append(f"- {name}: {'PASS' if ok else 'miss (scale-limited)'}")
    write_report("table3", lines)

    return [(f"table3_{k}", v["us_per_call"], v["fid"])
            for k, v in results.items()]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
