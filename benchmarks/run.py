"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's metric:
FID-proxy, reduction factor, acceleration, dominant-roofline seconds).
Markdown reports land in benchmarks/artifacts/.

``--json-out PATH`` additionally runs the sampler hot-path benchmark and
writes its JSON artifact (img/s, expert-forwards/step, retrace count) so
future PRs can track the serving-perf trajectory; ``--only sampler`` skips
the paper-table modules for a quick perf check.
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None,
                    help="write BENCH_sampler.json-style artifact here")
    ap.add_argument("--only", default=None,
                    help="run a single module by short name (e.g. 'sampler')")
    ap.add_argument("--plan-refresh", type=int, default=None,
                    help="also run the sampler step-fusion/plan-reuse "
                         "benchmark with this refresh interval R and "
                         "merge its fused_step + plan_reuse sections "
                         "into --json-out (passthrough to "
                         "benchmarks/bench_sampler.py --plan-refresh)")
    args = ap.parse_args()

    from benchmarks import (
        bench_sampler,
        fig3_pretrained_init,
        fig4_threshold,
        roofline,
        table1_monolithic_vs_ddm,
        table2_resources,
        table3_conversion,
        table4_homo_vs_hetero,
    )

    modules = [
        ("table2", table2_resources),
        ("roofline", roofline),
        ("table1", table1_monolithic_vs_ddm),
        ("table3", table3_conversion),
        ("table4", table4_homo_vs_hetero),
        ("fig3", fig3_pretrained_init),
        ("fig4", fig4_threshold),
        ("sampler", bench_sampler),
    ]
    if args.only:
        valid = [n for n, _ in modules]
        modules = [(n, m) for n, m in modules if n == args.only]
        if not modules:
            raise SystemExit(
                f"--only {args.only!r} matches no module; valid: {valid}"
            )
    print("name,us_per_call,derived")
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                print(",".join(str(x) for x in row))
                sys.stdout.flush()
        except Exception as e:  # keep the harness going
            print(f"{name}_ERROR,0,{type(e).__name__}")
            import traceback
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if args.plan_refresh is not None:
        try:
            fused_sec, _ = bench_sampler.collect_and_merge_step_fusion(
                args.json_out, args.plan_refresh
            )
            print(f"sampler_fused_step,"
                  f"{1e6 / max(fused_sec['img_per_s'], 1e-9):.1f},"
                  f"{fused_sec['speedup_with_plan_reuse']:.2f}x_vs_unfused "
                  f"parity={fused_sec['parity_max_abs_diff_vs_unfused']:.3g}")
        except Exception as e:  # keep the harness going (same policy as
            # the module loop above) — a failed step-fusion arm must not
            # drop the sections the other modules already collected from
            # the --json-out write below.
            print(f"fused_step_ERROR,0,{type(e).__name__}")
            import traceback
            traceback.print_exc(file=sys.stderr)
    if args.json_out:
        path = bench_sampler.write_json(args.json_out)
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
