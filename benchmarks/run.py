"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's metric:
FID-proxy, reduction factor, acceleration, dominant-roofline seconds).
Markdown reports land in benchmarks/artifacts/.
"""

import sys
import time


def main() -> None:
    from benchmarks import (
        fig3_pretrained_init,
        fig4_threshold,
        roofline,
        table1_monolithic_vs_ddm,
        table2_resources,
        table3_conversion,
        table4_homo_vs_hetero,
    )

    modules = [
        ("table2", table2_resources),
        ("roofline", roofline),
        ("table1", table1_monolithic_vs_ddm),
        ("table3", table3_conversion),
        ("table4", table4_homo_vs_hetero),
        ("fig3", fig3_pretrained_init),
        ("fig4", fig4_threshold),
    ]
    print("name,us_per_call,derived")
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                print(",".join(str(x) for x in row))
                sys.stdout.flush()
        except Exception as e:  # keep the harness going
            print(f"{name}_ERROR,0,{type(e).__name__}")
            import traceback
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
