"""Shared benchmark substrate.

Trains tiny-but-real heterogeneous ensembles on the synthetic latent
mixture and evaluates them with the exact-Fréchet FID analogue + mean
pairwise-distance diversity analogue (LPIPS↑).  Every paper table maps to
one module here; `run.py` executes all and emits `name,us_per_call,derived`
CSV rows (plus a markdown report under benchmarks/artifacts/).

Scale knobs are deliberately small (CPU CI); the *comparisons* (hetero vs
homo, Top-2 vs Full, converted vs native) are what reproduce the paper.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.data import (
    SyntheticSpec,
    fit_clusters,
    pairwise_diversity,
    sample_fid,
)
from repro.data.pipeline import ExpertDataStream, RouterDataStream
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2
from repro.training import AdamWConfig, ExpertTrainer, RouterTrainer

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# benchmark-scale knobs
LATENT = 8
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", 40))
BATCH = 32
EVAL_SAMPLES = int(os.environ.get("REPRO_BENCH_SAMPLES", 128))
SAMPLE_STEPS = 12


@dataclasses.dataclass
class Ensemble:
    spec: SyntheticSpec
    cfg: object
    rcfg: object
    apply_fn: object
    experts: list
    params: list
    router_fn: object
    monolithic_params: object = None


def train_ensemble(
    *, num_clusters: int = 4, objectives: list[str] | None = None,
    train_monolithic: bool = False, seed: int = 0,
    steps: int = TRAIN_STEPS, schedules: list[str] | None = None,
    same_cluster: bool = False,
) -> Ensemble:
    """Train K isolated experts (+ optional monolithic baseline + router)."""
    spec = SyntheticSpec(num_categories=num_clusters, latent_size=LATENT,
                         separation=3.0)
    cm, _ = fit_clusters(spec, corpus_size=512, num_clusters=num_clusters,
                         num_fine=64, seed=seed)
    cfg = dit_b2().reduced(latent_size=LATENT)
    apply_fn = D.make_expert_apply(cfg)
    objectives = objectives or ["fm"] * num_clusters
    if schedules is None:
        schedules = ["cosine" if o == "ddpm" else "linear"
                     for o in objectives]
    experts, params = [], []
    for cid, (obj, sch) in enumerate(zip(objectives, schedules)):
        trainer = ExpertTrainer(
            apply_fn=apply_fn, objective=obj, schedule_name=sch,
            opt=AdamWConfig(learning_rate=3e-4, warmup_steps=5),
            ema_decay=0.8,   # bench-scale (paper 0.9999 needs >>1e4 steps)
        )
        state = trainer.init_state(
            D.init(cfg, jax.random.PRNGKey(seed + 10 + cid))
        )
        stream = ExpertDataStream(
            spec, cm, cluster_id=0 if same_cluster else cid,
            batch_size=BATCH, seed=seed + cid,
        )
        for i in range(steps):
            state, _ = trainer.train_step(
                state, jax.random.fold_in(jax.random.PRNGKey(seed),
                                          1000 * cid + i),
                stream.next_batch(i),
            )
        experts.append(ExpertSpec(f"e{cid}", obj, sch, apply_fn,
                                  0 if same_cluster else cid))
        params.append(state.ema)

    rcfg = router_b2(num_clusters=num_clusters).reduced(latent_size=LATENT)
    rtrainer = RouterTrainer(
        apply_fn=lambda p, x, t: D.apply(rcfg, p, x, t),
        num_clusters=num_clusters,
    )
    rstate = rtrainer.init_state(D.init(rcfg, jax.random.PRNGKey(seed + 99)))
    rstream = RouterDataStream(spec, cm, batch_size=BATCH, seed=seed + 7)
    for i in range(steps):
        rstate, _ = rtrainer.train_step(
            rstate, jax.random.fold_in(jax.random.PRNGKey(seed + 1), i),
            rstream.next_batch(i),
        )
    router_fn = D.make_router_fn(rcfg, rstate.params)

    mono = None
    if train_monolithic:
        # Matched aggregate budget (§3.2): per-expert batch B over K experts
        # == monolithic batch K·B; we train the monolithic model with the
        # same TOTAL number of samples (steps × K · B / (K · B) = steps).
        trainer = ExpertTrainer(
            apply_fn=apply_fn, objective="fm", schedule_name="linear",
            opt=AdamWConfig(learning_rate=3e-4, warmup_steps=5),
            ema_decay=0.8,
        )
        state = trainer.init_state(D.init(cfg, jax.random.PRNGKey(seed + 5)))
        from repro.data.synthetic import sample_batch
        for i in range(steps):
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 2), i)
            batch = sample_batch(spec, key, BATCH * num_clusters)
            state, _ = trainer.train_step(state, key, batch)
        mono = state.ema

    return Ensemble(spec=spec, cfg=cfg, rcfg=rcfg, apply_fn=apply_fn,
                    experts=experts, params=params, router_fn=router_fn,
                    monolithic_params=mono)


def evaluate_sampler(
    ens: Ensemble, *, strategy: str, top_k: int = 2, threshold: float = 0.5,
    num_samples: int = EVAL_SAMPLES, steps: int = SAMPLE_STEPS,
    cfg_scale: float = 1.0, experts=None, params=None, seed: int = 0,
    ddpm_low_noise_only: float = 0.0, time_map: str = "identity",
) -> dict:
    """Sample and score: FID analogue + diversity analogue + wall time."""
    experts = experts if experts is not None else ens.experts
    params = params if params is not None else ens.params
    shape = (num_samples, LATENT, LATENT, ens.spec.latent_channels)
    t0 = time.time()
    out = sample_ensemble(
        jax.random.PRNGKey(seed), experts, params,
        ens.router_fn, shape,
        config=SamplerConfig(num_steps=steps, cfg_scale=cfg_scale,
                             strategy=strategy, top_k=top_k,
                             threshold=threshold,
                             ddpm_low_noise_only=ddpm_low_noise_only,
                             time_map=time_map),
    )
    out = jax.block_until_ready(out)
    dt = time.time() - t0
    samples = np.asarray(out)
    return {
        "fid": sample_fid(ens.spec, samples),
        "diversity": pairwise_diversity(samples),
        "us_per_call": dt / num_samples * 1e6,
        "finite": bool(np.isfinite(samples).all()),
    }


def write_report(name: str, lines: list[str]) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.md")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path
