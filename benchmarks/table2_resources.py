"""Table 2 — resource-efficiency accounting (exact reproduction).

The 16×/14× reductions are arithmetic over training configuration, not a
measurement; this benchmark reproduces the accounting exactly from §6.2/
§6.4 and verifies the paper's own numbers, plus derives per-expert FLOPs
and the VRAM claim from the DiT-XL/2 architecture.
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import write_report
from repro.models import dit as D
from repro.models.config import dit_xl2

# paper constants
DDM_GPU_DAYS = 1176.0
DDM_IMAGES = 158e6
OURS_GPU_DAYS = 72.0          # 8 experts × 9 A40-days (§6.4)
OURS_IMAGES = 11e6
EXPERTS = 8
STEPS = 500_000
BATCH = 128
LATENT_TOKENS = 256           # 32×32×4 latents, 2×2 patches


def run() -> list[tuple[str, float, float]]:
    compute_red = DDM_GPU_DAYS / OURS_GPU_DAYS
    data_red = DDM_IMAGES / OURS_IMAGES

    cfg = dit_xl2()
    shapes = jax.eval_shape(lambda k: D.init(cfg, k), jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))

    # per-expert training FLOPs ≈ 6 · params · tokens · steps · batch
    tokens = LATENT_TOKENS
    train_flops = 6.0 * n_params * tokens * BATCH * STEPS
    # A40: 149.7 TFLOP/s bf16 peak; 40% MFU assumption
    a40 = 149.7e12 * 0.4
    days = train_flops / a40 / 86400

    # VRAM: params+grads fp16 + Adam fp32 + EMA fp32 + activations
    vram = n_params * (2 + 2 + 8 + 4) / 1e9

    lines = [
        "# Table 2 — Resource comparison (accounting reproduction)",
        "",
        f"- compute reduction: {DDM_GPU_DAYS:.0f} → {OURS_GPU_DAYS:.0f} "
        f"GPU-days = **{compute_red:.1f}×** (paper: 16×)",
        f"- data reduction: {DDM_IMAGES/1e6:.0f}M → {OURS_IMAGES/1e6:.0f}M "
        f"= **{data_red:.1f}×** (paper: 14×)",
        f"- DiT-XL/2 expert params: **{n_params/1e6:.0f}M** (paper: 605M "
        "after AdaLN-Single; 891M per-block baseline)",
        f"- per-expert train FLOPs (500K steps × batch 128 × 256 tokens): "
        f"{train_flops:.2e}",
        f"- implied A40-days/expert @40% MFU: {days:.1f} "
        "(paper §6.4: ≈9 → 72 total for 8 experts)",
        f"- train-state VRAM/expert: {vram:.1f} GB "
        "(paper: 20–48 GB single-GPU envelope)",
    ]
    write_report("table2", lines)
    return [
        ("table2_compute_reduction_x", 0.0, round(compute_red, 2)),
        ("table2_data_reduction_x", 0.0, round(data_red, 2)),
        ("table2_xl2_params_M", 0.0, round(n_params / 1e6, 1)),
        ("table2_days_per_expert", 0.0, round(days, 2)),
        ("table2_vram_GB", 0.0, round(vram, 1)),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
