"""Table 4 / §3.4 — homogeneous (KFM) vs heterogeneous (1DDPM:(K-1)FM)
under ALIGNED inference settings (same sampler, steps, CFG).

Paper claim: 2DDPM:6FM beats 8FM on FID (11.88 vs 12.45) and intra-prompt
diversity (LPIPS 0.631 vs 0.617).  Here: 4-expert ensembles, FID analogue
+ intra-prompt diversity analogue (multiple seeds per 'prompt').
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    LATENT,
    SAMPLE_STEPS,
    evaluate_sampler,
    train_ensemble,
    write_report,
)
from repro.core import SamplerConfig, sample_ensemble
from repro.data import pairwise_diversity


def intra_prompt_diversity(ens, *, prompts: int = 8, per: int = 4) -> float:
    """§3.4.1: generate `per` samples per prompt, mean pairwise distance
    within each prompt's outputs (prompt == conditioning seed here)."""
    vals = []
    for p in range(prompts):
        text = jax.random.normal(
            jax.random.PRNGKey(1000 + p),
            (per, ens.cfg.text_len, ens.cfg.text_dim),
        )
        out = sample_ensemble(
            jax.random.PRNGKey(2000 + p), ens.experts, ens.params,
            ens.router_fn, (per, LATENT, LATENT, 4),
            cond={"text_emb": text},
            config=SamplerConfig(num_steps=SAMPLE_STEPS, cfg_scale=1.0,
                                 strategy="topk", top_k=2),
        )
        vals.append(pairwise_diversity(np.asarray(out)))
    return float(np.mean(vals))


def run() -> list[tuple[str, float, float]]:
    K = 4
    homo = train_ensemble(num_clusters=K, objectives=["fm"] * K, seed=0)
    hetero = train_ensemble(
        num_clusters=K, objectives=["ddpm", "fm", "fm", "fm"], seed=0
    )

    r_homo = evaluate_sampler(homo, strategy="topk", top_k=2)
    r_het = evaluate_sampler(hetero, strategy="topk", top_k=2)
    # §7.3: restrict converted-DDPM experts to the low-noise regime — at
    # short training budgets this is essential because Prop. 1's SNR
    # weighting makes ε-experts converge slowest exactly at high noise.
    r_het_gated = evaluate_sampler(hetero, strategy="topk", top_k=2,
                                   ddpm_low_noise_only=0.5)
    d_homo = intra_prompt_diversity(homo)
    d_het = intra_prompt_diversity(hetero)

    lines = ["# Table 4 — Homogeneous vs Heterogeneous (aligned settings)",
             "", "| model | FID-proxy↓ | intra-prompt div↑ | us/img |",
             "|---|---|---|---|",
             f"| homogeneous {K}FM | {r_homo['fid']:.3f} | {d_homo:.3f} | "
             f"{r_homo['us_per_call']:.0f} |",
             f"| heterogeneous 1DDPM:{K-1}FM | {r_het['fid']:.3f} | "
             f"{d_het:.3f} | {r_het['us_per_call']:.0f} |",
             f"| hetero + §7.3 low-noise DDPM gate (t<0.5) | "
             f"{r_het_gated['fid']:.3f} | — | "
             f"{r_het_gated['us_per_call']:.0f} |",
             "",
             f"paper: hetero FID 11.88 < homo 12.45; hetero LPIPS 0.631 > "
             f"homo 0.617.",
             f"here: hetero diversity {'>' if d_het > d_homo else '<='} homo "
             "(diversity direction is the paper's robust finding); at short "
             "training budgets the ungated hetero FID suffers from "
             "high-noise ε-experts (Prop. 1 weighting) and recovers with "
             "the paper's own §7.3 low-noise restriction.",
             ]
    write_report("table4", lines)
    return [
        ("table4_homo_fid", r_homo["us_per_call"], r_homo["fid"]),
        ("table4_hetero_fid", r_het["us_per_call"], r_het["fid"]),
        ("table4_hetero_gated_fid", r_het_gated["us_per_call"],
         r_het_gated["fid"]),
        ("table4_homo_intra_div", 0.0, round(d_homo, 4)),
        ("table4_hetero_intra_div", 0.0, round(d_het, 4)),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
