"""Fig. 3 / §3.2.2 — impact of pretrained checkpoint conversion.

Train an FM expert (a) from scratch and (b) initialized from a converted
'ImageNet-DDPM' checkpoint (Eq. 20: transfer patch/pos/blocks, re-init
final layer, fresh text stack).  Paper: 1.2× convergence acceleration and
lower validation loss at equal steps.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BATCH, LATENT, TRAIN_STEPS, write_report
from repro.core import convert_checkpoint
from repro.data import SyntheticSpec, fit_clusters
from repro.data.pipeline import ExpertDataStream
from repro.models import dit as D
from repro.models.config import dit_b2
from repro.training import AdamWConfig, ExpertTrainer


def _train(trainer, params, stream, steps, seed):
    state = trainer.init_state(params)
    losses = []
    for i in range(steps):
        state, m = trainer.train_step(
            state, jax.random.fold_in(jax.random.PRNGKey(seed), i),
            stream.next_batch(i),
        )
        losses.append(m["loss"])
    return losses


def run() -> list[tuple[str, float, float]]:
    spec = SyntheticSpec(num_categories=2, latent_size=LATENT,
                         separation=3.0)
    cm, _ = fit_clusters(spec, corpus_size=512, num_clusters=2, num_fine=64)
    cfg = dit_b2().reduced(latent_size=LATENT)
    apply_fn = D.make_expert_apply(cfg)
    steps = TRAIN_STEPS

    # "ImageNet pretraining": class-free DDPM on the full mixture.
    src_cfg = dit_b2(use_text=False).reduced(latent_size=LATENT)
    pre_trainer = ExpertTrainer(
        apply_fn=D.make_expert_apply(src_cfg), objective="ddpm",
        schedule_name="cosine",
        opt=AdamWConfig(learning_rate=3e-4, warmup_steps=5), ema_decay=0.8,
    )
    pre_state = pre_trainer.init_state(D.init(src_cfg, jax.random.PRNGKey(7)))
    from repro.data.synthetic import sample_batch
    for i in range(steps):
        key = jax.random.fold_in(jax.random.PRNGKey(70), i)
        batch = sample_batch(spec, key, BATCH)
        batch.pop("text_emb")
        pre_state, _ = pre_trainer.train_step(pre_state, key, batch)

    stream = ExpertDataStream(spec, cm, cluster_id=0, batch_size=BATCH)
    trainer = ExpertTrainer(
        apply_fn=apply_fn, objective="fm", schedule_name="linear",
        opt=AdamWConfig(learning_rate=3e-4, warmup_steps=5), ema_decay=0.8,
    )
    scratch = _train(trainer, D.init(cfg, jax.random.PRNGKey(1)),
                     stream, steps, seed=11)
    template = D.init(cfg, jax.random.PRNGKey(2))
    converted, report = convert_checkpoint(
        pre_state.params, template, rng=jax.random.PRNGKey(3)
    )
    warm = _train(trainer, converted, stream, steps, seed=11)

    s_final = float(np.mean(scratch[-5:]))
    w_final = float(np.mean(warm[-5:]))
    # convergence acceleration: steps for scratch to reach warm's final loss
    reach = next((i for i, l in enumerate(scratch) if l <= w_final),
                 len(scratch))
    accel = reach / max(
        next((i for i, l in enumerate(warm) if l <= w_final), len(warm)), 1
    )

    lines = ["# Fig. 3 — Pretrained checkpoint conversion",
             "",
             f"- transfer report: { {k: v for k, v in report.items()} }",
             f"- scratch final loss ({steps} steps): {s_final:.4f}",
             f"- converted-init final loss: {w_final:.4f}",
             f"- convergence acceleration (steps-to-match): {accel:.2f}× "
             "(paper: 1.2×)",
             ]
    write_report("fig3", lines)
    return [
        ("fig3_scratch_loss", 0.0, round(s_final, 4)),
        ("fig3_pretrained_loss", 0.0, round(w_final, 4)),
        ("fig3_acceleration_x", 0.0, round(float(accel), 3)),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
