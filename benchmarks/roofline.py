"""§Roofline report generator — reads the dry-run artifacts and emits the
per-(arch × shape × mesh) roofline table (markdown) used by EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
OUT = os.path.join(os.path.dirname(__file__), "artifacts", "roofline.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_all(mesh: str = "16x16", tag: str = "") -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(ART, f"*_{mesh}*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return rows


def fmt(x: float) -> str:
    return f"{x:.2e}"


def table(rows: list[dict]) -> list[str]:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS/dev | useful | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['compute_s'])} | "
            f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {fmt(r['model_flops_per_device'])} | "
            f"{r['useful_flops_ratio']:.2f} | {diagnose(r)} |"
        )
    return lines


def diagnose(r: dict) -> str:
    rf = r["roofline"]
    dom = rf["dominant"]
    if dom == "collective":
        by = r["collectives"]["bytes_by_type"]
        worst = max(by, key=by.get) if by else "?"
        return (f"{worst} traffic dominates — overlap or reshard "
                "(e.g. reduce-scatter TP, fewer gathers)")
    if dom == "memory":
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            return "param+cache streaming (expected for decode) — " \
                   "quantize cache / batch more requests"
        return "activation traffic — fuse (Pallas), chunk-remat attention"
    return "MXU-bound — good; raise useful-flops ratio"


def load_tagged() -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("tag"):
            rows.append(r)
    return rows


def main() -> None:
    lines = ["# Roofline (single-pod 16×16, TPU v5e: 197 TF bf16 / "
             "819 GB/s HBM / 50 GB/s ICI)", ""]
    rows = load_all("16x16")
    lines += table(rows)
    tagged = load_tagged()
    if tagged:
        lines += ["", "# §Perf optimized variants (tagged artifacts)", "",
                  "| arch | shape | tag | compute s | memory s | "
                  "collective s | dominant |", "|---|---|---|---|---|---|---|"]
        for r in tagged:
            rf = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['tag']} | "
                f"{fmt(rf['compute_s'])} | {fmt(rf['memory_s'])} | "
                f"{fmt(rf['collective_s'])} | {rf['dominant']} |"
            )
    lines += ["", "# Multi-pod (2×16×16) deltas", ""]
    rows2 = load_all("2x16x16")
    if rows2:
        lines += table(rows2)
    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(rows)} + {len(tagged)} + {len(rows2)} rows)")


def run() -> list[tuple[str, float, float]]:
    """CSV hook for run.py: emit dominant-term seconds per pair."""
    main()
    out = []
    for r in load_all("16x16"):
        rf = r["roofline"]
        out.append((
            f"roofline_{r['arch']}_{r['shape']}",
            round(r["compile_s"] * 1e6, 1),
            round(max(rf["compute_s"], rf["memory_s"],
                      rf["collective_s"]), 6),
        ))
    return out


if __name__ == "__main__":
    main()
