"""One-kernel ragged grouped expert GEMM (ROADMAP perf item 1).

Acceptance gates for the ragged dispatch stack:
  (a) ragged_gemm == ref_ragged_gemm across dense / int8 / fp8 operand
      sweeps, including empty segments, single-expert and all-experts
      tile maps, and dead capacity slots whose NaN weights stay inert;
  (b) the int8 MXU contraction accumulates in int32 (asserted on the
      jaxpr) and the fp8 contraction in float32;
  (c) the debug tile counter proves grid steps scale with actual rows
      only — empty expert segments cost zero tiles;
  (d) ops.ragged_expert_matmul (Pallas and fallback paths) matches the
      gathered dense einsum, with quantized storage inside the store
      dequant error envelope;
  (e) RaggedExecutor == GroupedExecutor bitwise on a real DiT ensemble
      (dense store; CFG drop_mask, stacked-null, and no-text variants)
      and within quantized bounds for int8/fp8 stores, end-to-end
      through sample_ensemble.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExpertSpec,
    GroupedExecutor,
    RaggedExecutor,
    SamplerConfig,
    make_dispatch_plan,
    plan_from_slots,
    resolve_dispatch,
    sample_ensemble,
)
from repro.core.conversion import ConversionConfig
from repro.core.param_store import make_store
from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.ragged_gemm import ragged_gemm
from repro.models import dit as D
from repro.models.config import dit_b2

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def _quantize(w, dtype):
    """Per-expert symmetric quantization matching QuantizedStore's math."""
    qmax = 127.0 if dtype == "int8" else 448.0
    axes = tuple(range(1, w.ndim))
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=axes), 1e-12) / qmax
    q = w / scale.reshape((-1,) + (1,) * (w.ndim - 1))
    if dtype == "int8":
        q = jnp.clip(jnp.round(q), -127, 127).astype(jnp.int8)
    else:
        q = q.astype(jnp.float8_e4m3fn)
    return q, scale


# --- (a) kernel vs oracle ----------------------------------------------------

RAGGED_CASES = [
    # (m, d, f, k_cap, block_m, block_f, seed)
    (256, 32, 128, 4, 64, 128, 0),
    (128, 16, 256, 3, 32, 128, 1),
    (64, 48, 128, 8, 8, 128, 2),       # 8-row tiles (TPU sublane floor)
    (512, 64, 384, 2, 128, 128, 3),
]


@pytest.mark.parametrize("m,d,f,k,bm,bf,seed", RAGGED_CASES)
def test_ragged_gemm_dense_sweep(m, d, f, k, bm, bf, seed):
    x = _rand((m, d), seed=seed)
    w = _rand((k, d, f), seed=seed + 10)
    te = jax.random.randint(jax.random.PRNGKey(seed + 20), (m // bm,), 0, k)
    out = ragged_gemm(x, w, te, block_m=bm, block_f=bf, interpret=True)
    ref = R.ref_ragged_gemm(x, w, te)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("m,d,f,k,bm,bf,seed", RAGGED_CASES[:2])
def test_ragged_gemm_int8_bitwise_vs_oracle(m, d, f, k, bm, bf, seed):
    """int8×int8→int32 accumulation is exact integer math, and the dequant
    epilogue multiplies in the oracle's order — so kernel == oracle at the
    bit level, not just within tolerance."""
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (m, d), -127, 128).astype(jnp.int8)
    w = jax.random.randint(ky, (k, d, f), -127, 128).astype(jnp.int8)
    xs = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,)) + 0.01
    ws = jax.random.uniform(jax.random.PRNGKey(seed + 2), (k,)) + 0.01
    te = jax.random.randint(jax.random.PRNGKey(seed + 3), (m // bm,), 0, k)
    out = ragged_gemm(x, w, te, xs, ws, block_m=bm, block_f=bf,
                      interpret=True)
    ref = R.ref_ragged_gemm(x, w, te, xs, ws)
    assert out.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("m,d,f,k,bm,bf,seed", RAGGED_CASES[:2])
def test_ragged_gemm_fp8_vs_oracle(m, d, f, k, bm, bf, seed):
    x = _rand((m, d), seed=seed).astype(jnp.float8_e4m3fn)
    w = _rand((k, d, f), seed=seed + 10).astype(jnp.float8_e4m3fn)
    xs = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,)) + 0.01
    ws = jax.random.uniform(jax.random.PRNGKey(seed + 2), (k,)) + 0.01
    te = jax.random.randint(jax.random.PRNGKey(seed + 3), (m // bm,), 0, k)
    out = ragged_gemm(x, w, te, xs, ws, block_m=bm, block_f=bf,
                      interpret=True)
    ref = R.ref_ragged_gemm(x, w, te, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("tile_map", ["single", "all", "sparse"])
def test_ragged_gemm_segment_shapes(tile_map):
    """Single-expert, all-experts-hit, and mostly-empty segment maps all
    reduce to the same per-tile contract."""
    m, d, f, k, bm = 128, 16, 128, 8, 16
    x = _rand((m, d), seed=4)
    w = _rand((k, d, f), seed=5)
    gm = m // bm
    te = {
        "single": jnp.zeros((gm,), jnp.int32),
        "all": jnp.arange(gm, dtype=jnp.int32) % k,
        "sparse": jnp.where(jnp.arange(gm) < gm // 2, 2, 5).astype(jnp.int32),
    }[tile_map]
    out = ragged_gemm(x, w, te, block_m=bm, block_f=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(R.ref_ragged_gemm(x, w, te)),
        rtol=1e-6, atol=1e-5,
    )


def test_ragged_gemm_dead_slots_stay_inert():
    """K_cap capacity slots the plan never references (evicted / invalid
    validity-mask entries) are never DMA'd: NaN weights in those leaves
    cannot poison the output."""
    m, d, f, k, bm = 64, 16, 128, 6, 16
    x = _rand((m, d), seed=6)
    w = _rand((k, d, f), seed=7)
    live = jnp.array([1, 4])
    dead = jnp.array([0, 2, 3, 5])
    w = w.at[dead].set(jnp.nan)
    te = live[jnp.arange(m // bm) % 2].astype(jnp.int32)
    out = ragged_gemm(x, w, te, block_m=bm, block_f=128, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(R.ref_ragged_gemm(x, w, te)),
        rtol=1e-6, atol=1e-5,
    )


# --- (b) accumulation dtypes -------------------------------------------------

def test_int8_contraction_accumulates_in_int32():
    """The quantized MXU contract: int8 operands must accumulate in int32
    (exact) — a float32 accumulation would silently round 8-bit products."""
    x = jnp.ones((16, 8), jnp.int8)
    w = jnp.ones((2, 8, 128), jnp.int8)
    te = jnp.zeros((2,), jnp.int32)
    scales = jnp.ones((16,)), jnp.ones((2,))
    jaxpr = str(jax.make_jaxpr(
        lambda *a: ragged_gemm(*a, block_m=8, block_f=128, interpret=True)
    )(x, w, te, *scales))
    prefs = re.findall(r"preferred_element_type=(\w+)", jaxpr)
    assert prefs == ["int32"], prefs
    assert "i8[" in jaxpr            # operands reach the dot as int8


def test_fp8_contraction_accumulates_in_float32():
    x = jnp.ones((16, 8), jnp.float8_e4m3fn)
    w = jnp.ones((2, 8, 128), jnp.float8_e4m3fn)
    te = jnp.zeros((2,), jnp.int32)
    scales = jnp.ones((16,)), jnp.ones((2,))
    jaxpr = str(jax.make_jaxpr(
        lambda *a: ragged_gemm(*a, block_m=8, block_f=128, interpret=True)
    )(x, w, te, *scales))
    prefs = re.findall(r"preferred_element_type=(\w+)", jaxpr)
    assert prefs == ["float32"], prefs


# --- (c) zero-cost empty segments (runtime tile count) -----------------------

def test_grid_steps_scale_with_rows_not_experts():
    """The runtime proof of the ragged economy: the executed-tile map has
    exactly (M/block_m)·(F/block_f) entries whether one expert or eight
    absorb the rows, and growing the resident capacity K adds nothing."""
    m, d, f, bm, bf = 128, 16, 256, 16, 128
    x = _rand((m, d), seed=8)
    gm, gf = m // bm, f // bf
    counts = []
    for k, spread in [(8, False), (8, True), (64, True)]:
        w = _rand((k, d, f), seed=9)
        te = (jnp.arange(gm, dtype=jnp.int32) % k if spread
              else jnp.zeros((gm,), jnp.int32))
        out, tiles = ragged_gemm(x, w, te, block_m=bm, block_f=bf,
                                 interpret=True, debug=True)
        assert tiles.shape == (gm, gf)
        assert bool(jnp.all(tiles == 1))   # each grid step ran exactly once
        counts.append(int(tiles.sum()))
    # one expert hit vs all hit vs 8× capacity: identical tile counts
    assert counts == [gm * gf] * 3


def test_tile_misalignment_is_loud():
    x = _rand((100, 16))
    w = _rand((2, 16, 128))
    with pytest.raises(ValueError, match="tile-aligned"):
        ragged_gemm(x, w, jnp.zeros((2,), jnp.int32),
                    block_m=64, block_f=128, interpret=True)
    with pytest.raises(ValueError, match="x_scale"):
        ragged_gemm(x.astype(jnp.int8)[:64], w.astype(jnp.int8),
                    jnp.zeros((1,), jnp.int32),
                    block_m=64, block_f=128, interpret=True)


# --- (d) ops.ragged_expert_matmul wrapper ------------------------------------

def test_ragged_block_m_policy():
    assert ops.ragged_block_m(16) == 16
    assert ops.ragged_block_m(256) == 256
    assert ops.ragged_block_m(1024) == 256
    assert ops.ragged_block_m(2560) == 160    # halves under the cap
    assert ops.ragged_block_m(8) == 8
    assert ops.ragged_block_m(12) is None     # below-sublane remainder
    assert ops.ragged_block_m(7) is None
    assert ops.ragged_block_m(0) is None


@pytest.mark.parametrize("force_pallas", ["1", "0"])
def test_ragged_expert_matmul_matches_gathered_einsum(
    force_pallas, monkeypatch
):
    """Wrapper == gathered dense einsum on both the Pallas (interpret) and
    fallback paths, with a non-tile-aligned output width (F=40 pads to the
    _tile_pad lane multiple and slices back)."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", force_pallas)
    P, m, d, f, K = 6, 16, 32, 40, 4
    x = _rand((P, m, d), seed=10)
    w = _rand((K, d, f), seed=11)
    b = _rand((K, f), seed=12)
    eids = jax.random.randint(jax.random.PRNGKey(13), (P,), 0, K)
    out = ops.ragged_expert_matmul(x, w, eids, bias=b)
    ref = jnp.einsum("pmd,pdf->pmf", x, w[eids]) + b[eids][:, None, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


def test_ragged_expert_matmul_narrow_groups_take_fallback(monkeypatch):
    """Row groups below the 8-row sublane floor (e.g. per-pair vectors)
    run the dense-math fallback even when Pallas is forced."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    P, d, f, K = 5, 16, 24, 3
    x = _rand((P, 1, d), seed=14)
    w = _rand((K, d, f), seed=15)
    eids = jnp.array([0, 2, 1, 2, 0], jnp.int32)
    out = ops.ragged_expert_matmul(x, w, eids)
    ref = jnp.einsum("pmd,pdf->pmf", x, w[eids])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("qdtype,bound", [("int8", 0.03), ("fp8", 0.12)])
@pytest.mark.parametrize("force_pallas", ["1", "0"])
def test_ragged_expert_matmul_quantized_bounds(
    qdtype, bound, force_pallas, monkeypatch
):
    """Quantized storage ends within the store-dequant error envelope of
    the full-precision contraction on both execution paths."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", force_pallas)
    P, m, d, f, K = 6, 16, 32, 40, 4
    x = _rand((P, m, d), seed=16)
    wf = _rand((K, d, f), seed=17)
    eids = jax.random.randint(jax.random.PRNGKey(18), (P,), 0, K)
    dense = jnp.einsum("pmd,pdf->pmf", x, wf[eids])
    q, scale = _quantize(wf, qdtype)
    out = ops.ragged_expert_matmul(x, q, eids, w_scale=scale)
    rel = float(jnp.max(jnp.abs(out - dense)) / jnp.max(jnp.abs(dense)))
    assert rel < bound, rel


# --- (e) executor + end-to-end parity on a real DiT --------------------------

_CFG = dit_b2().reduced(d_model=64, num_heads=2, text_dim=16, text_len=4)
_K, _B, _TOPK = 4, 5, 2


@pytest.fixture(scope="module")
def dit_ensemble():
    keys = jax.random.split(KEY, _K)
    params = [D.init(_CFG, k) for k in keys]
    stacked = D.stack_expert_params(params)
    apply_fn = D.make_expert_apply(_CFG)
    ragged_fn = D.make_ragged_expert_apply(_CFG)
    return params, stacked, apply_fn, ragged_fn


def _plan(b=_B, k=_TOPK, seed=1):
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed), (b, _K)), -1
    )
    return make_dispatch_plan(probs, k)


def _latents(b=_B, seed=2):
    shape = (b, _CFG.latent_size, _CFG.latent_size, _CFG.latent_channels)
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


@pytest.mark.parametrize("variant", ["drop_mask", "stacked_null", "no_text"])
def test_ragged_executor_matches_grouped_bitwise(dit_ensemble, variant):
    _, stacked, apply_fn, ragged_fn = dit_ensemble
    store = make_store(stacked)
    x, tb = _latents(), jax.random.uniform(jax.random.PRNGKey(3), (_B,))
    text = _rand((_B, _CFG.text_len, _CFG.text_dim), seed=4)
    if variant == "drop_mask":
        g = 2
        cond_g = {
            "text_emb": jnp.stack([text, text], axis=1),
            "drop_mask": jnp.broadcast_to(
                jnp.array([False, True])[None], (_B, 2)
            ),
        }
    elif variant == "stacked_null":
        g = 2
        null = _rand((_B, _CFG.text_len, _CFG.text_dim), seed=5)
        cond_g = {"text_emb": jnp.stack([text, null], axis=1)}
    else:
        g, cond_g = 1, {}
    tab = jnp.ones((5, _K), jnp.float32)
    conv = ConversionConfig()
    plan = _plan()
    pg, wg, ig = GroupedExecutor(apply_fn, store, conv).predictions(
        plan, x, tb, cond_g, g, tab
    )
    pr, wr, ir = RaggedExecutor(ragged_fn, store, conv).predictions(
        plan, x, tb, cond_g, g, tab
    )
    assert pr.shape == pg.shape
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(wg), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(ig), np.asarray(ir))


@pytest.mark.parametrize("qdtype", ["int8", "fp8"])
def test_ragged_executor_quantized_matches_grouped(dit_ensemble, qdtype):
    """Quantized stores: the fallback dequant multiplies in the store's
    exact float32 order, so ragged == grouped bitwise off-TPU too."""
    _, stacked, apply_fn, ragged_fn = dit_ensemble
    store = make_store(stacked, dtype=qdtype)
    x, tb = _latents(seed=6), jax.random.uniform(jax.random.PRNGKey(7), (_B,))
    text = _rand((_B, _CFG.text_len, _CFG.text_dim), seed=8)
    cond_g = {
        "text_emb": jnp.stack([text, text], axis=1),
        "drop_mask": jnp.broadcast_to(
            jnp.array([False, True])[None], (_B, 2)
        ),
    }
    tab = jnp.ones((5, _K), jnp.float32)
    conv = ConversionConfig()
    plan = _plan(seed=9)
    pg, _, _ = GroupedExecutor(apply_fn, store, conv).predictions(
        plan, x, tb, cond_g, 2, tab
    )
    pr, _, _ = RaggedExecutor(ragged_fn, store, conv).predictions(
        plan, x, tb, cond_g, 2, tab
    )
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(pr))


def test_ragged_executor_dead_validity_slots(dit_ensemble):
    """A plan over capacity K with invalid slots remapped to weight-0
    (routed_slots semantics): ragged == grouped when some slots never
    receive an assignment."""
    _, stacked, apply_fn, ragged_fn = dit_ensemble
    store = make_store(stacked)
    # all assignments on experts {0, 3}: segments 1 and 2 are empty
    idx = jnp.array([[0, 3]] * _B, jnp.int32)
    w = jnp.full((_B, 2), 0.5)
    plan = plan_from_slots(idx, w, _K)
    x, tb = _latents(seed=10), jnp.full((_B,), 0.4)
    tab = jnp.ones((5, _K), jnp.float32)
    conv = ConversionConfig()
    pg, _, _ = GroupedExecutor(apply_fn, store, conv).predictions(
        plan, x, tb, {}, 1, tab
    )
    pr, _, _ = RaggedExecutor(ragged_fn, store, conv).predictions(
        plan, x, tb, {}, 1, tab
    )
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(pr))


def test_resolve_dispatch_ragged_rules():
    # auto prefers ragged when the expert set publishes a ragged forward
    assert resolve_dispatch("auto", "routed", True, False, True) == "ragged"
    assert resolve_dispatch("auto", "routed", True, False, False) == "grouped"
    # batch-uniform plans keep the single-forward gathered path
    assert resolve_dispatch("auto", "routed", True, True, True) == "gathered"
    # explicit ragged needs the forward, stackable params, routed mode
    assert resolve_dispatch("ragged", "routed", True, False, True) == "ragged"
    with pytest.raises(ValueError, match="ragged_apply_fn"):
        resolve_dispatch("ragged", "routed", True, False, False)
    with pytest.raises(ValueError, match="stackable"):
        resolve_dispatch("ragged", "routed", False)
    with pytest.raises(ValueError, match="routed"):
        resolve_dispatch("ragged", "dense", True)


def test_sample_ensemble_ragged_end_to_end(dit_ensemble):
    """Full sampler: dispatch='ragged' == dispatch='grouped' bitwise, and
    'auto' now lands on the ragged backend for this expert set."""
    params, stacked, apply_fn, ragged_fn = dit_ensemble
    experts = [
        ExpertSpec(
            f"e{i}", "ddpm" if i % 2 == 0 else "fm",
            "cosine" if i % 2 == 0 else "linear", apply_fn, i,
            ragged_apply_fn=ragged_fn,
        )
        for i in range(_K)
    ]

    def router_fn(x, t):
        logits = (
            jnp.tile(jnp.arange(float(_K))[None], (x.shape[0], 1))
            + x.mean(axis=(1, 2, 3))[:, None] * 3.0
        )
        return jax.nn.softmax(logits, axis=-1)

    text = _rand((_B, _CFG.text_len, _CFG.text_dim), seed=11)
    shape = (_B, _CFG.latent_size, _CFG.latent_size, _CFG.latent_channels)
    store = make_store(stacked)
    outs = {}
    for disp in ("grouped", "ragged", "auto"):
        cfg = SamplerConfig(num_steps=2, strategy="topk", top_k=2,
                            cfg_scale=4.0, dispatch=disp)
        outs[disp] = sample_ensemble(
            jax.random.PRNGKey(12), experts, params, router_fn, shape,
            cond={"text_emb": text}, null_cond={}, config=cfg,
            stacked_params=store,
        )
    np.testing.assert_array_equal(
        np.asarray(outs["grouped"]), np.asarray(outs["ragged"])
    )
    np.testing.assert_array_equal(
        np.asarray(outs["auto"]), np.asarray(outs["ragged"])
    )
