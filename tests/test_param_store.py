"""ExpertParamStore: typed stacked params + quantized experts.

Acceptance gates for the param-store layer (core.param_store):
  (a) DenseStore is bit-identical to the raw stacked-pytree convention
      it replaces (gather / expert / static_slice / materialize);
  (b) quantization round-trip error bounds per leaf — int8 ≤ 1e-2 of the
      per-expert-leaf absmax (actual bound 1/254 ≈ 4e-3), fp8 (e4m3,
      3 mantissa bits) ≤ 6.25e-2 element-relative;
  (c) end-to-end sampler parity QuantizedStore vs DenseStore (FID-proxy:
      max-abs final-latent diff under a fixed seed within tolerance), on
      toy and real reduced-DiT experts;
  (d) the routed path never materializes the stacked leaves at full
      precision — dequant runs through the fused ``hetero_fuse_dequant``
      path on gathered/sliced views only;
  (e) resident-byte accounting: int8 ≥ 3.5× smaller than the fp32 dense
      store on real DiT expert params;
  (f) stores are pytrees (jit/device_put) and their sharding annotation
      puts per-expert scales on the "expert" axis with their leaves;
  (g) checkpoint loading errors name the missing file / metadata key
      (regression for the opaque-KeyError failure), and
      ``from_checkpoint_dir(param_dtype='int8')`` quantizes on load.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.core.param_store import (
    PARAM_DTYPES,
    DenseStore,
    ExpertParamStore,
    QuantizedStore,
    as_store,
    make_store,
)
from repro.kernels import ops, ref as R
from repro.kernels.hetero_fuse import hetero_fuse_dequant
from repro.launch.mesh import make_expert_mesh
from repro.launch.sharding import expert_param_specs
from repro.models import dit as D
from repro.models.config import dit_b2
from repro.training import expert_metadata, load_checkpoint, save_checkpoint

KEY = jax.random.PRNGKey(0)
LATENT = (4, 4, 2)


def _shared_apply(params, x, t, *, text_emb=None, drop_mask=None, **_):
    null = jnp.float32(0.07)
    if text_emb is None:
        cond_term = null
    else:
        ct = text_emb.mean(axis=(1, 2))[:, None, None, None]
        if drop_mask is not None:
            ct = jnp.where(drop_mask[:, None, None, None], null, ct)
        cond_term = ct
    return x * params["a"] + params["b"] + cond_term


def _ensemble(k=4, leaf_shape=()):
    """Toy stackable ensemble; ``leaf_shape`` grows the param leaves so
    quantization is non-trivial (scalar leaves round-trip exactly)."""
    def leaf(val, key):
        if not leaf_shape:
            return jnp.float32(val)
        return val + 0.01 * jax.random.normal(key, leaf_shape)

    params = [
        {"a": leaf(0.7 + 0.06 * i, jax.random.PRNGKey(50 + i)),
         "b": leaf(0.01 * i, jax.random.PRNGKey(90 + i))}
        for i in range(k)
    ]
    if leaf_shape:
        # keep the toy apply scalar-broadcastable
        params = [{"a": p["a"].mean(), "b": p["b"].mean()} for p in params]
    experts = [
        ExpertSpec(
            f"e{i}", "ddpm" if i % 2 == 0 else "fm",
            "cosine" if i % 2 == 0 else "linear", _shared_apply, i,
        )
        for i in range(k)
    ]

    def router_fn(x, t):
        logits = (
            jnp.tile(jnp.arange(float(k))[None], (x.shape[0], 1))
            + x.mean(axis=(1, 2, 3))[:, None]
        )
        return jax.nn.softmax(logits, axis=-1)

    return experts, params, router_fn


def _jitter(tree, key):
    """Perturb every leaf: freshly-initialized DiT experts carry §2.5
    zero-init output layers, which make the forward weight-independent
    (zero final projection) — parity tests against them would be
    vacuous."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten([
        leaf + 0.02 * jax.random.normal(k, leaf.shape, leaf.dtype)
        for leaf, k in zip(leaves, keys)
    ])


def _dit_params(k=2, latent_size=8, jitter=False):
    cfg = dit_b2().reduced(latent_size=latent_size)
    params = [D.init(cfg, jax.random.PRNGKey(10 + i)) for i in range(k)]
    if jitter:
        params = [_jitter(p, jax.random.PRNGKey(70 + i))
                  for i, p in enumerate(params)]
    return cfg, params


# --- (a) DenseStore is bit-identical to the raw convention ------------------


def test_dense_store_matches_raw_stacked_ops():
    params = [{"w": jnp.full((3, 2), float(i)),
               "b": {"v": jnp.ones((4,)) * i}} for i in range(3)]
    stacked = D.stack_expert_params(params)
    store = make_store(stacked)
    assert isinstance(store, DenseStore) and store.num_experts == 3
    # per-sample gather == raw fancy-indexing
    idx = jnp.array([2, 0])
    got = store.gather(idx)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(stacked["w"][idx]))
    # scalar gather == dynamic_index_in_dim
    one = store.gather(jnp.asarray(1))
    np.testing.assert_array_equal(np.asarray(one["w"]),
                                  np.asarray(stacked["w"][1]))
    # static expert slice == raw [e]
    np.testing.assert_array_equal(np.asarray(store.expert(2)["b"]["v"]),
                                  np.asarray(stacked["b"]["v"][2]))
    sub = store.static_slice(1, 3)
    assert sub.num_experts == 2
    np.testing.assert_array_equal(np.asarray(sub.stacked["w"]),
                                  np.asarray(stacked["w"][1:3]))
    # materialize is the identity (same buffers, no copy semantics change)
    assert store.materialize() is stacked
    # dit delegators keep their historical signatures
    per_sample = D.gather_expert_params(stacked, idx)
    np.testing.assert_array_equal(np.asarray(per_sample["w"]),
                                  np.asarray(stacked["w"][idx]))
    axes = D.stacked_param_logical_axes(stacked)
    assert axes["w"] == ("expert", None, None)
    assert axes["b"]["v"] == ("expert", None)


def test_make_store_dtype_validation_and_bf16_cast():
    stacked = {"w": jnp.ones((2, 3), jnp.float32)}
    with pytest.raises(ValueError, match="param_dtype"):
        make_store(stacked, dtype="int4")
    assert set(PARAM_DTYPES) == {"native", "fp32", "bf16", "int8", "fp8"}
    bf = make_store(stacked, dtype="bf16")
    assert isinstance(bf, DenseStore)
    assert bf.stacked["w"].dtype == jnp.bfloat16
    # the store reports what its leaves actually hold
    assert bf.storage == "bf16"
    assert make_store(stacked).storage == "native"
    assert bf.static_slice(0, 1).storage == "bf16"
    assert bf.nbytes() == make_store(stacked).nbytes() // 2
    # as_store: raw pytree wraps, existing stores pass through untouched
    assert as_store(bf) is bf
    assert as_store(None) is None
    assert isinstance(as_store(stacked), DenseStore)


# --- (b) quantization round-trip error bounds per leaf ----------------------


@pytest.mark.parametrize("storage,bound", [
    # int8: symmetric round-to-nearest, worst case scale/2 = absmax/254
    ("int8", 1e-2),
    # fp8 e4m3: 3 mantissa bits -> ulp/2 <= 2^-4 relative to the element
    ("fp8", 6.25e-2),
])
def test_quantization_round_trip_bounds_per_leaf(storage, bound):
    _, params = _dit_params(k=2)
    stacked = D.stack_expert_params(params)
    store = make_store(stacked, dtype=storage)
    assert isinstance(store, QuantizedStore)
    deq = store.materialize()
    ok_leaves = 0
    for orig, got in zip(jax.tree.leaves(stacked), jax.tree.leaves(deq)):
        orig = np.asarray(orig, np.float32)
        got = np.asarray(got, np.float32)
        k_ = orig.shape[0]
        err = np.abs(got - orig).reshape(k_, -1).max(axis=1)
        absmax = np.abs(orig).reshape(k_, -1).max(axis=1)
        # per-expert-per-leaf relative bound (zero leaves are exact)
        rel = err / np.where(absmax > 0, absmax, 1.0)
        assert (rel <= bound).all(), f"leaf rel err {rel.max()} > {bound}"
        ok_leaves += 1
    assert ok_leaves == len(jax.tree.leaves(stacked))


def test_quantized_access_paths_agree_with_materialize():
    stacked = {
        "w": jax.random.normal(KEY, (4, 5, 3)),
        "b": {"v": jax.random.normal(jax.random.PRNGKey(1), (4, 7))},
        "s": jnp.arange(1.0, 5.0),          # (K,) scalar-per-expert leaf
    }
    store = make_store(stacked, dtype="int8")
    full = store.materialize()
    idx = jnp.array([3, 1, 1])
    got = store.gather(idx)
    for key_ in ("w",):
        np.testing.assert_allclose(np.asarray(got[key_]),
                                   np.asarray(full[key_][idx]), atol=0)
    one = store.gather(jnp.asarray(2))
    np.testing.assert_allclose(np.asarray(one["b"]["v"]),
                               np.asarray(full["b"]["v"][2]), atol=0)
    np.testing.assert_allclose(np.asarray(store.expert(3)["w"]),
                               np.asarray(full["w"][3]), atol=0)
    sub = store.static_slice(1, 3)
    assert sub.num_experts == 2 and sub.storage == "int8"
    np.testing.assert_allclose(np.asarray(sub.materialize()["w"]),
                               np.asarray(full["w"][1:3]), atol=0)


def test_stores_are_pytrees_through_jit():
    stacked = {"w": jax.random.normal(KEY, (4, 6))}
    for dtype in ("native", "int8", "fp8"):
        store = make_store(stacked, dtype=dtype)
        leaves, treedef = jax.tree.flatten(store)
        rebuilt = jax.tree.unflatten(treedef, leaves)
        assert rebuilt.num_experts == 4

        @jax.jit
        def gather_w(s: ExpertParamStore, idx):
            return s.gather(idx)["w"]

        out = gather_w(store, jnp.array([1, 2]))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(store.materialize()["w"][1:3]),
            atol=0,
        )


# --- kernel: fused dequant (Pallas interpret) == oracle ---------------------


@pytest.mark.parametrize("r,t", [(1, 1), (3, 17), (2, 128), (5, 1500)])
def test_hetero_fuse_dequant_kernel_interpret_matches_oracle(r, t):
    q = (jax.random.normal(KEY, (r, t)) * 80).astype(jnp.int8)
    scale = jax.random.uniform(jax.random.PRNGKey(1), (r,),
                               minval=0.01, maxval=0.5)
    ref = R.ref_hetero_fuse_dequant(q, scale)
    # pad to the kernel's tile contract the same way ops.dequant_params does
    tp = -(-t // 128) * 128 if t <= 1024 else -(-t // 1024) * 1024
    qp = jnp.pad(q, ((0, 0), (0, tp - t)))
    out = hetero_fuse_dequant(qp, scale, block_t=min(1024, tp),
                              interpret=True)[:, :t]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0)


def test_dequant_params_wrapper_arbitrary_leaves(monkeypatch):
    for shape in [(3,), (2, 5), (4, 3, 7, 2)]:
        q = (jax.random.normal(KEY, shape) * 50).astype(jnp.int8)
        scale = jnp.linspace(0.1, 0.4, shape[0])
        want = np.asarray(q, np.float32) * np.asarray(scale).reshape(
            (-1,) + (1,) * (len(shape) - 1)
        )
        got = ops.dequant_params(q, scale)
        np.testing.assert_allclose(np.asarray(got), want, atol=0)
        # identical through the interpret-mode Pallas kernel path
        monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
        got_k = ops.dequant_params(q, scale)
        monkeypatch.delenv("REPRO_FORCE_PALLAS")
        np.testing.assert_allclose(np.asarray(got_k), want, atol=0)


# --- (c) end-to-end sampler parity quantized vs dense -----------------------


@pytest.mark.parametrize("dispatch", ["gathered", "grouped"])
def test_sampler_parity_quantized_vs_dense_toy(dispatch):
    experts, params, router_fn = _ensemble(4)
    text = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 6))
    cond, null = {"text_emb": text}, {"text_emb": None}
    base = SamplerConfig(num_steps=5, cfg_scale=3.0, strategy="topk",
                         top_k=2, dispatch=dispatch)
    outs = {}
    for dtype in ("native", "int8", "fp8"):
        cfg = dataclasses.replace(base, param_dtype=dtype)
        outs[dtype] = np.asarray(sample_ensemble(
            KEY, experts, params, router_fn, (3,) + LATENT,
            cond=cond, null_cond=null, config=cfg,
        ))
    # toy scalar leaves quantize exactly up to float rounding
    np.testing.assert_allclose(outs["int8"], outs["native"], atol=1e-4)
    np.testing.assert_allclose(outs["fp8"], outs["native"], atol=1e-2)


def test_sampler_parity_quantized_vs_dense_dit():
    """FID-proxy gate on real (reduced) DiT experts: max-abs final-latent
    diff between the int8 store and the dense store under a fixed seed."""
    cfg, params = _dit_params(k=2, jitter=True)
    apply_fn = D.make_expert_apply(cfg)
    experts = [
        ExpertSpec(f"e{i}", "ddpm" if i == 0 else "fm",
                   "cosine" if i == 0 else "linear", apply_fn, i)
        for i in range(2)
    ]
    router_fn = lambda x, t: jnp.full((x.shape[0], 2), 0.5)  # noqa: E731
    scfg = SamplerConfig(num_steps=3, cfg_scale=1.0, strategy="topk",
                         top_k=2)
    shape = (2, cfg.latent_size, cfg.latent_size, cfg.latent_channels)
    dense = np.asarray(sample_ensemble(
        KEY, experts, params, router_fn, shape, config=scfg,
    ))
    quant = np.asarray(sample_ensemble(
        KEY, experts, params, router_fn, shape,
        config=dataclasses.replace(scfg, param_dtype="int8"),
    ))
    assert np.isfinite(dense).all() and np.isfinite(quant).all()
    # non-vacuous: jittered weights make the forward weight-dependent,
    # so int8 quantization must perturb the latents a measurable amount …
    diff = np.abs(quant - dense).max()
    assert diff > 0.0, "quantization had no effect — vacuous parity test"
    # … while per-leaf relative error ≤ 4e-3 keeps the end-to-end drift
    # within 5% of the dense latent scale (measured ~1.9%; fp8 would sit
    # near 7%, which is why int8 is the serving default candidate).
    rel = diff / np.abs(dense).max()
    assert rel < 0.05, f"int8 sampler drifted {rel:.3f} (rel) from dense"


# --- (d) no full-precision materialization on the routed path ---------------


def test_routed_path_never_materializes_quantized_stack(monkeypatch):
    experts, params, router_fn = _ensemble(4)
    cfg = SamplerConfig(num_steps=3, cfg_scale=1.0, strategy="topk",
                        top_k=2, param_dtype="int8")

    def boom(self, dtype=None):
        raise AssertionError(
            "materialize() called on the routed hot path — quantized "
            "stacked leaves must never expand to full precision"
        )

    monkeypatch.setattr(QuantizedStore, "materialize", boom)
    calls = {"n": 0}
    orig = ops.dequant_params

    def counted(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(ops, "dequant_params", counted)
    for dispatch in ("gathered", "grouped"):
        out = sample_ensemble(
            KEY, experts, params, router_fn, (3,) + LATENT,
            config=dataclasses.replace(cfg, dispatch=dispatch),
        )
        assert np.isfinite(np.asarray(out)).all()
    # every expansion went through the fused dequant op
    assert calls["n"] > 0


# --- (e) resident-byte accounting -------------------------------------------


def test_int8_store_meets_byte_reduction_on_dit_params():
    _, params = _dit_params(k=8)
    stacked = D.stack_expert_params(params)
    dense = make_store(stacked)
    quant = make_store(stacked, dtype="int8")
    reduction = dense.nbytes() / quant.nbytes()
    assert reduction >= 3.5, f"int8 byte reduction {reduction:.2f}x < 3.5x"
    # scales are the only fp32 residue: one per expert per leaf
    n_leaves = len(jax.tree.leaves(stacked))
    scale_bytes = sum(s.size * s.dtype.itemsize
                      for s in jax.tree.leaves(quant.scales))
    assert scale_bytes == n_leaves * 8 * 4


# --- (f) sharding: scales ride the expert axis with their leaves ------------


def test_expert_param_specs_on_quantized_store():
    mesh = make_expert_mesh(1, 1)
    stacked = {"w": jnp.ones((2, 3, 2)), "b": {"v": jnp.ones((2, 4))}}
    store = make_store(stacked, dtype="int8")
    axes = store.logical_axes()
    assert axes.qvals["w"] == ("expert", None, None)
    assert axes.scales["w"] == ("expert",)
    specs = expert_param_specs(store, mesh, logical_axes=axes)
    assert specs.qvals["w"][0] == "expert"
    assert specs.scales["w"] == jax.sharding.PartitionSpec("expert")
    assert specs.scales["b"]["v"] == jax.sharding.PartitionSpec("expert")
    # dit delegator accepts stores too
    axes2 = D.stacked_param_logical_axes(store)
    assert axes2.scales["w"] == ("expert",)


# --- (g) checkpoint loading: named errors + quantize-on-load ----------------


def test_load_checkpoint_missing_file_names_path(tmp_path):
    missing = os.path.join(tmp_path, "nope.npz")
    with pytest.raises(FileNotFoundError, match="nope.npz"):
        load_checkpoint(missing)
    # extension-less form resolves to .npz before erroring
    with pytest.raises(FileNotFoundError, match="nope.npz"):
        load_checkpoint(os.path.join(tmp_path, "nope"))


def test_load_checkpoint_missing_metadata_names_file(tmp_path):
    bad = os.path.join(tmp_path, "raw.npz")
    np.savez(bad, w=np.ones((2, 2)))        # not a save_checkpoint artifact
    with pytest.raises(ValueError, match=r"raw\.npz.*__metadata__"):
        load_checkpoint(bad)


def test_from_checkpoint_dir_quantizes_on_load(tmp_path):
    from repro.launch.serve import ServingEngine
    from repro.models.config import router_b2

    cfg = dit_b2().reduced(latent_size=8)
    for cid, (obj, sch) in enumerate([("ddpm", "cosine"), ("fm", "linear")]):
        save_checkpoint(
            os.path.join(tmp_path, f"expert{cid}.npz"),
            # jittered so quantization measurably perturbs the forward
            # (zero-init output layers would make the parity check vacuous)
            _jitter(D.init(cfg, jax.random.PRNGKey(cid)),
                    jax.random.PRNGKey(40 + cid)),
            metadata=expert_metadata(name=f"e{cid}", objective=obj,
                                     schedule=sch, cluster_id=cid,
                                     arch=cfg.name, step=0),
        )
    rcfg = router_b2(num_clusters=2).reduced(latent_size=8)
    save_checkpoint(os.path.join(tmp_path, "router.npz"),
                    D.init(rcfg, jax.random.PRNGKey(9)),
                    metadata={"num_clusters": 2})
    scfg = SamplerConfig(num_steps=3, cfg_scale=1.0, strategy="topk",
                         top_k=2)
    dense_engine = ServingEngine.from_checkpoint_dir(
        str(tmp_path), dit_cfg=cfg, router_cfg=rcfg, sampler=scfg,
    )
    engine = ServingEngine.from_checkpoint_dir(
        str(tmp_path), dit_cfg=cfg, router_cfg=rcfg, sampler=scfg,
        param_dtype="int8",
    )
    assert isinstance(engine.param_store, QuantizedStore)
    assert engine.sampler.param_dtype == "int8"
    # the full-precision per-expert list is dropped: the quantized store
    # IS the resident representation (~1/4 the bytes of the dense store)
    assert engine.expert_params is None
    ratio = dense_engine.param_store.nbytes() / engine.param_store.nbytes()
    assert ratio >= 3.5
    out = np.asarray(engine.generate(KEY, None, 2))
    ref = np.asarray(dense_engine.generate(KEY, None, 2))
    assert np.isfinite(out).all()
    # same FID-proxy gate as the direct-sampler parity test: within 5%
    # of the dense latent scale, and measurably nonzero (non-vacuous).
    diff = np.abs(out - ref).max()
    assert 0.0 < diff / np.abs(ref).max() < 0.05


def test_quantized_param_dtype_with_heterogeneous_experts_raises():
    from repro.launch.serve import ServingEngine

    def other_apply(params, x, t, **_):
        return 0.4 * x

    experts = [
        ExpertSpec("h0", "ddpm", "cosine", _shared_apply, 0),
        ExpertSpec("h1", "fm", "linear", other_apply, 1),
    ]
    params = [{"a": jnp.float32(0.9), "b": jnp.float32(0.0)}, None]
    # every non-native storage request must fail loudly — bf16 included:
    # silently serving dense fp32 while claiming halved resident bytes
    # would be a lying configuration.
    for pd in ("int8", "fp8", "bf16"):
        with pytest.raises(ValueError, match="homogeneous"):
            ServingEngine(
                experts=experts, expert_params=params, router_fn=None,
                latent_shape=LATENT,
                sampler=SamplerConfig(num_steps=2, strategy="threshold",
                                      param_dtype=pd),
            )


def test_param_dtype_rejected_when_engine_cannot_route():
    """Configurations that resolve to dense/reference execution never
    touch the store: a non-native param_dtype there must be rejected at
    construction (not ignored, and not deferred to a generate() crash
    after the quantized engine dropped its per-expert params)."""
    from repro.launch.serve import ServingEngine

    experts, params, router_fn = _ensemble(4)
    for strategy, engine, pd in [
        ("full", "auto", "int8"),        # dense mode, params dropped
        ("full", "auto", "bf16"),        # dense mode, store would be unused
        ("topk", "reference", "int8"),   # reference engine, params needed
    ]:
        with pytest.raises(ValueError, match="routed"):
            ServingEngine(
                experts=experts, expert_params=params,
                router_fn=router_fn, latent_shape=LATENT, engine=engine,
                sampler=SamplerConfig(num_steps=2, strategy=strategy,
                                      param_dtype=pd),
            )
    # single-expert sets resolve to dense execution too
    with pytest.raises(ValueError, match="2 experts"):
        ServingEngine(
            experts=experts[:1], expert_params=params[:1], router_fn=None,
            latent_shape=LATENT,
            sampler=SamplerConfig(num_steps=2, strategy="topk",
                                  param_dtype="int8"),
        )
