"""Step-fused sampling hot path: kernel parity, plan reuse, cond cache.

Acceptance gates for the step-fusion subsystem (this PR's tentpole):
  (a) the ``hetero_fuse_step`` Pallas kernel (interpret mode) matches its
      ``ref_hetero_fuse_step`` oracle, including non-tile-aligned latent
      shapes through the ``ops.fused_step`` padding wrapper;
  (b) the step-fused sampler (``SamplerConfig.step_fused``, the default)
      with ``plan_refresh_every=1`` is BIT-IDENTICAL to the seed unfused
      three-op chain, on every dispatch backend and CFG formulation;
  (c) ``plan_refresh_every=R>1`` actually skips routing work (runtime-
      counted router executions) and its sampler drift vs per-step
      routing stays bounded on the 8-expert top-2 CFG configuration;
  (d) the serving engine's conditioning LRU deduplicates byte-identical
      embeddings, evicts least-recently-used, and counts hits/misses;
  (e) ``bench_sampler.write_json`` / ``submerge_section`` merge by
      section without dropping sibling entries (previously e2e-only).
"""

import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExpertSpec, SamplerConfig, sample_ensemble
from repro.core.sampling import coeff_tables_cached
from repro.kernels import ops, ref
from repro.kernels.hetero_fuse import hetero_fuse_step
from repro.launch.serve import ServingEngine

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

KEY = jax.random.PRNGKey(0)
LATENT = (4, 4, 2)


def _shared_apply(params, x, t, *, text_emb=None, drop_mask=None, **_):
    null = jnp.float32(0.07)
    if text_emb is None:
        cond_term = null
    else:
        ct = text_emb.mean(axis=(1, 2))[:, None, None, None]
        if drop_mask is not None:
            ct = jnp.where(drop_mask[:, None, None, None], null, ct)
        cond_term = ct
    return x * params["a"] + params["b"] + cond_term


def _ensemble(k=8, apply_fn=_shared_apply):
    params = [
        {"a": jnp.float32(0.7 + 0.06 * i), "b": jnp.float32(0.01 * i)}
        for i in range(k)
    ]
    experts = [
        ExpertSpec(
            f"e{i}", "ddpm" if i % 2 == 0 else "fm",
            "cosine" if i % 2 == 0 else "linear", apply_fn, i,
        )
        for i in range(k)
    ]

    def router_fn(x, t):
        logits = (
            jnp.tile(jnp.arange(float(k))[None], (x.shape[0], 1))
            + x.mean(axis=(1, 2, 3))[:, None] * 3.0
        )
        return jax.nn.softmax(logits, axis=-1)

    return experts, params, router_fn


def _sample(experts, params, router_fn, *, batch=4, cfg=None, **cfg_kw):
    config = cfg if cfg is not None else SamplerConfig(
        num_steps=6, cfg_scale=3.0, strategy="topk", top_k=2, **cfg_kw,
    )
    cond = {"text_emb": jax.random.normal(KEY, (batch, 5, 6))}
    return sample_ensemble(
        KEY, experts, params, router_fn, (batch,) + LATENT,
        cond=cond, null_cond={"text_emb": None}, config=config,
    )


# --- (a) kernel == oracle ---------------------------------------------------


@pytest.mark.parametrize("k,g,b,t", [
    (2, 2, 3, 256),      # the CFG-batched serving shape class
    (3, 1, 2, 128),      # no-guidance single branch
    (1, 2, 1, 1024),     # single slot, full tile
    (4, 2, 2, 2048),     # multi-tile grid
])
def test_fuse_step_kernel_matches_oracle(k, g, b, t):
    keys = jax.random.split(jax.random.PRNGKey(k * 100 + g * 10 + b), 4)
    preds = jax.random.normal(keys[0], (k, g, b, t))
    x = jax.random.normal(keys[1], (b, t))
    w = jax.nn.softmax(jax.random.normal(keys[2], (g, b, k)), axis=-1)
    coef = jax.random.uniform(keys[3], (5, k, g, b), minval=0.05,
                              maxval=1.0)
    dt = jnp.array([0.02], jnp.float32)
    out_kernel = hetero_fuse_step(
        preds, x, w, coef, dt, cfg_scale=7.5, interpret=True,
    )
    out_ref = ref.ref_hetero_fuse_step(preds, x, w, coef, dt, cfg_scale=7.5)
    np.testing.assert_allclose(out_kernel, out_ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("latent", [
    (5, 5, 4),           # 100 floats -> pads to one 128 tile
    (3, 7, 1),           # 21 floats, deeply unaligned
    (11, 10, 10),        # 1100 floats -> pads past one 1024 block
])
def test_fused_step_padding_non_tile_aligned(monkeypatch, latent):
    """ops.fused_step pads unaligned latents up to the kernel tile and the
    padded rows never leak into the result."""
    k, g, b = 2, 2, 3
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    preds = jax.random.normal(keys[0], (k, g * b) + latent)
    x = jax.random.normal(keys[1], (b,) + latent)
    w = jax.nn.softmax(jax.random.normal(keys[2], (g * b, k)), axis=-1)
    coef = jax.random.uniform(keys[3], (5, k, g * b), minval=0.05,
                              maxval=1.0)
    dt = jnp.float32(0.02)

    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    out_pallas = ops.fused_step(preds, x, w, coef, dt, g=g, cfg_scale=4.0)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "0")
    out_oracle = ops.fused_step(preds, x, w, coef, dt, g=g, cfg_scale=4.0)
    assert out_pallas.shape == (b,) + latent
    np.testing.assert_allclose(out_pallas, out_oracle, atol=1e-5, rtol=1e-5)


# --- (b) step-fused == seed unfused chain, bit-identical --------------------


@pytest.mark.parametrize("variant", [
    "grouped", "gathered", "dense_full", "threshold", "two_pass", "no_cfg",
])
def test_step_fused_bit_identical_to_unfused(variant):
    """The fused kernel folds — but must not change — the per-step math:
    max |fused − unfused| == 0 exactly (the acceptance gate the
    ``fused_step`` bench section tracks as parity_max_abs_diff)."""
    experts, params, router_fn = _ensemble(8)
    kw = {}
    if variant in ("grouped", "gathered"):
        kw["dispatch"] = variant
    elif variant == "dense_full":
        kw["strategy"] = "full"
    elif variant == "threshold":
        kw["strategy"] = "threshold"
    elif variant == "two_pass":
        kw["batched_cfg"] = False
    elif variant == "no_cfg":
        kw["cfg_scale"] = 1.0

    base_cfg = SamplerConfig(num_steps=6, cfg_scale=3.0, strategy="topk",
                             top_k=2)
    for key, val in kw.items():
        base_cfg = dataclasses.replace(base_cfg, **{key: val})
    fused = _sample(experts, params, router_fn,
                    cfg=dataclasses.replace(base_cfg, step_fused=True))
    unfused = _sample(experts, params, router_fn,
                      cfg=dataclasses.replace(base_cfg, step_fused=False))
    assert np.isfinite(np.asarray(fused)).all()
    assert float(jnp.abs(fused - unfused).max()) == 0.0


def test_plan_refresh_r1_bit_identical_to_seed():
    """The new default config (step_fused=True, plan_refresh_every=1)
    reproduces the seed sampler bit-for-bit."""
    experts, params, router_fn = _ensemble(8)
    new_default = _sample(experts, params, router_fn)  # PR defaults
    seed_path = _sample(experts, params, router_fn,
                        step_fused=False, plan_refresh_every=1)
    assert float(jnp.abs(new_default - seed_path).max()) == 0.0


# --- (c) plan reuse: routing actually skipped + bounded drift ---------------


def test_plan_refresh_skips_router_executions():
    """R=3 over 6 steps must execute the router exactly twice per run —
    counted at RUNTIME (the lax.cond carry branch pays no routing), not
    at trace time."""
    calls = {"n": 0}

    def _bump():
        calls["n"] += 1

    experts, params, base_router = _ensemble(8)

    def counted_router(x, t):
        jax.debug.callback(_bump)
        return base_router(x, t)

    def run(refresh):
        out = _sample(experts, params, counted_router,
                      plan_refresh_every=refresh)
        jax.block_until_ready(out)
        jax.effects_barrier()

    run(1)
    calls["n"] = 0
    run(1)
    jax.effects_barrier()
    assert calls["n"] == 6          # per-step routing: 6 steps
    calls["n"] = 0
    run(3)
    jax.effects_barrier()
    assert calls["n"] == 2          # refresh at steps 0 and 3 only


@pytest.mark.parametrize("refresh", [2, 4])
def test_plan_refresh_drift_bounded(refresh):
    """8-expert top-2 CFG: reusing the plan for R steps drifts the final
    latents by a bounded amount relative to per-step routing (posteriors
    change slowly in t — the premise plan reuse banks on)."""
    experts, params, router_fn = _ensemble(8)
    per_step = _sample(experts, params, router_fn, plan_refresh_every=1)
    reused = _sample(experts, params, router_fn,
                     plan_refresh_every=refresh)
    assert np.isfinite(np.asarray(reused)).all()
    drift = float(jnp.abs(reused - per_step).max())
    scale = float(jnp.abs(per_step).max())
    assert drift <= 0.25 * scale, (
        f"plan reuse R={refresh} drifted {drift:.4f} "
        f"(latent scale {scale:.4f})"
    )


def test_plan_refresh_rejects_bad_values():
    experts, params, router_fn = _ensemble(2)
    with pytest.raises(ValueError, match="plan_refresh_every"):
        _sample(experts, params, router_fn, plan_refresh_every=0)
    with pytest.raises(ValueError, match="reference"):
        cond = {"text_emb": jax.random.normal(KEY, (2, 5, 6))}
        sample_ensemble(
            KEY, experts, params, router_fn, (2,) + LATENT, cond=cond,
            config=SamplerConfig(num_steps=2, plan_refresh_every=2),
            engine="reference",
        )


def test_coeff_tables_cached_identical_and_shared():
    """The run-key cache returns the same (concrete, non-tracer) table
    object for identical keys and matches a fresh computation."""
    coeff_tables_cached.cache_clear()
    key = (("ddpm", "fm"), ("cosine", "linear"), 6)
    t1 = coeff_tables_cached(key[0], key[1], key[2],
                             SamplerConfig().conversion)
    t2 = coeff_tables_cached(key[0], key[1], key[2],
                             SamplerConfig().conversion)
    assert t1 is t2                 # cache hit, no rebuild
    assert t1.shape == (6, 5, 2)
    assert not isinstance(t1, jax.core.Tracer)


# --- (d) conditioning cache -------------------------------------------------


def _toy_engine(**kw):
    experts, params, router_fn = _ensemble(4)
    return ServingEngine(
        experts=experts, expert_params=params, router_fn=router_fn,
        latent_shape=LATENT,
        sampler=SamplerConfig(num_steps=2, cfg_scale=3.0, top_k=2),
        **kw,
    )


def test_cond_cache_hits_and_lru_eviction():
    engine = _toy_engine(cond_cache_size=2)
    a = np.ones((2, 5, 6), np.float32)
    b = np.full((2, 5, 6), 2.0, np.float32)
    c = np.full((2, 5, 6), 3.0, np.float32)

    ra1 = engine._cached_cond(a)
    ra2 = engine._cached_cond(np.array(a))   # same bytes, new host array
    assert ra1 is ra2                         # deduped to ONE device buffer
    assert engine.stats["cond_cache_hits"] == 1
    assert engine.stats["cond_cache_misses"] == 1

    engine._cached_cond(b)                    # cache: [a, b]
    engine._cached_cond(c)                    # evicts a -> [b, c]
    assert len(engine._cond_cache) == 2
    engine._cached_cond(a)                    # miss again after eviction
    assert engine.stats["cond_cache_misses"] == 4
    assert engine.stats["cond_cache_hits"] == 1
    engine._cached_cond(c)                    # still resident
    assert engine.stats["cond_cache_hits"] == 2


def test_cond_cache_passes_device_arrays_through():
    """Device-resident embeddings skip hashing: dedupe would force a
    blocking device->host copy per request for a buffer the caller is
    already sharing."""
    engine = _toy_engine(cond_cache_size=8)
    dev = jnp.ones((2, 5, 6), jnp.float32)
    engine._cached_cond(dev)
    engine._cached_cond(dev)
    assert engine.stats["cond_cache_hits"] == 0
    assert engine.stats["cond_cache_misses"] == 0
    assert len(engine._cond_cache) == 0


def test_cond_cache_disabled_and_none():
    engine = _toy_engine(cond_cache_size=0)
    assert engine._cached_cond(None) is None
    a = np.ones((1, 2, 3), np.float32)
    engine._cached_cond(a)
    engine._cached_cond(a)
    assert engine.stats["cond_cache_hits"] == 0
    assert engine.stats["cond_cache_misses"] == 0
    assert len(engine._cond_cache) == 0


def test_cond_cache_served_results_match_uncached():
    """Cached conditioning must not change outputs: same request through
    a caching and a cache-disabled engine is bit-identical, and the
    repeat request scores a hit."""
    cached = _toy_engine(cond_cache_size=8)
    uncached = _toy_engine(cond_cache_size=0)
    text = np.asarray(jax.random.normal(KEY, (2, 5, 6)))
    o1 = cached.generate(jax.random.PRNGKey(1), text, 2)
    o2 = uncached.generate(jax.random.PRNGKey(1), text, 2)
    assert float(jnp.abs(o1 - o2).max()) == 0.0
    cached.generate(jax.random.PRNGKey(2), np.array(text), 2)
    assert cached.stats["cond_cache_hits"] == 1


def test_plan_refreshes_counter():
    experts, params, router_fn = _ensemble(4)
    engine = ServingEngine(
        experts=experts, expert_params=params, router_fn=router_fn,
        latent_shape=LATENT,
        sampler=SamplerConfig(num_steps=5, cfg_scale=3.0, top_k=2,
                              plan_refresh_every=2),
    )
    text = jax.random.normal(KEY, (2, 5, 6))
    engine.generate(jax.random.PRNGKey(0), text, 2)
    assert engine.stats["plan_refreshes"] == 3   # ceil(5 / 2)
    h = engine.submit(jax.random.PRNGKey(1), text)
    engine.flush()
    h.result()
    assert engine.stats["plan_refreshes"] == 6


# --- (e) write_json / submerge_section ---------------------------------------


def test_write_json_merges_by_section(tmp_path):
    from benchmarks import bench_sampler

    path = str(tmp_path / "bench.json")
    bench_sampler.write_json(path, {"seed": {"img_per_s": 1.0},
                                    "speedup": 2.0})
    bench_sampler.write_json(path, {"fused_step": {"img_per_s": 3.0}})
    with open(path) as f:
        merged = json.load(f)
    # earlier sections survive, new section lands, same-name overwrites
    assert merged["seed"] == {"img_per_s": 1.0}
    assert merged["fused_step"] == {"img_per_s": 3.0}
    assert merged["speedup"] == 2.0
    bench_sampler.write_json(path, {"speedup": 4.0})
    with open(path) as f:
        assert json.load(f)["speedup"] == 4.0


def test_write_json_survives_corrupt_artifact(tmp_path):
    from benchmarks import bench_sampler

    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        f.write("{not json")
    bench_sampler.write_json(path, {"seed": {"img_per_s": 1.0}})
    with open(path) as f:
        assert json.load(f) == {"seed": {"img_per_s": 1.0}}


def test_submerge_section_keeps_sibling_keys(tmp_path):
    from benchmarks import bench_sampler

    path = str(tmp_path / "bench.json")
    bench_sampler.write_json(
        path, {"plan_reuse": {"R1": {"img_per_s": 1.0}}}
    )
    merged = bench_sampler.submerge_section(
        path, "plan_reuse", {"R4": {"img_per_s": 2.0}}
    )
    assert merged == {"R1": {"img_per_s": 1.0},
                      "R4": {"img_per_s": 2.0}}
    # missing file / missing section degrade to just the new entries
    assert bench_sampler.submerge_section(
        str(tmp_path / "absent.json"), "plan_reuse", {"R2": {}}
    ) == {"R2": {}}
