"""Static HLO cost model: trip counts, dot flops, collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import HloCostModel, parse_hlo
from repro.launch.hlo_analysis import collective_bytes


def test_scan_trip_count_multiplies_flops():
    """A scanned matmul must count L× the body flops (cost_analysis
    famously counts it once — the whole reason this model exists)."""
    d, L = 64, 7

    def f(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jnp.zeros((L, d, d))
    x = jnp.zeros((8, d))
    compiled = jax.jit(f).lower(ws, x).compile()
    totals = HloCostModel(compiled.as_text()).totals()
    expected = 2 * 8 * d * d * L
    assert abs(totals.flops - expected) / expected < 0.05, (
        totals.flops, expected
    )


def test_single_dot_flops():
    a = jnp.zeros((32, 64))
    b = jnp.zeros((64, 16))
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    totals = HloCostModel(compiled.as_text()).totals()
    assert totals.flops == 2 * 32 * 64 * 16


def test_bytes_reasonable_for_elementwise():
    x = jnp.zeros((1024, 1024))
    compiled = jax.jit(lambda x: jnp.tanh(x) + 1.0).lower(x).compile()
    totals = HloCostModel(compiled.as_text()).totals()
    nbytes = 1024 * 1024 * 4
    # read + write, allow fusion-accounting slack
    assert nbytes <= totals.hbm_bytes <= 6 * nbytes


def test_collective_regex_on_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[256,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, to_apply=%add
  ROOT %out = f32[16,128]{1,0} slice(%ar), slice={[0:16], [0:128]}
}
"""
    stats = collective_bytes(hlo)
    ag = 256 * 128 * 4 * (15 / 16)
    ar = 256 * 128 * 4 * 2 * (15 / 16)
    np.testing.assert_allclose(stats.bytes_by_type["all-gather"], ag)
    np.testing.assert_allclose(stats.bytes_by_type["all-reduce"], ar)
    assert stats.count_by_type == {"all-gather": 1, "all-reduce": 1}


def test_parse_hlo_computations():
    x = jnp.zeros((4, 4))
    compiled = jax.jit(lambda x: x @ x).lower(x).compile()
    comps = parse_hlo(compiled.as_text())
    assert comps, "no computations parsed"
    assert any("main" in n for n in comps)
