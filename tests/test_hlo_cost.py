"""Static HLO cost model: trip counts, dot flops, collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import HloCostModel, parse_hlo
from repro.launch.hlo_analysis import (collective_bytes,
                                       compiled_bytes_accessed)


def test_scan_trip_count_multiplies_flops():
    """A scanned matmul must count L× the body flops (cost_analysis
    famously counts it once — the whole reason this model exists)."""
    d, L = 64, 7

    def f(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jnp.zeros((L, d, d))
    x = jnp.zeros((8, d))
    compiled = jax.jit(f).lower(ws, x).compile()
    totals = HloCostModel(compiled.as_text()).totals()
    expected = 2 * 8 * d * d * L
    assert abs(totals.flops - expected) / expected < 0.05, (
        totals.flops, expected
    )


def test_single_dot_flops():
    a = jnp.zeros((32, 64))
    b = jnp.zeros((64, 16))
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    totals = HloCostModel(compiled.as_text()).totals()
    assert totals.flops == 2 * 32 * 64 * 16


def test_bytes_reasonable_for_elementwise():
    x = jnp.zeros((1024, 1024))
    compiled = jax.jit(lambda x: jnp.tanh(x) + 1.0).lower(x).compile()
    totals = HloCostModel(compiled.as_text()).totals()
    nbytes = 1024 * 1024 * 4
    # read + write, allow fusion-accounting slack
    assert nbytes <= totals.hbm_bytes <= 6 * nbytes


def test_collective_regex_on_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[256,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, to_apply=%add
  ROOT %out = f32[16,128]{1,0} slice(%ar), slice={[0:16], [0:128]}
}
"""
    stats = collective_bytes(hlo)
    ag = 256 * 128 * 4 * (15 / 16)
    ar = 256 * 128 * 4 * 2 * (15 / 16)
    np.testing.assert_allclose(stats.bytes_by_type["all-gather"], ag)
    np.testing.assert_allclose(stats.bytes_by_type["all-reduce"], ar)
    assert stats.count_by_type == {"all-gather": 1, "all-reduce": 1}


def test_parse_hlo_computations():
    x = jnp.zeros((4, 4))
    compiled = jax.jit(lambda x: x @ x).lower(x).compile()
    comps = parse_hlo(compiled.as_text())
    assert comps, "no computations parsed"
    assert any("main" in n for n in comps)


# --- compiled_bytes_accessed degradation (interpret-mode/CPU backends) -------


class _FakeCompiled:
    """Stand-in for a jax compiled executable with a fixed cost_analysis."""

    def __init__(self, ca):
        self._ca = ca

    def cost_analysis(self):
        if isinstance(self._ca, Exception):
            raise self._ca
        return self._ca


def test_bytes_accessed_real_compiled_is_nonnegative_float():
    x = jnp.zeros((8, 8))
    compiled = jax.jit(lambda x: x @ x + 1.0).lower(x).compile()
    out = compiled_bytes_accessed(compiled)
    assert isinstance(out, float) and out >= 0.0


def test_bytes_accessed_raising_backend_degrades_to_zero():
    """Backends without a cost model raise from cost_analysis()."""
    fake = _FakeCompiled(NotImplementedError("no cost model on this backend"))
    assert compiled_bytes_accessed(fake) == 0.0


def test_bytes_accessed_empty_cost_analysis_list():
    """Older jax: cost_analysis() -> [] (no properties reported)."""
    assert compiled_bytes_accessed(_FakeCompiled([])) == 0.0


def test_bytes_accessed_missing_key_degrades_to_zero():
    """CPU/interpret builds report flops but no 'bytes accessed' key."""
    assert compiled_bytes_accessed(_FakeCompiled({"flops": 123.0})) == 0.0
    assert compiled_bytes_accessed(_FakeCompiled([{"flops": 1.0}])) == 0.0


def test_bytes_accessed_non_dict_payload_degrades_to_zero():
    assert compiled_bytes_accessed(_FakeCompiled("bogus")) == 0.0
    assert compiled_bytes_accessed(_FakeCompiled(None)) == 0.0


def test_bytes_accessed_reads_key_old_and_new_shapes():
    assert compiled_bytes_accessed(
        _FakeCompiled({"bytes accessed": 42.0})) == 42.0
    assert compiled_bytes_accessed(
        _FakeCompiled([{"bytes accessed": 7.0}])) == 7.0
