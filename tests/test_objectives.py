"""Objectives + Prop. 1 implicit timestep weighting (§2.4)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    DDPM,
    FLOW_MATCHING,
    get_objective,
    get_schedule,
    sample_timesteps,
    target_for,
    w_eps,
    w_v,
    weight_ratio,
)
from repro.core.objectives import sh_v_target, sh_v_to_x0


def test_objective_defaults():
    assert get_objective(DDPM).default_schedule == "cosine"
    assert get_objective(FLOW_MATCHING).default_schedule == "linear"
    assert get_objective(DDPM).predicts == "epsilon"
    assert get_objective(FLOW_MATCHING).predicts == "velocity"


def test_targets():
    lin = get_schedule("linear")
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (2, 4, 4, 2))
    eps = jax.random.normal(jax.random.PRNGKey(1), x0.shape)
    t = jnp.array([0.3, 0.7])
    np.testing.assert_array_equal(target_for("ddpm", lin, x0, eps, t), eps)
    np.testing.assert_allclose(
        target_for("fm", lin, x0, eps, t), eps - x0, atol=1e-6
    )


@settings(max_examples=40, deadline=None)
@given(
    t=st.floats(min_value=0.01, max_value=0.99),
    sched=st.sampled_from(["linear", "cosine"]),
)
def test_prop1_ratio_property(t, sched):
    """Eq. 11: w_v / w_eps == 1/alpha^2 >= 1 for BOTH schedule families
    (the Remark: the structure is schedule-independent)."""
    sch = get_schedule(sched)
    tb = jnp.array([t])
    ratio = float((w_v(sch, tb) / w_eps(sch, tb))[0])
    expected = float(weight_ratio(sch, tb)[0])
    np.testing.assert_allclose(ratio, expected, rtol=1e-4)
    assert ratio >= 1.0 - 1e-6


def test_prop1_divergence_at_high_noise():
    cos = get_schedule("cosine")
    r_low = float(weight_ratio(cos, jnp.array([0.1]))[0])
    r_high = float(weight_ratio(cos, jnp.array([0.99]))[0])
    assert r_high > 100 * r_low


def test_salimans_ho_v_param_recovers_x0():
    """§2.4 notation remark: under VP, x0 = alpha x_t - sigma v."""
    cos = get_schedule("cosine")
    key = jax.random.PRNGKey(2)
    x0 = jax.random.normal(key, (3, 4, 4, 1))
    eps = jax.random.normal(jax.random.PRNGKey(3), x0.shape)
    t = jnp.array([0.2, 0.5, 0.8])
    xt = cos.perturb(x0, eps, t)
    v = sh_v_target(cos, x0, eps, t)
    np.testing.assert_allclose(sh_v_to_x0(cos, xt, v, t), x0, atol=1e-5)


def test_timestep_sampling_domains():
    """§6.3: DDPM samples the discrete grid; FM samples U(0,1)."""
    key = jax.random.PRNGKey(0)
    td = sample_timesteps(key, 512, objective="ddpm")
    tf = sample_timesteps(key, 512, objective="fm")
    # DDPM times land exactly on the 1/999 grid
    grid = np.round(np.asarray(td) * 999)
    np.testing.assert_allclose(np.asarray(td) * 999, grid, atol=1e-4)
    assert 0.0 <= float(tf.min()) and float(tf.max()) < 1.0
    # FM times are NOT all on the grid
    off = np.abs(np.asarray(tf) * 999 - np.round(np.asarray(tf) * 999))
    assert (off > 1e-3).any()
