"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.adaln_fuse import adaln_fuse
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hetero_fuse import hetero_fuse
from repro.kernels.ssd_scan import ssd_scan


def _rand(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


# --- flash attention ---------------------------------------------------------

FLASH_CASES = [
    # (b, h, s, d, causal, window, dtype, bq, bk)
    (2, 3, 128, 32, True, 0, jnp.float32, 64, 64),
    (1, 2, 256, 64, True, 64, jnp.float32, 64, 128),
    (2, 2, 128, 16, False, 0, jnp.float32, 32, 64),
    (1, 4, 256, 32, True, 0, jnp.bfloat16, 128, 128),
    (1, 1, 64, 128, True, 16, jnp.bfloat16, 64, 32),
]


@pytest.mark.parametrize("b,h,s,d,causal,window,dtype,bq,bk", FLASH_CASES)
def test_flash_attention_sweep(b, h, s, d, causal, window, dtype, bq, bk):
    q = _rand((b, h, s, d), dtype, 0)
    k = _rand((b, h, s, d), dtype, 1)
    v = _rand((b, h, s, d), dtype, 2)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = R.ref_flash_attention(q, k, v, causal=causal, window=window)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


# --- SSD scan ----------------------------------------------------------------

SSD_CASES = [
    # (b, h, s, p, n, chunk, head_block)
    (2, 8, 64, 16, 16, 16, 4),
    (1, 4, 128, 32, 8, 32, 4),
    (2, 2, 32, 8, 32, 8, 2),
]


@pytest.mark.parametrize("b,h,s,p,n,chunk,hb", SSD_CASES)
def test_ssd_scan_sweep(b, h, s, p, n, chunk, hb):
    x = _rand((b, h, s, p), seed=0)
    dt = jax.nn.softplus(_rand((b, h, s), seed=1))
    A = -jnp.exp(_rand((h,), seed=2))
    B = _rand((b, s, n), seed=3)
    C = _rand((b, s, n), seed=4)
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk, head_block=hb,
                     interpret=True)
    yr, str_ = R.ref_ssd_scan(
        jnp.swapaxes(x, 1, 2), jnp.swapaxes(dt, 1, 2), A, B, C
    )
    np.testing.assert_allclose(y, jnp.swapaxes(yr, 1, 2), atol=5e-4)
    np.testing.assert_allclose(st, str_, atol=5e-4)


# --- AdaLN fuse --------------------------------------------------------------


@pytest.mark.parametrize("b,s,d,bs,dtype", [
    (3, 64, 48, 16, jnp.float32),
    (1, 256, 128, 64, jnp.float32),
    (2, 64, 64, 64, jnp.bfloat16),
])
def test_adaln_fuse_sweep(b, s, d, bs, dtype):
    x = _rand((b, s, d), dtype, 0)
    g = _rand((b, d), dtype, 1)
    be = _rand((b, d), dtype, 2)
    out = adaln_fuse(x, g, be, block_s=bs, interpret=True)
    ref = R.ref_adaln_fuse(x, g, be)
    atol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


# --- hetero fuse -------------------------------------------------------------


@pytest.mark.parametrize("k,b,t,bt", [(2, 3, 128, 32), (8, 2, 256, 128),
                                      (4, 1, 64, 64)])
def test_hetero_fuse_sweep(k, b, t, bt):
    preds = _rand((k, b, t), seed=0)
    xt = _rand((b, t), seed=1)
    w = jax.nn.softmax(_rand((b, k), seed=2), -1)
    isd = jnp.arange(k) % 2 == 0
    al = jax.random.uniform(jax.random.PRNGKey(3), (k, b),
                            minval=0.05, maxval=1.0)
    si = jnp.sqrt(1 - al ** 2)
    da = -jnp.ones((k, b))
    ds = jnp.ones((k, b))
    vs = jnp.full((k, b), 0.93)
    out = hetero_fuse(preds, xt, w, isd, al, si, da, ds, vs,
                      block_t=bt, interpret=True)
    ref = R.ref_hetero_fuse(preds, xt, w, isd, al, si, da, ds, vs)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_hetero_fuse_wrapper_matches_core():
    """ops.fused_convert_and_fuse == unify_prediction + fuse_predictions."""
    import os

    from repro.core import (
        ConversionConfig,
        fuse_predictions,
        get_schedule,
        unify_prediction,
    )
    from repro.kernels import ops

    os.environ["REPRO_FORCE_PALLAS"] = "1"
    try:
        lin, cos = get_schedule("linear"), get_schedule("cosine")
        t = jnp.array([0.3, 0.7, 0.5])
        preds = _rand((2, 3, 8, 8, 4), seed=0)
        xt = _rand((3, 8, 8, 4), seed=1)
        w = jax.nn.softmax(_rand((3, 2), seed=2), -1)
        cfg = ConversionConfig()
        fused = ops.fused_convert_and_fuse(
            preds, xt, w, ["ddpm", "fm"], [cos, lin], t, cfg
        )
        v0 = unify_prediction(preds[0], xt, t, objective="ddpm",
                              schedule=cos, cfg=cfg)
        v1 = unify_prediction(preds[1], xt, t, objective="fm",
                              schedule=lin, cfg=cfg)
        ref = fuse_predictions(jnp.stack([v0, v1]), w)
        np.testing.assert_allclose(fused, ref, atol=1e-4)
    finally:
        os.environ.pop("REPRO_FORCE_PALLAS", None)
