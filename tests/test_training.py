"""Training substrate: optimizer, EMA, trainers, checkpointing, pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convert_checkpoint
from repro.data import (
    ExpertDataStream,
    RouterDataStream,
    SyntheticSpec,
    extract_features,
    fit_clusters,
)
from repro.models import dit as D
from repro.models.config import dit_b2, router_b2
from repro.training import (
    AdamWConfig,
    ExpertTrainer,
    RouterTrainer,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    ema_init,
    ema_update,
    expert_metadata,
    load_checkpoint,
    lr_schedule,
    save_checkpoint,
)

KEY = jax.random.PRNGKey(0)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(learning_rate=0.1, warmup_steps=0, clip_norm=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"x": 2.0 * params["x"]}
        params, state, _ = adamw_update(cfg, grads, state, params)
    np.testing.assert_allclose(params["x"], 0.0, atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}        # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5
    )


def test_lr_schedule_warmup_and_cosine():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=100,
                      total_steps=1000, cosine_decay=True,
                      min_lr_ratio=0.1)
    lr0 = float(lr_schedule(cfg, jnp.array(0)))
    lr_mid = float(lr_schedule(cfg, jnp.array(100)))
    lr_end = float(lr_schedule(cfg, jnp.array(1000)))
    assert lr0 < 0.05 and lr_mid == pytest.approx(1.0, rel=0.05)
    assert lr_end == pytest.approx(0.1, rel=0.05)


def test_ema_converges_to_params():
    p = {"w": jnp.ones((3,))}
    ema = ema_init({"w": jnp.zeros((3,))})
    for _ in range(100):
        ema = ema_update(ema, p, decay=0.9)
    np.testing.assert_allclose(ema["w"], 1.0, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("objective,schedule",
                         [("ddpm", "cosine"), ("fm", "linear")])
def test_expert_loss_decreases(objective, schedule):
    spec = SyntheticSpec(num_categories=2, latent_size=8)
    cm, _ = fit_clusters(spec, corpus_size=256, num_clusters=2, num_fine=32)
    cfg = dit_b2().reduced(latent_size=8)
    trainer = ExpertTrainer(
        apply_fn=D.make_expert_apply(cfg), objective=objective,
        schedule_name=schedule,
        opt=AdamWConfig(learning_rate=3e-4, warmup_steps=5),
    )
    state = trainer.init_state(D.init(cfg, KEY))
    stream = ExpertDataStream(spec, cm, cluster_id=0, batch_size=16)
    losses = []
    for i in range(25):
        state, m = trainer.train_step(
            state, jax.random.fold_in(KEY, i), stream.next_batch(i)
        )
        losses.append(m["loss"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


@pytest.mark.slow
def test_router_trains_above_chance():
    spec = SyntheticSpec(num_categories=4, latent_size=8, separation=3.5)
    cm, _ = fit_clusters(spec, corpus_size=512, num_clusters=4, num_fine=64)
    rcfg = router_b2(num_clusters=4).reduced(latent_size=8)
    trainer = RouterTrainer(
        apply_fn=lambda p, x, t: D.apply(rcfg, p, x, t), num_clusters=4,
    )
    state = trainer.init_state(D.init(rcfg, KEY))
    stream = RouterDataStream(spec, cm, batch_size=32)
    accs = []
    for i in range(40):
        state, m = trainer.train_step(
            state, jax.random.fold_in(KEY, i), stream.next_batch(i)
        )
        accs.append(m["acc"])
    assert np.mean(accs[-5:]) > 0.3, accs  # chance = 0.25


def test_checkpoint_roundtrip(tmp_path):
    cfg = dit_b2().reduced(latent_size=8)
    params = D.init(cfg, KEY)
    meta = expert_metadata(name="e0", objective="ddpm", schedule="cosine",
                           cluster_id=0, arch=cfg.name, step=123)
    path = os.path.join(tmp_path, "expert0.npz")
    save_checkpoint(path, params, metadata=meta)
    loaded, meta2 = load_checkpoint(path)
    assert meta2["objective"] == "ddpm" and meta2["step"] == 123
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(loaded)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_checkpoint_truncated_archive_named_error(tmp_path):
    """Decentralized transports hand us partial bytes: a checkpoint cut
    off mid-archive must raise a ValueError naming the file and reason,
    never an opaque zipfile/EOF error from inside np.load."""
    import pytest

    path = os.path.join(tmp_path, "expert0.npz")
    save_checkpoint(path, {"a": jnp.ones((64, 64)), "b": jnp.zeros((7,))},
                    metadata=expert_metadata(
                        name="e0", objective="fm", schedule="linear",
                        cluster_id=0, arch="toy"))
    blob = open(path, "rb").read()
    for frac in (0.25, 0.6, 0.95):       # cut in the header, middle, tail
        cut = os.path.join(tmp_path, f"cut{frac}.npz")
        with open(cut, "wb") as f:
            f.write(blob[: int(len(blob) * frac)])
        with pytest.raises(ValueError, match=rf"cut{frac}\.npz.*(corrupt|truncated|metadata)"):
            load_checkpoint(cut)


def test_load_checkpoint_non_zip_bytes_named_error(tmp_path):
    import pytest

    path = os.path.join(tmp_path, "garbage.npz")
    with open(path, "wb") as f:
        f.write(b"these are not the archive bytes you are looking for")
    with pytest.raises(ValueError, match=r"garbage\.npz.*corrupt or truncated"):
        load_checkpoint(path)


def test_load_checkpoint_missing_file_and_metadata(tmp_path):
    import pytest

    with pytest.raises(FileNotFoundError, match="nope"):
        load_checkpoint(os.path.join(tmp_path, "nope.npz"))
    # a real npz that was not written by save_checkpoint
    path = os.path.join(tmp_path, "foreign.npz")
    np.savez(path, w=np.ones((2, 2)))
    with pytest.raises(ValueError, match=r"foreign\.npz.*__metadata__"):
        load_checkpoint(path)


def test_pretrained_init_transfers_into_model():
    """Eq. 20 end-to-end: an 'ImageNet DiT' checkpoint (no text stack)
    initializes a text-conditioned expert; transferred groups match, the
    final layer is re-initialized, and the model still runs."""
    cfg_src = dit_b2(use_text=False).reduced(latent_size=8)
    cfg_dst = dit_b2().reduced(latent_size=8)
    src = D.init(cfg_src, KEY)
    dst_template = D.init(cfg_dst, jax.random.PRNGKey(1))
    out, report = convert_checkpoint(src, dst_template,
                                     rng=jax.random.PRNGKey(2))
    assert report["blocks"] == "transfer"
    assert report["final_layer"] == "reinit"
    assert report["text_proj"] == "new"
    np.testing.assert_array_equal(
        np.asarray(out["patch_embed"]["w"]),
        np.asarray(src["patch_embed"]["w"]),
    )
    x = jax.random.normal(KEY, (2, 8, 8, 4))
    pred = D.apply(cfg_dst, out, x, jnp.array([0.5, 0.5]))
    assert pred.shape == x.shape
    assert bool(jnp.isfinite(pred).all())


def test_expert_streams_are_disjoint():
    """Decentralization invariant: expert streams only emit samples whose
    cluster assignment matches their own cluster."""
    spec = SyntheticSpec(num_categories=4, latent_size=8)
    cm, _ = fit_clusters(spec, corpus_size=256, num_clusters=4, num_fine=32)
    s0 = ExpertDataStream(spec, cm, cluster_id=0, batch_size=16)
    b = s0.next_batch(0)
    assign = np.asarray(cm.assign(extract_features(b["latents"])))
    assert (assign == 0).mean() >= 0.9
