"""Router fusion (Eq. 1), selection strategies (§3.1), samplers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ConversionConfig,
    ExpertSpec,
    SamplerConfig,
    cfg_combine,
    fuse_predictions,
    prediction_conflict,
    routing_weights,
    sample_ddpm_ancestral,
    sample_ensemble,
    sample_single_expert,
    select_topk,
    threshold_router_weights,
)

KEY = jax.random.PRNGKey(0)


def _probs(b=5, k=8, seed=0):
    return jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed), (b, k)), -1
    )


@settings(max_examples=25, deadline=None)
@given(k=st.integers(min_value=1, max_value=8), seed=st.integers(0, 100))
def test_topk_weights_property(k, seed):
    probs = _probs(seed=seed)
    w, mask = select_topk(probs, k)
    assert int((w > 0).sum(-1).max()) <= k
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    # selected experts are the k most probable ones
    top = np.asarray(jax.lax.top_k(probs, k)[1])
    sel = np.asarray(w > 0)
    for b in range(probs.shape[0]):
        assert set(np.nonzero(sel[b])[0]).issubset(set(top[b]) | set(
            np.nonzero(np.asarray(probs[b]) >= np.asarray(probs[b])[top[b]].min())[0]
        ))


def test_strategies():
    probs = _probs()
    w1 = routing_weights(probs, "top1")
    assert ((w1 > 0).sum(-1) == 1).all()
    wf = routing_weights(probs, "full")
    np.testing.assert_allclose(wf, probs, atol=1e-6)
    with pytest.raises(ValueError):
        routing_weights(probs, "bogus")


def test_fuse_predictions_eq1():
    preds = jnp.stack([jnp.full((2, 3), 1.0), jnp.full((2, 3), 3.0)])
    w = jnp.array([[0.5, 0.5], [1.0, 0.0]])
    out = fuse_predictions(preds, w)
    np.testing.assert_allclose(out[0], 2.0)
    np.testing.assert_allclose(out[1], 1.0)


def test_threshold_router():
    t = jnp.array([0.2, 0.5, 0.8])
    w = threshold_router_weights(t, 2, threshold=0.5)
    # t<=0.5 -> expert 0 (low-noise / converted DDPM), else expert 1 (FM)
    np.testing.assert_array_equal(np.asarray(w),
                                  [[1, 0], [1, 0], [0, 1]])


def test_prediction_conflict_zero_when_identical():
    preds = jnp.stack([jnp.ones((2, 4)), jnp.ones((2, 4))])
    w = jnp.full((2, 2), 0.5)
    np.testing.assert_allclose(prediction_conflict(preds, w), 0.0, atol=1e-7)
    preds2 = jnp.stack([jnp.zeros((2, 4)), jnp.ones((2, 4))])
    assert (np.asarray(prediction_conflict(preds2, w)) > 0).all()


def test_cfg_combine():
    c, u = jnp.array(2.0), jnp.array(1.0)
    assert float(cfg_combine(c, u, 1.0)) == 2.0
    assert float(cfg_combine(c, u, 7.5)) == 1.0 + 7.5


def _toy_expert(objective: str):
    """Analytic expert: predicts its target exactly for x0 = 0."""
    if objective == "fm":
        # v = eps - x0 with x0=0 -> v = eps = x_t / t on linear path...
        # use a contractive prediction: v = x (drives x -> 0 as t decreases)
        return lambda params, x, t, **c: x
    return lambda params, x, t, **c: x  # eps-style: also proportional to x


def test_sample_ensemble_strategies_finite():
    experts = [
        ExpertSpec("e0", "ddpm", "cosine", _toy_expert("ddpm"), 0),
        ExpertSpec("e1", "fm", "linear", _toy_expert("fm"), 1),
    ]
    router_fn = lambda x, t: jnp.full((x.shape[0], 2), 0.5)
    for strat in ("top1", "topk", "full", "threshold"):
        out = sample_ensemble(
            KEY, experts, [None, None], router_fn, (2, 4, 4, 1),
            config=SamplerConfig(num_steps=6, cfg_scale=1.0, strategy=strat),
        )
        assert out.shape == (2, 4, 4, 1)
        assert bool(jnp.isfinite(out).all()), strat


def test_single_expert_exact_ode():
    """With v(x,t) = x the ODE dx/dt = v gives x(0) = x(1)·exp(-1); Euler
    with N steps converges to it."""
    e = ExpertSpec("e", "fm", "linear", lambda p, x, t, **c: x)
    out = sample_single_expert(
        KEY, e, None, (1, 2, 2, 1),
        config=SamplerConfig(num_steps=400, cfg_scale=1.0),
    )
    x1 = jax.random.normal(KEY, (1, 2, 2, 1))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x1) * np.exp(-1.0), rtol=5e-3
    )


def test_ddpm_ancestral_finite():
    out = sample_ddpm_ancestral(
        KEY, lambda p, x, t, **c: 0.1 * x, None, (2, 4, 4, 1),
        num_steps=10, cfg_scale=1.0,
    )
    assert bool(jnp.isfinite(out).all())


def _euler_reference(apply_fn, shape, *, num_steps, cfg_scale=1.0,
                     cond=None, null_cond=None):
    """Unified-sampler reference path for a single cosine-DDPM expert,
    started from the ancestral sampler's own noise draw."""
    e = ExpertSpec("d", "ddpm", "cosine", apply_fn, 0)
    noise = jax.random.normal(KEY, shape, dtype=jnp.float32)
    return sample_ensemble(
        KEY, [e], [None], None, shape,
        cond=cond, null_cond=null_cond,
        config=SamplerConfig(
            num_steps=num_steps, cfg_scale=cfg_scale, strategy="full",
            # Eq. 31 dampening is an Euler-path-only stabilizer; the
            # native DDIM update has no analogue, so parity needs it off.
            conversion=ConversionConfig(velocity_scaling="none"),
        ),
        engine="reference", init_noise=noise,
    )


def test_ddpm_ancestral_converges_to_reference_euler_path():
    """Table 3 'Native DDPM' baseline vs the unified sampler: the DDIM
    (eta=0) ancestral update and the velocity-Euler step discretize the
    SAME cosine-path probability-flow ODE, so with the Eq. 31 dampening
    disabled and an in-clamp-range x0-hat (eps-hat = x keeps x0-hat
    bounded through the alpha->0 endpoint) the two samplers must agree
    to first order: max |diff| halves when the step count doubles."""
    shape = (2, 4, 4, 1)
    apply_fn = lambda p, x, t, **c: x  # noqa: E731
    errs = []
    for n in (12, 48, 192):
        anc = sample_ddpm_ancestral(KEY, apply_fn, None, shape,
                                    num_steps=n, cfg_scale=1.0)
        eul = _euler_reference(apply_fn, shape, num_steps=n)
        errs.append(float(jnp.abs(anc - eul).max()))
    # 4x the steps must cut the discretization gap at least in half
    # (measured slope is ~4x per 4x, i.e. clean first order)
    assert errs[1] < errs[0] / 2.0, errs
    assert errs[2] < errs[1] / 2.0, errs
    assert errs[-1] < 0.02, errs


def test_ddpm_ancestral_cfg_matches_reference_euler_path():
    """CFG parity: eps-space guidance (native ancestral) == velocity-space
    guidance (unified path) while the conversion stays affine in eps."""
    shape = (2, 4, 4, 1)

    def apply_fn(p, x, t, *, text_emb=None, **_):
        shift = 0.0 if text_emb is None else text_emb.mean() * 0.1
        return x + shift

    text = jax.random.normal(jax.random.PRNGKey(7), (2, 3, 4))
    cond = {"text_emb": text}
    null = {"text_emb": None}
    anc = sample_ddpm_ancestral(
        KEY, apply_fn, None, shape, cond=cond, null_cond=null,
        num_steps=96, cfg_scale=3.0,
    )
    eul = _euler_reference(apply_fn, shape, num_steps=96, cfg_scale=3.0,
                           cond=cond, null_cond=null)
    np.testing.assert_allclose(np.asarray(anc), np.asarray(eul), atol=0.05)
