"""Router fusion (Eq. 1), selection strategies (§3.1), samplers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ExpertSpec,
    SamplerConfig,
    cfg_combine,
    fuse_predictions,
    prediction_conflict,
    routing_weights,
    sample_ddpm_ancestral,
    sample_ensemble,
    sample_single_expert,
    select_topk,
    threshold_router_weights,
)

KEY = jax.random.PRNGKey(0)


def _probs(b=5, k=8, seed=0):
    return jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed), (b, k)), -1
    )


@settings(max_examples=25, deadline=None)
@given(k=st.integers(min_value=1, max_value=8), seed=st.integers(0, 100))
def test_topk_weights_property(k, seed):
    probs = _probs(seed=seed)
    w, mask = select_topk(probs, k)
    assert int((w > 0).sum(-1).max()) <= k
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    # selected experts are the k most probable ones
    top = np.asarray(jax.lax.top_k(probs, k)[1])
    sel = np.asarray(w > 0)
    for b in range(probs.shape[0]):
        assert set(np.nonzero(sel[b])[0]).issubset(set(top[b]) | set(
            np.nonzero(np.asarray(probs[b]) >= np.asarray(probs[b])[top[b]].min())[0]
        ))


def test_strategies():
    probs = _probs()
    w1 = routing_weights(probs, "top1")
    assert ((w1 > 0).sum(-1) == 1).all()
    wf = routing_weights(probs, "full")
    np.testing.assert_allclose(wf, probs, atol=1e-6)
    with pytest.raises(ValueError):
        routing_weights(probs, "bogus")


def test_fuse_predictions_eq1():
    preds = jnp.stack([jnp.full((2, 3), 1.0), jnp.full((2, 3), 3.0)])
    w = jnp.array([[0.5, 0.5], [1.0, 0.0]])
    out = fuse_predictions(preds, w)
    np.testing.assert_allclose(out[0], 2.0)
    np.testing.assert_allclose(out[1], 1.0)


def test_threshold_router():
    t = jnp.array([0.2, 0.5, 0.8])
    w = threshold_router_weights(t, 2, threshold=0.5)
    # t<=0.5 -> expert 0 (low-noise / converted DDPM), else expert 1 (FM)
    np.testing.assert_array_equal(np.asarray(w),
                                  [[1, 0], [1, 0], [0, 1]])


def test_prediction_conflict_zero_when_identical():
    preds = jnp.stack([jnp.ones((2, 4)), jnp.ones((2, 4))])
    w = jnp.full((2, 2), 0.5)
    np.testing.assert_allclose(prediction_conflict(preds, w), 0.0, atol=1e-7)
    preds2 = jnp.stack([jnp.zeros((2, 4)), jnp.ones((2, 4))])
    assert (np.asarray(prediction_conflict(preds2, w)) > 0).all()


def test_cfg_combine():
    c, u = jnp.array(2.0), jnp.array(1.0)
    assert float(cfg_combine(c, u, 1.0)) == 2.0
    assert float(cfg_combine(c, u, 7.5)) == 1.0 + 7.5


def _toy_expert(objective: str):
    """Analytic expert: predicts its target exactly for x0 = 0."""
    if objective == "fm":
        # v = eps - x0 with x0=0 -> v = eps = x_t / t on linear path...
        # use a contractive prediction: v = x (drives x -> 0 as t decreases)
        return lambda params, x, t, **c: x
    return lambda params, x, t, **c: x  # eps-style: also proportional to x


def test_sample_ensemble_strategies_finite():
    experts = [
        ExpertSpec("e0", "ddpm", "cosine", _toy_expert("ddpm"), 0),
        ExpertSpec("e1", "fm", "linear", _toy_expert("fm"), 1),
    ]
    router_fn = lambda x, t: jnp.full((x.shape[0], 2), 0.5)
    for strat in ("top1", "topk", "full", "threshold"):
        out = sample_ensemble(
            KEY, experts, [None, None], router_fn, (2, 4, 4, 1),
            config=SamplerConfig(num_steps=6, cfg_scale=1.0, strategy=strat),
        )
        assert out.shape == (2, 4, 4, 1)
        assert bool(jnp.isfinite(out).all()), strat


def test_single_expert_exact_ode():
    """With v(x,t) = x the ODE dx/dt = v gives x(0) = x(1)·exp(-1); Euler
    with N steps converges to it."""
    e = ExpertSpec("e", "fm", "linear", lambda p, x, t, **c: x)
    out = sample_single_expert(
        KEY, e, None, (1, 2, 2, 1),
        config=SamplerConfig(num_steps=400, cfg_scale=1.0),
    )
    x1 = jax.random.normal(KEY, (1, 2, 2, 1))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x1) * np.exp(-1.0), rtol=5e-3
    )


def test_ddpm_ancestral_finite():
    out = sample_ddpm_ancestral(
        KEY, lambda p, x, t, **c: 0.1 * x, None, (2, 4, 4, 1),
        num_steps=10, cfg_scale=1.0,
    )
    assert bool(jnp.isfinite(out).all())
