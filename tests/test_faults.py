"""Fault tolerance: masked routing, elastic membership, quarantine.

Covers the robustness rung (ROADMAP item 4):
  (a) masked routing invariants — a dead expert is never selected, even
      when the routing width k exceeds the live count; masked serving is
      bit-identical to a dense rebuild over the live subset;
  (b) elastic membership ops — hot add_expert/evict_expert/retire_expert
      mutate membership without retracing, in-flight requests complete
      bit-identically against their admission-time snapshot, and the
      health state machine transitions as documented;
  (c) checkpoint quarantine — every corruption class manufactured by
      launch.faults (truncated, scrambled, non-finite, shape-mismatched)
      is rejected with a named ValueError, recorded, and counted, both
      at assembly (from_checkpoint_dir) and at hot-add time;
  (d) stats round-trip — the quarantine/membership counters surface in
      membership_line(), the line the serve CLI prints.

The multi-device variant of (a)+(b) lives in sharded_parity step 8 and
the launch.faults __main__ scenario (subprocess, forced 2-device host).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SamplerConfig,
    fusion_weights,
    make_dispatch_plan,
    select_topk,
)
from repro.launch.faults import (
    FlushFaultInjector,
    mismatch_checkpoint_shapes,
    poison_checkpoint_nonfinite,
    scramble_checkpoint,
    truncate_checkpoint,
)
from repro.launch.serve import ServingEngine
from repro.launch.sharded_parity import toy_ensemble
from repro.models.config import dit_b2, router_b2
from repro.training import expert_metadata, save_checkpoint

KEY = jax.random.PRNGKey(0)
LATENT = (4, 4, 2)
SAMPLER = SamplerConfig(num_steps=4, cfg_scale=3.0,
                        strategy="topk", top_k=2)

EXPERTS, PARAMS, ROUTER_FN, _ = toy_ensemble(8)


def _elastic(k=6, capacity=8, **kw):
    return ServingEngine(
        experts=EXPERTS[:k], expert_params=PARAMS[:k],
        router_fn=ROUTER_FN, latent_shape=LATENT, sampler=SAMPLER,
        capacity=capacity, **kw,
    )


def _dense(idx):
    return ServingEngine(
        experts=[EXPERTS[i] for i in idx],
        expert_params=[PARAMS[i] for i in idx],
        router_fn=ROUTER_FN, latent_shape=LATENT, sampler=SAMPLER,
    )


def _toy_ckpt(path, i, cid=None):
    save_checkpoint(path, PARAMS[i], metadata=expert_metadata(
        name=f"e{i}", objective=EXPERTS[i].objective,
        schedule=EXPERTS[i].schedule,
        cluster_id=i if cid is None else cid, arch="toy",
    ))
    return path if path.endswith(".npz") else path + ".npz"


TEXT = jax.random.normal(jax.random.PRNGKey(3), (4, 5, 6))


# --- (a) masked routing invariants ------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4, 6])
def test_masked_plan_never_selects_invalid(k):
    """Even with k > live count, no plan slot may reference a dead
    expert — extra slots remap to a live fallback with weight 0."""
    kcap = 8
    valid = jnp.array([False, True, False, True, False,
                       False, True, False])          # 3 live of 8
    probs = jax.nn.softmax(
        jax.random.normal(KEY, (5, kcap)), axis=-1)
    w, _ = select_topk(probs * valid[None, :], k)    # the pipeline's form
    plan = make_dispatch_plan(w, k, valid=valid)
    live = {1, 3, 6}
    assert set(np.asarray(plan.slot_idx).ravel()) <= live
    np.testing.assert_allclose(
        np.asarray(plan.slot_w).sum(axis=-1), 1.0, atol=1e-6)
    if k > 3:     # the remapped overflow slots carry exactly zero weight
        sw = np.asarray(plan.slot_w)
        assert (np.sort(sw, axis=-1)[:, : k - 3] == 0.0).all()


def test_masked_fusion_weights_zero_on_dead_experts():
    valid = jnp.array([True, False, True, True])
    x = jax.random.normal(KEY, (3, 4, 4, 2))
    t = jnp.full((3,), 0.5)

    def router(xx, tt):
        return jax.nn.softmax(
            jax.random.normal(jax.random.PRNGKey(1), (xx.shape[0], 4)),
            axis=-1)

    w = fusion_weights(EXPERTS[:4], router, x, t,
                       strategy="topk", top_k=3, valid=valid)
    assert np.asarray(w)[:, 1].max() == 0.0
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, atol=1e-6)


def test_masked_serving_matches_dense_rebuild_bitwise():
    """Acceptance: capacity-8 store with 6 live == dense 6-expert engine,
    and NaN bytes in a dead slot never reach the output."""
    el = _elastic(6, 8)
    # poison a dead capacity slot's params: must be unobservable
    store = el.param_store
    poisoned = store.set_expert(7, jax.tree.map(
        lambda x: jnp.full_like(x, jnp.nan), PARAMS[0]))
    el.param_store = poisoned.with_valid(store.valid_mask())
    out = np.asarray(el.generate(KEY, TEXT, 4))
    ref = np.asarray(_dense(range(6)).generate(KEY, TEXT, 4))
    np.testing.assert_array_equal(out, ref)


def test_degraded_mode_counts_and_serves():
    """Fewer live experts than the routing width: still serves (weights
    renormalize over survivors), degraded_steps accumulates."""
    el = _elastic(6, 8)
    for e in (0, 1, 2, 3, 4):
        el.evict_expert(e)
    assert el.num_live_experts == 1              # < top_k=2
    out = np.asarray(el.generate(KEY, TEXT, 4))
    assert np.isfinite(out).all()
    assert el.stats["degraded_steps"] == SAMPLER.num_steps
    # single survivor == the dense single-expert routed output
    ref = np.asarray(_dense([5]).generate(KEY, TEXT, 4))
    np.testing.assert_array_equal(out, ref)


# --- (b) elastic membership -------------------------------------------------


def test_hot_add_and_evict_without_retrace(tmp_path):
    el = _elastic(6, 8)
    base = np.asarray(el.generate(KEY, TEXT, 4))
    assert el.stats["traces"] == 1
    slot = el.add_expert(_toy_ckpt(os.path.join(tmp_path, "e6.npz"), 6))
    assert slot == 6 and el.expert_health[6] == "ACTIVE"
    out7 = np.asarray(el.generate(KEY, TEXT, 4))
    np.testing.assert_array_equal(
        out7, np.asarray(_dense(range(7)).generate(KEY, TEXT, 4)))
    el.evict_expert(2)
    assert el.expert_health[2] == "EVICTED"
    out = np.asarray(el.generate(KEY, TEXT, 4))
    assert np.isfinite(out).all() and not np.array_equal(out, base)
    # membership is traced data: add + evict never recompiled
    assert el.stats["traces"] == 1
    assert el.stats["experts_added"] == 1
    assert el.stats["experts_evicted"] == 1


def test_eviction_mid_submit_is_bit_identical(tmp_path):
    """Acceptance: in-flight requests complete against the admission-time
    plan, bit-identical, while hot-add + evict land for new traffic."""
    el = _elastic(6, 8)
    admitted = np.asarray(el.generate(KEY, TEXT, 4))
    h_old = el.submit(KEY, TEXT, 4)
    el.add_expert(_toy_ckpt(os.path.join(tmp_path, "e6.npz"), 6))
    el.evict_expert(2)
    h_new = el.submit(KEY, TEXT, 4)
    assert el.flush() == 2                       # one dispatch per epoch
    np.testing.assert_array_equal(np.asarray(h_old.result()), admitted)
    assert not np.array_equal(np.asarray(h_new.result()), admitted)
    assert h_old.state == "DONE" and h_new.state == "DONE"


def test_retire_drains_then_frees_slot(tmp_path):
    el = _elastic(6, 8)
    h = el.submit(KEY, TEXT, 4)
    el.retire_expert(5)
    assert el.expert_health[5] == "DRAINING"
    with pytest.raises(ValueError, match="DRAINING"):
        el.add_expert(_toy_ckpt(os.path.join(tmp_path, "e7.npz"), 7),
                      slot=5)
    el.flush()
    assert np.isfinite(np.asarray(h.result())).all()
    assert el.expert_health[5] == "EVICTED"
    assert el.add_expert(
        _toy_ckpt(os.path.join(tmp_path, "e7b.npz"), 7)) == 5


def test_membership_ops_require_elastic_engine():
    dense = _dense(range(4))
    assert not dense.elastic
    with pytest.raises(ValueError, match="capacity"):
        dense.evict_expert(0)
    with pytest.raises(ValueError, match="capacity"):
        dense.add_expert("whatever.npz")


def test_elastic_guards_reject_unroutable_configs():
    with pytest.raises(ValueError, match="capacity=4 < 6"):
        _elastic(6, capacity=4)
    with pytest.raises(ValueError, match="router_fn"):
        ServingEngine(experts=EXPERTS[:2], expert_params=PARAMS[:2],
                      router_fn=None, latent_shape=LATENT,
                      sampler=SAMPLER, capacity=4)


# --- (c) checkpoint quarantine ----------------------------------------------


@pytest.mark.parametrize("corrupt,reason", [
    (truncate_checkpoint, "corrupt or truncated"),
    (scramble_checkpoint, "corrupt or truncated"),
    (poison_checkpoint_nonfinite, "non-finite"),
    (mismatch_checkpoint_shapes, "shape"),
])
def test_add_expert_quarantines_every_corruption_class(
        tmp_path, corrupt, reason):
    el = _elastic(6, 8)
    p = corrupt(_toy_ckpt(os.path.join(tmp_path, "bad.npz"), 7))
    with pytest.raises(ValueError, match=reason):
        el.add_expert(p)
    assert el.stats["quarantined_checkpoints"] == 1
    assert el.quarantine[0]["path"] == p
    assert el.expert_health[6] == "EMPTY"        # slot still free
    assert el.num_live_experts == 6
    # engine still serves after the rejected add
    assert np.isfinite(np.asarray(el.generate(KEY, TEXT, 4))).all()


def test_from_checkpoint_dir_skip_quarantines_and_masks_holes(tmp_path):
    cfg = dit_b2().reduced(latent_size=8)
    rcfg = router_b2(num_clusters=4).reduced(latent_size=8)
    from repro.models import dit as D
    for cid in (0, 1, 3):
        save_checkpoint(
            os.path.join(tmp_path, f"expert{cid}.npz"),
            D.init(cfg, jax.random.PRNGKey(10 + cid)),
            metadata=expert_metadata(
                name=f"e{cid}", objective="fm", schedule="linear",
                cluster_id=cid, arch=cfg.name))
    save_checkpoint(
        os.path.join(tmp_path, "expert2.npz"),
        D.init(cfg, jax.random.PRNGKey(12)),
        metadata=expert_metadata(name="e2", objective="fm",
                                 schedule="linear", cluster_id=2,
                                 arch=cfg.name))
    truncate_checkpoint(os.path.join(tmp_path, "expert2.npz"), 0.4)
    save_checkpoint(os.path.join(tmp_path, "router.npz"),
                    D.init(rcfg, jax.random.PRNGKey(99)))
    # default: refuse to start on the bad artifact
    with pytest.raises(ValueError, match="expert2"):
        ServingEngine.from_checkpoint_dir(
            str(tmp_path), dit_cfg=cfg, router_cfg=rcfg)
    # skip mode: quarantine it, mask the hole, serve degraded
    eng = ServingEngine.from_checkpoint_dir(
        str(tmp_path), dit_cfg=cfg, router_cfg=rcfg,
        sampler=SamplerConfig(num_steps=2, cfg_scale=3.0,
                              strategy="topk", top_k=2),
        on_bad_checkpoint="skip")
    assert eng.elastic and eng.capacity == 4
    assert eng.num_live_experts == 3
    assert eng.expert_health[2] == "EMPTY"
    assert len(eng.quarantine) == 1
    assert "expert2" in eng.quarantine[0]["path"]
    assert eng.stats["quarantined_checkpoints"] == 1


# --- (d) stats round-trip ---------------------------------------------------


def test_quarantine_counters_roundtrip_membership_line(tmp_path):
    el = _elastic(6, 8)
    el.add_expert(_toy_ckpt(os.path.join(tmp_path, "e6.npz"), 6))
    el.evict_expert(2)
    with pytest.raises(ValueError):
        el.add_expert(truncate_checkpoint(
            _toy_ckpt(os.path.join(tmp_path, "bad.npz"), 7)))
    el.quarantine_expert(4, reason="health check caught NaNs")
    line = el.membership_line()
    assert "live=5/8" in line
    assert "added=1" in line
    assert "evicted=1" in line
    assert "quarantined=2" in line               # bad ckpt + runtime slot
    assert el.expert_health[4] == "QUARANTINED"


def test_flush_fault_injector_isolates_groups():
    el = _elastic(6, 8)
    h_text = el.submit(KEY, TEXT, 4)
    h_uncond = el.submit(jax.random.PRNGKey(1), None, 4)
    with FlushFaultInjector(el, fail_on={1}) as inj:
        assert el.flush() == 1
    assert inj.calls == 2
    states = sorted((h_text.state, h_uncond.state))
    assert states == ["DONE", "QUEUED"]
    assert el.flush() == 1                       # re-queued group recovers
    assert {h_text.state, h_uncond.state} == {"DONE"}
