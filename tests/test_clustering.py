"""Hierarchical k-means clustering (paper §6.1)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    cluster_balance,
    cosine_assign,
    hierarchical_kmeans,
    kmeans,
    partition_indices,
)

KEY = jax.random.PRNGKey(0)


def _blob_data(k=4, per=64, d=16, sep=4.0, seed=0):
    rng = np.random.RandomState(seed)
    centers = rng.randn(k, d)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    feats = np.concatenate(
        [sep * centers[i] + 0.3 * rng.randn(per, d) for i in range(k)]
    )
    labels = np.repeat(np.arange(k), per)
    return jnp.asarray(feats), labels


def _purity(pred, true, k):
    total = 0
    for c in range(k):
        members = true[np.asarray(pred) == c]
        if len(members):
            total += np.bincount(members).max()
    return total / len(true)


def test_kmeans_recovers_blobs():
    feats, labels = _blob_data()
    _, assign = kmeans(KEY, feats, num_clusters=4)
    assert _purity(assign, labels, 4) > 0.95


def test_hierarchical_two_stage():
    feats, labels = _blob_data(k=4, per=64, sep=8.0)
    cm = hierarchical_kmeans(KEY, feats, num_coarse=4, num_fine=32)
    assert cm.fine_centroids.shape[0] == 32
    assert cm.num_clusters == 4
    assign = cm.assign(feats)
    # two-stage k-means can merge blobs at small scale; require clearly
    # better-than-chance purity (chance = 0.25)
    assert _purity(assign, labels, 4) > 0.7
    # fine->coarse map consistent with direct assignment most of the time
    direct = cm.assign_direct(feats)
    agree = (np.asarray(assign) == np.asarray(direct)).mean()
    assert agree > 0.5


def test_partitions_are_disjoint_and_complete():
    feats, _ = _blob_data()
    cm = hierarchical_kmeans(KEY, feats, num_coarse=4, num_fine=16)
    assign = np.asarray(cm.assign(feats))
    parts = partition_indices(assign, 4)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(feats)
    assert len(np.unique(all_idx)) == len(feats)  # disjoint
    bal = cluster_balance(assign, 4)
    np.testing.assert_allclose(bal.sum(), 1.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_assignment_invariant_to_feature_scale(seed):
    """Cosine metric: scaling features must not change assignments."""
    feats, _ = _blob_data(seed=seed)
    cm = hierarchical_kmeans(KEY, feats, num_coarse=4, num_fine=16)
    a1 = np.asarray(cm.assign(feats))
    a2 = np.asarray(cm.assign(feats * 7.3))
    np.testing.assert_array_equal(a1, a2)


def test_cosine_assign_basic():
    cents = jnp.eye(3)
    feats = jnp.array([[0.9, 0.1, 0.0], [0.0, 0.0, 2.0]])
    np.testing.assert_array_equal(cosine_assign(feats, cents), [0, 2])
