"""Continuous batching: rolling scheduler parity, admission, liveness.

Covers the `repro.serving` subsystem end to end:
  (a) bitwise parity — N staggered requests through the rolling
      mixed-timestep scheduler resolve bit-identically to sequential
      per-request ``generate()`` on a twin engine (step-fused, 8-expert
      top-2 CFG), with genuinely mixed timesteps observed mid-flight;
  (b) admission control — bounded residency, FIFO queueing with
      head-of-line blocking, QueueBackpressure at queue-depth, outright
      rejection of unschedulable requests, the QUEUED → RESIDENT → DONE
      state machine;
  (c) retrace budget — one trace per bucket shape across request churn
      AND elastic membership changes (epoch-keyed buckets share the
      compiled step), with in-flight requests pinned to their
      admission-time snapshot bit-exactly;
  (d) flush re-queue order regression — a partially-failed ``flush()``
      re-queues in global submission order, not group order;
  (e) RT304 scheduler liveness — ``check_scheduler_liveness`` /
      ``EngineSanitizer.check_scheduler`` raise ``StarvationHazard`` on
      a starved queue head and stay quiet on a healthy one;
  (f) latency observability — percentile math, ``stats`` publication,
      the scheduler summary line;
  (g) kernel layer — per-row ``(B,)`` dt is bitwise identical to the
      scalar dt on both the reference and Pallas-interpret paths;
  (h) dispatch helpers — ``routed_slots`` and ``slot_coef_rows`` match
      their lockstep counterparts bitwise.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import (
    EngineSanitizer,
    StarvationHazard,
    assert_no_retrace,
    check_scheduler_liveness,
)
from repro.core import (
    SamplerConfig,
    make_dispatch_plan,
    routed_slots,
    slot_coef,
    slot_coef_rows,
)
from repro.kernels import ops
from repro.launch.serve import ServingEngine
from repro.launch.sharded_parity import toy_ensemble
from repro.serving import (
    AdmissionError,
    ContinuousScheduler,
    QueueBackpressure,
    percentile,
)

KEY = jax.random.PRNGKey(0)
LATENT = (4, 4, 2)
TEXT_TAIL = (5, 6)
SAMPLER = SamplerConfig(num_steps=6, cfg_scale=3.0,
                        strategy="topk", top_k=2)

EXPERTS, PARAMS, ROUTER_FN, _ = toy_ensemble(8)


def _engine(k=8, **kw):
    return ServingEngine(
        experts=EXPERTS[:k], expert_params=PARAMS[:k],
        router_fn=ROUTER_FN, latent_shape=LATENT, sampler=SAMPLER, **kw,
    )


def _req_inputs(i, bs):
    key = jax.random.PRNGKey(100 + i)
    text = jax.random.normal(
        jax.random.fold_in(key, 1), (bs,) + TEXT_TAIL, jnp.float32
    )
    return key, text


def _fake_clock():
    c = itertools.count()
    return lambda: float(next(c))


# --- (a) bitwise parity ------------------------------------------------------


def test_rolling_staggered_bitwise_equals_generate():
    """Staggered arrivals through the rolling batch == sequential
    generate(), bitwise, with mixed timesteps genuinely observed."""
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=4)
    specs = [(0, 1), (1, 2), (2, 1), (4, 1), (5, 2), (8, 1)]
    handles, inputs = [], []
    mixed_seen = False
    tick = 0
    for arrive, bs in specs:
        while tick < arrive:
            sched.step()
            tick += 1
        key, text = _req_inputs(len(handles), bs)
        handles.append(sched.submit(key, text))
        inputs.append((key, text, bs))
    while sched.queue_depth or sched.num_resident:
        sched.step()
        for bucket in sched._buckets.values():
            t_host = bucket.t_idx_host()
            live = {
                int(t_host[i]) for i, r in enumerate(bucket.rows)
                if r is not None and t_host[i] < bucket.num_steps
            }
            if len(live) >= 2:
                mixed_seen = True
    assert mixed_seen, "rolling batch never actually mixed timesteps"

    twin = _engine()
    for h, (key, text, bs) in zip(handles, inputs):
        assert h.state == "DONE" and h.done
        want = np.asarray(twin.generate(key, text, bs))
        got = np.asarray(h.result())
        assert got.shape == (bs,) + LATENT
        assert np.array_equal(got, want), \
            f"max |diff| = {np.abs(got - want).max():.3e}"


@pytest.mark.parametrize("spt", [2, 4])
def test_rolling_multi_step_ticks_bitwise(spt):
    """steps_per_tick > 1 (one launch scans several fused steps) stays
    bitwise equal to sequential generate() under staggered arrivals —
    including spt=4 with num_steps=6, where requests finish mid-tick
    and must freeze at the sentinel inside the launch."""
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=4, steps_per_tick=spt)
    handles, inputs = [], []
    for i, bs in enumerate([1, 2, 1, 1]):
        key, text = _req_inputs(i, bs)
        handles.append(sched.submit(key, text))
        inputs.append((key, text, bs))
        sched.step()
    sched.run_until_idle()
    assert eng.stats["traces"] == 1

    twin = _engine()
    for h, (key, text, bs) in zip(handles, inputs):
        assert h.state == "DONE"
        want = np.asarray(twin.generate(key, text, bs))
        got = np.asarray(h.result())
        assert np.array_equal(got, want), \
            f"spt={spt}: max |diff| = {np.abs(got - want).max():.3e}"


def test_rolling_no_text_and_plan_refresh_parity():
    """Unconditioned requests + R>1 plan reuse: each row carries its own
    refresh phase and still matches generate() bitwise."""
    cfg = SamplerConfig(num_steps=8, cfg_scale=3.0, strategy="topk",
                        top_k=2, plan_refresh_every=3)
    eng = ServingEngine(experts=EXPERTS, expert_params=PARAMS,
                        router_fn=ROUTER_FN, latent_shape=LATENT,
                        sampler=cfg)
    sched = ContinuousScheduler(eng, max_resident=3)
    handles = []
    for i in range(4):
        handles.append(
            sched.submit(jax.random.PRNGKey(40 + i), batch_size=1))
        sched.step()
    sched.run_until_idle()
    twin = ServingEngine(experts=EXPERTS, expert_params=PARAMS,
                         router_fn=ROUTER_FN, latent_shape=LATENT,
                         sampler=cfg)
    for i, h in enumerate(handles):
        want = np.asarray(twin.generate(jax.random.PRNGKey(40 + i),
                                        None, 1))
        assert np.array_equal(np.asarray(h.result()), want)


# --- (b) admission control ---------------------------------------------------


def test_admission_residency_and_backpressure():
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=2, max_queue_depth=3)

    # unschedulable: wider than any bucket — rejected at submit.
    with pytest.raises(AdmissionError, match="max_resident"):
        sched.submit(jax.random.PRNGKey(1), batch_size=3)

    handles = [sched.submit(jax.random.PRNGKey(10 + i), batch_size=1)
               for i in range(2)]
    assert all(h.state == "QUEUED" for h in handles)
    sched.step()
    assert all(h.state == "RESIDENT" for h in handles)
    assert sched.num_resident == 2

    # bucket full: further requests queue (depth-bounded)...
    queued = [sched.submit(jax.random.PRNGKey(20 + i), batch_size=1)
              for i in range(3)]
    sched.step()
    assert all(h.state == "QUEUED" for h in queued)
    assert sched.queue_depth == 3

    # ...and the queue itself backpressures past max_queue_depth.
    with pytest.raises(QueueBackpressure):
        sched.submit(jax.random.PRNGKey(30), batch_size=1)

    sched.run_until_idle()
    for h in handles + queued:
        assert h.state == "DONE"
        assert np.isfinite(np.asarray(h.result())).all()
    assert sched.queue_depth == 0 and sched.num_resident == 0


def test_admission_is_fifo_by_submission():
    """With a 1-row bucket every request runs alone; completion order
    must follow submission (seq) order."""
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=1)
    order = []
    handles = [sched.submit(jax.random.PRNGKey(50 + i), batch_size=1)
               for i in range(3)]
    seen = set()
    while sched.queue_depth or sched.num_resident:
        sched.step()
        for h in handles:
            if h.done and h.seq not in seen:
                seen.add(h.seq)
                order.append(h.seq)
    assert order == sorted(order)


# --- (c) retrace budget + elastic snapshots ----------------------------------


def test_rolling_one_trace_per_bucket_across_churn():
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=4)
    with assert_no_retrace(eng, budget=1):     # first contact compiles once
        handles = []
        for i in range(5):                     # churn: joins and leaves
            key, text = _req_inputs(60 + i, 1)
            handles.append(sched.submit(key, text))
            sched.step()
            sched.step()
        sched.run_until_idle()
    assert all(h.done for h in handles)
    # a second bucket shape (no text) compiles exactly once more.
    with assert_no_retrace(eng, budget=1):
        sched.submit(jax.random.PRNGKey(70), batch_size=1)
        sched.run_until_idle()


def test_rolling_elastic_epoch_snapshot_bitwise():
    """Mid-flight eviction: the resident request finishes against its
    admission-time membership; a post-eviction request routes over the
    survivors — both bitwise vs twin engines, with zero extra traces
    for the new epoch's bucket."""
    k1, t1 = _req_inputs(80, 1)
    k2, t2 = _req_inputs(81, 1)

    eng = ServingEngine(experts=EXPERTS[:6], expert_params=PARAMS[:6],
                        router_fn=ROUTER_FN, latent_shape=LATENT,
                        sampler=SAMPLER, capacity=8)
    sched = ContinuousScheduler(eng, max_resident=2)
    h1 = sched.submit(k1, t1)
    sched.step()
    sched.step()
    assert h1.state == "RESIDENT"
    eng.evict_expert(0)                         # epoch bump mid-flight
    h2 = sched.submit(k2, t2)
    with assert_no_retrace(eng, budget=0):      # new epoch, same trace
        sched.run_until_idle()
    assert h1.state == "DONE" and h2.state == "DONE"

    twin_old = ServingEngine(
        experts=EXPERTS[:6], expert_params=PARAMS[:6],
        router_fn=ROUTER_FN, latent_shape=LATENT, sampler=SAMPLER,
        capacity=8)
    assert np.array_equal(np.asarray(h1.result()),
                          np.asarray(twin_old.generate(k1, t1, 1)))
    twin_new = ServingEngine(
        experts=EXPERTS[:6], expert_params=PARAMS[:6],
        router_fn=ROUTER_FN, latent_shape=LATENT, sampler=SAMPLER,
        capacity=8)
    twin_new.evict_expert(0)
    assert np.array_equal(np.asarray(h2.result()),
                          np.asarray(twin_new.generate(k2, t2, 1)))


def test_scheduler_failed_bucket_requeues_in_seq_order(monkeypatch):
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=4)
    handles = [sched.submit(*_req_inputs(90 + i, 1)) for i in range(3)]
    boom = RuntimeError("poisoned step")
    monkeypatch.setattr(
        ContinuousScheduler, "_advance",
        lambda self, bucket: (_ for _ in ()).throw(boom))
    sched.step()                               # admit + fail the bucket
    assert [r.seq for r in sched._queue] == sorted(h.seq for h in handles)
    assert all(h.state == "QUEUED" and h.requeues == 1 for h in handles)
    monkeypatch.undo()
    sched.run_until_idle()
    assert all(h.state == "DONE" for h in handles)


def test_scheduler_requeue_budget_marks_failed(monkeypatch):
    eng = _engine(max_request_requeues=0)
    sched = ContinuousScheduler(eng, max_resident=2)
    h = sched.submit(*_req_inputs(95, 1))
    boom = RuntimeError("poisoned step")
    monkeypatch.setattr(
        ContinuousScheduler, "_advance",
        lambda self, bucket: (_ for _ in ()).throw(boom))
    sched.step()
    assert h.state == "FAILED"
    with pytest.raises(RuntimeError, match="poisoned step"):
        h.result()
    assert eng.stats["failed_requests"] == 1


# --- (d) flush re-queue order regression -------------------------------------


def test_flush_requeues_in_submission_order(monkeypatch):
    """A partially-failed flush() must re-queue by global submission
    order (seq), not by dispatch-group iteration order: A and C share a
    text group, B sits between them in a second group — the re-queued
    queue must read [A, B, C], not [A, C, B]."""
    eng = _engine()
    ka, ta = _req_inputs(0, 1)
    kc, tc = _req_inputs(2, 1)
    a = eng.submit(ka, ta)
    b = eng.submit(jax.random.PRNGKey(201), None, batch_size=1)
    c = eng.submit(kc, tc)
    assert [a.seq, b.seq, c.seq] == sorted([a.seq, b.seq, c.seq])
    monkeypatch.setattr(
        ServingEngine, "_dispatch_group",
        lambda self, *args: (_ for _ in ()).throw(RuntimeError("boom")))
    assert eng.flush() == 0
    assert [r.seq for r in eng._queue] == [a.seq, b.seq, c.seq]
    assert [r for r in eng._queue] == [a, b, c]
    monkeypatch.undo()
    assert eng.flush() == 2                    # both groups dispatch
    for h in (a, b, c):
        assert h.state == "DONE"
        assert np.isfinite(np.asarray(h.result())).all()


# --- (e) RT304 scheduler liveness --------------------------------------------


def test_rt304_starvation_detected_and_healthy_pass():
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=2)
    san = EngineSanitizer(eng, starvation_bound=3)

    # healthy: nothing queued — liveness is quiet at any bound.
    sched.submit(*_req_inputs(300, 1))
    sched.step()
    san.check_scheduler(sched)
    check_scheduler_liveness(sched, 0)

    # starve the head: fill the bucket, queue a third, tick past bound.
    sched.submit(*_req_inputs(301, 1))
    starved = sched.submit(*_req_inputs(302, 1))
    for _ in range(4):
        sched.step()
    assert starved.state == "QUEUED"
    assert sched.max_pending_wait_steps() >= 4
    with pytest.raises(StarvationHazard, match="RT304"):
        check_scheduler_liveness(sched, 3)
    with pytest.raises(StarvationHazard, match="RT304"):
        san.check_scheduler(sched)
    # a generous bound (the default 2*num_steps) still passes — the
    # queue drains normally.
    EngineSanitizer(eng).check_scheduler(sched)
    sched.run_until_idle()
    assert starved.state == "DONE"


def test_rt304_registered_for_explain():
    from repro.analysis.rules import find_rule, rule_classes

    assert any(r.id == "RT304" for r in rule_classes())
    assert find_rule("scheduler-starvation").id == "RT304"


# --- (f) latency observability -----------------------------------------------


def test_percentile_nearest_rank():
    vals = [float(v) for v in range(1, 101)]
    assert percentile(vals, 50) == 50.0
    assert percentile(vals, 95) == 95.0
    assert percentile(vals, 99) == 99.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 99) == 7.0
    # an empty window has no percentiles — None, never a fake 0.0
    assert percentile([], 50) is None
    assert percentile([], 99) is None
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0   # order-insensitive


def test_stats_publish_latency_percentiles():
    eng = _engine()
    sched = ContinuousScheduler(eng, max_resident=2, clock=_fake_clock())
    for i in range(4):
        sched.submit(*_req_inputs(400 + i, 1))
        sched.step()
    sched.run_until_idle()
    s = eng.stats
    for key in ("latency_p50_s", "latency_p95_s", "latency_p99_s",
                "queue_wait_p50_s", "queue_wait_p95_s",
                "latency_p50_steps", "queue_wait_p50_steps",
                "throughput_img_s", "completed_requests",
                "scheduler_steps"):
        assert key in s, key
    assert s["completed_requests"] == 4.0
    assert s["latency_p50_s"] <= s["latency_p95_s"] <= s["latency_p99_s"]
    assert s["queue_wait_p50_steps"] <= s["queue_wait_p95_steps"]
    # e2e includes queue wait, and every request ran num_steps ticks.
    assert s["latency_p50_steps"] >= SAMPLER.num_steps
    assert s["throughput_img_s"] > 0.0
    line = sched.line()
    assert "p50" in line and "p95" in line and "img/s" in line


# --- (g) kernel layer: per-row dt --------------------------------------------


def _step_operands(seed=5, K=3, g=2, B=4):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    preds = jax.random.normal(ks[0], (K, g * B) + LATENT)
    x = jax.random.normal(ks[1], (B,) + LATENT)
    w = jax.nn.softmax(jax.random.normal(ks[2], (g * B, K)), axis=-1)
    coef = jax.random.normal(ks[3], (5, K, g * B)) * 0.5 + 1.0
    return preds, x, w, coef


@pytest.mark.parametrize("force_pallas", ["0", "1"])
def test_fused_step_per_row_dt_bitwise(monkeypatch, force_pallas):
    """(B,) dt with equal entries == scalar dt, bitwise, on the
    reference path and the Pallas-interpret path."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", force_pallas)
    preds, x, w, coef = _step_operands()
    kw = dict(g=2, cfg_scale=3.0)
    a = ops.fused_step(preds, x, w, coef, 0.125, **kw)
    b = ops.fused_step(preds, x, w, coef,
                       jnp.full((x.shape[0],), 0.125), **kw)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("force_pallas", ["0", "1"])
def test_fused_step_mixed_dt_rows_match_scalar_runs(monkeypatch,
                                                    force_pallas):
    """Row r of a mixed-dt launch == row r of a scalar-dt launch with
    that row's dt: the per-row dt path is exactly row-sliced."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", force_pallas)
    preds, x, w, coef = _step_operands(seed=6)
    B = x.shape[0]
    dts = jnp.array([0.1, 0.25, 0.05, 0.4])
    kw = dict(g=2, cfg_scale=3.0)
    mixed = np.asarray(ops.fused_step(preds, x, w, coef, dts, **kw))
    for r in range(B):
        ref = np.asarray(
            ops.fused_step(preds, x, w, coef, float(dts[r]), **kw))
        assert np.array_equal(mixed[r], ref[r]), f"row {r}"


# --- (h) dispatch helpers ----------------------------------------------------


@pytest.mark.parametrize("k", [1, 2])
def test_routed_slots_matches_plan(k):
    w = jax.nn.softmax(jax.random.normal(KEY, (5, 8)), axis=-1)
    valid = jnp.array([True, True, False, True, True, True, False, True])
    for v in (None, valid):
        ww = w * valid[None] if v is not None else w
        plan = make_dispatch_plan(ww, k, valid=v)
        idx, sw = routed_slots(ww, k, valid=v)
        assert np.array_equal(np.asarray(idx), np.asarray(plan.slot_idx))
        assert np.array_equal(np.asarray(sw), np.asarray(plan.slot_w))


def test_slot_coef_rows_uniform_matches_slot_coef():
    tab = jax.random.normal(KEY, (5, 8))
    idx_all = jax.random.randint(jax.random.PRNGKey(2), (6, 2), 0, 8)
    uniform = slot_coef(tab, idx_all)
    rows = slot_coef_rows(jnp.broadcast_to(tab, (6, 5, 8)), idx_all)
    assert np.array_equal(np.asarray(uniform), np.asarray(rows))


def test_slot_coef_rows_gathers_per_row_tables():
    tabs = jax.random.normal(KEY, (3, 5, 4))
    idx_all = jnp.array([[0, 1], [2, 3], [1, 0]])
    out = np.asarray(slot_coef_rows(tabs, idx_all))
    t = np.asarray(tabs)
    for r in range(3):
        for j in range(2):
            assert np.array_equal(out[:, j, r], t[r, :, idx_all[r, j]])
